"""Distributed-behaviour tests.  The pooled fetch / hierarchical top-k /
sharded-mesh tests need >1 device, so they run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (keeping this process
at 1 device per the dry-run isolation rule)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.elastic import SkipSlowReducer, viable_mesh_shape
from repro.distributed.hlo_analysis import hlo_metrics


def _run_subprocess(body: str):
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n"
              "import sys\nsys.path.insert(0, 'src')\n" + textwrap.dedent(body))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, cwd=".")
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_pooled_fetch_equals_local():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.core.pool import make_pooled_fetch, local_fetch
        mesh = make_mesh((2, 4), ("data", "model"))
        B, S, d, k = 4, 32, 16, 8
        pool = jax.random.normal(jax.random.PRNGKey(0), (B, S, d))
        idx = jax.random.randint(jax.random.PRNGKey(1), (B, k), 0, S)
        fetch = make_pooled_fetch(mesh, batch_axes=("data",))
        got = jax.jit(fetch)(pool, idx)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(local_fetch(pool, idx)),
                                   rtol=1e-6)
        print("FETCH_OK")
    """)
    assert "FETCH_OK" in out


def test_hierarchical_topk_equals_plain():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.core.topk import make_hierarchical_topk
        from repro.models.dsa import topk_select
        mesh = make_mesh((2, 4), ("data", "model"))
        B, S, k = 4, 64, 8
        scores = jax.random.normal(jax.random.PRNGKey(0), (B, S), jnp.float32)
        cache_len = jnp.array([64, 40, 10, 1], jnp.int32)
        hier = make_hierarchical_topk(mesh, k, batch_axes=("data",))
        i1, v1 = jax.jit(hier)(scores, cache_len)
        i2, v2 = topk_select(scores, cache_len, k)
        # same SET of selected indices among valid lanes
        for b in range(B):
            s1 = set(np.asarray(i1[b])[np.asarray(v1[b])].tolist())
            s2 = set(np.asarray(i2[b])[np.asarray(v2[b])].tolist())
            assert s1 == s2, (b, s1, s2)
        print("TOPK_OK")
    """)
    assert "TOPK_OK" in out


def test_decode_step_sharded_equals_single_device():
    """The full SAC decode step under a (2,4) mesh with the pooled fetch
    must produce the same logits as the unsharded single-device model."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.core.pool import make_pooled_fetch
        from repro.configs import get_config
        from repro.models.model import build_model
        from repro.distributed import sharding as shd
        import dataclasses

        cfg = get_config("qwen2-1.5b").reduced()
        B, S = 4, 32
        mesh = make_mesh((2, 4), ("data", "model"))
        fetch = make_pooled_fetch(mesh, batch_axes=("data",))
        m_ref = build_model(cfg, mode="sac")
        m_sh = build_model(cfg, fetch_fn=fetch, mode="sac")
        params = m_ref.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
        st, _ = m_ref.prefill(params, toks)
        _, l_ref = m_ref.decode(params, st, toks[:, 0])
        with shd.use_rules(shd.SERVE_RULES, mesh):
            st2, _ = m_ref.prefill(params, toks)
            st2 = dict(st2)
            st2["kv_pool"] = jax.device_put(
                st2["kv_pool"], NamedSharding(mesh, P(None, "data", "model", None)))
            st2["idx_pool"] = jax.device_put(
                st2["idx_pool"], NamedSharding(mesh, P(None, "data", "model", None)))
            with mesh:
                _, l_sh = jax.jit(m_sh.decode)(params, st2, toks[:, 0])
        # bf16 psum partial sums reduce in a different order than the
        # local gather: tolerance covers reduction-order rounding only
        diff = float(jnp.abs(l_ref - l_sh).max())
        assert diff < 5e-2, diff
        print("DECODE_SHARDED_OK", diff)
    """)
    assert "DECODE_SHARDED_OK" in out


# ---- elastic / straggler (pure host logic, no devices needed) ----

def test_viable_mesh_shape():
    assert viable_mesh_shape(256) == (16, 16)
    # losing a node: keep TP=16 (model fit is fixed), shrink DP, idle the
    # remainder
    assert viable_mesh_shape(255) == (15, 16)
    data, model = viable_mesh_shape(240)
    assert data * model <= 240 and model == 16


def test_skip_slow_reducer_drops_straggler():
    red = SkipSlowReducer(n_hosts=4, deadline_factor=2.0)
    g = lambda v: {"w": np.full((2,), float(v))}
    contributions = {0: (g(1.0), 0.10), 1: (g(2.0), 0.11),
                     2: (g(3.0), 0.12), 3: (g(100.0), 5.0)}  # straggler
    avg, report = red.aggregate(1, contributions)
    assert report.skipped == [3]
    assert report.contributors == 3
    np.testing.assert_allclose(avg["w"], np.full((2,), 2.0))


def test_skip_slow_reducer_quorum_floor():
    red = SkipSlowReducer(n_hosts=4, deadline_factor=1.01,
                          min_quorum_frac=0.75)
    g = lambda v: {"w": np.array([v])}
    # everyone "slow" except one: quorum forces keeping the 3 fastest
    contributions = {i: (g(i), float(i + 1)) for i in range(4)}
    avg, report = red.aggregate(2, contributions)
    assert report.contributors >= 3


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint saved under one topology restores onto a smaller
    'cluster' (1 device here) — the node-loss restart path."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.distributed.elastic import remesh, reshard_tree
    from repro.models.model import build_model
    from repro.training import checkpoint as ckpt

    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 7, {"params": params})
    restored, step, _ = ckpt.restore(str(tmp_path), {"params": params})
    mesh = remesh(1)
    on_mesh = reshard_tree(restored["params"], model.specs, mesh)
    for a, b in zip(jax.tree.leaves(on_mesh), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
