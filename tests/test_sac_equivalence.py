"""The SAC technique's core correctness property: with k >= context, the
sparse top-k decode is EXACTLY the dense full-attention decode — the
technique changes traffic, not math (paper §4.1).  Tested per family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models.model import build_model

B, S = 2, 32


@pytest.mark.parametrize("arch", sorted(ASSIGNED) + ["deepseek-v32"])
def test_sac_equals_dense_when_k_covers_context(arch, rng):
    cfg = get_config(arch).reduced()
    if not cfg.sac.enabled:
        pytest.skip("attention-free arch: SAC inapplicable (DESIGN §5)")
    cfg = dataclasses.replace(
        cfg, sac=dataclasses.replace(cfg.sac, topk=S + 8))
    m_sac = build_model(cfg, mode="sac")
    m_dense = build_model(cfg, mode="dense")
    params = m_sac.init(rng)
    if cfg.enc_dec:
        inp = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16)
        lengths = None                        # cross-KV pool never grows
    else:
        inp = jax.random.randint(rng, (B, S), 0, cfg.vocab)
        # leave pool headroom for the decoded tokens: decoding past pool
        # capacity has (deliberately) clamped semantics that differ
        # between the window and top-k paths
        lengths = jnp.full((B,), S - 4, jnp.int32)
    st1, _ = (m_sac.prefill(params, inp) if lengths is None
              else m_sac.prefill(params, inp, lengths=lengths))
    st2, _ = (m_dense.prefill(params, inp) if lengths is None
              else m_dense.prefill(params, inp, lengths=lengths))
    toks = jnp.array([3, 5], jnp.int32)
    for _ in range(2):
        st1, l1 = m_sac.decode(params, st1, toks)
        st2, l2 = m_dense.decode(params, st2, toks)
        assert float(jnp.abs(l1 - l2).max()) == 0.0
        toks = jnp.argmax(l1, -1).astype(jnp.int32)


def test_sparse_topk_actually_selects(rng):
    """With small k the sparse path differs from dense (it IS selecting)
    but stays finite and close in distribution."""
    cfg = get_config("qwen2-1.5b").reduced()
    cfg = dataclasses.replace(cfg, sac=dataclasses.replace(cfg.sac, topk=4))
    m_sac = build_model(cfg, mode="sac")
    m_dense = build_model(cfg, mode="dense")
    params = m_sac.init(rng)
    toks_in = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    st1, _ = m_sac.prefill(params, toks_in)
    st2, _ = m_dense.prefill(params, toks_in)
    t = jnp.array([3, 5], jnp.int32)
    _, l1 = m_sac.decode(params, st1, t)
    _, l2 = m_dense.decode(params, st2, t)
    assert not jnp.isnan(l1).any()
    assert float(jnp.abs(l1 - l2).max()) > 0.0  # selection happened


def test_variable_lengths_masking(rng):
    """Requests with different cache_len must not read beyond their
    prefix (cross-request isolation in the batched pool)."""
    cfg = get_config("qwen2-1.5b").reduced()
    m = build_model(cfg, mode="sac")
    params = m.init(rng)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    lengths = jnp.array([S // 2, S], jnp.int32)
    st, _ = m.prefill(params, toks, lengths=lengths)
    # request 0 with garbage in [S/2, S) of the pool must decode the same
    # as a fresh prefill of only its prefix
    st_ref, _ = m.prefill(params, toks[:, :S // 2])
    t = jnp.array([3, 5], jnp.int32)
    _, l_full = m.decode(params, st, t)
    _, l_ref = m.decode(params, st_ref, t)
    assert float(jnp.abs(l_full[0] - l_ref[0]).max()) < 1e-5


def test_decode_matches_forward_next_token(rng):
    """Greedy decode logits == forward() logits at the same position
    (prefill/decode consistency, dense mode, exactness)."""
    cfg = get_config("minicpm-2b").reduced()
    m = build_model(cfg, mode="dense")
    params = m.init(rng)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    st, _ = m.prefill(params, toks[:, :-1])
    _, dec_logits = m.decode(params, st, toks[:, -1])
    fwd_logits, _ = m.forward(params, toks)
    diff = jnp.abs(dec_logits - fwd_logits[:, -1]).max()
    assert float(diff) < 0.15, float(diff)  # bf16 accumulation-order noise
