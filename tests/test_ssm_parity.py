"""Recurrent-math parity: the chunked/parallel training formulations must
match the sequential decode recurrences step by step (Mamba2 SSD, mLSTM
decayed linear attention, sLSTM) — the correctness backbone of zamba2
and xlstm serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm
from repro.models.layers import init_params


def test_mamba2_block_matches_sequential_decode(rng):
    cfg = get_config("zamba2-7b").reduced()
    p = init_params(ssm.mamba2_param_specs(cfg), rng)
    B, S = 2, 16
    x = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16) * 0.5

    y_par, _ = ssm.mamba2_block(p, x, cfg, chunk=4)

    d_inner = 2 * cfg.d_model
    state = (jnp.zeros(ssm.mamba2_state_shape(cfg, B)[0], jnp.float32),
             jnp.zeros((B, 3, d_inner), jnp.bfloat16))
    ys = []
    for t in range(S):
        y_t, state = ssm.mamba2_decode(p, x[:, t], cfg, state)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=0.15, atol=0.05)  # bf16 chunked-vs-step


def test_mlstm_block_matches_recurrent_decode(rng):
    cfg = get_config("xlstm-125m").reduced()
    p = init_params(ssm.mlstm_param_specs(cfg), rng)
    B, S = 2, 12
    x = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16) * 0.5

    y_par = ssm.mlstm_block(p, x, cfg)

    nh = cfg.n_heads
    hd = cfg.d_model // nh
    state = (jnp.zeros((B, nh, hd, hd), jnp.float32),
             jnp.zeros((B, nh, hd), jnp.float32),
             jnp.zeros((B, nh), jnp.float32))
    ys = []
    for t in range(S):
        y_t, state = ssm.mlstm_decode(p, x[:, t], cfg, state)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=0.2, atol=0.08)


def test_slstm_block_matches_decode(rng):
    cfg = get_config("xlstm-125m").reduced()
    p = init_params(ssm.slstm_param_specs(cfg), rng)
    B, S = 2, 10
    x = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16) * 0.5

    y_par = ssm.slstm_block(p, x, cfg)

    state = tuple(jnp.zeros((B, cfg.d_model), jnp.float32) for _ in range(4))
    ys = []
    for t in range(S):
        y_t, state = ssm.slstm_decode(p, x[:, t], cfg, state)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=0.1, atol=0.03)


def test_mamba2_state_carries_context(rng):
    """Decode continuation must depend on the prior context (the state is
    doing its job): different prefixes -> different next outputs."""
    cfg = get_config("zamba2-7b").reduced()
    p = init_params(ssm.mamba2_param_specs(cfg), rng)
    B = 1
    d_inner = 2 * cfg.d_model
    zero = (jnp.zeros(ssm.mamba2_state_shape(cfg, B)[0], jnp.float32),
            jnp.zeros((B, 3, d_inner), jnp.bfloat16))
    xa = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_model), jnp.bfloat16)
    xb = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.d_model), jnp.bfloat16)
    xq = jax.random.normal(jax.random.PRNGKey(3), (B, cfg.d_model), jnp.bfloat16)
    _, sa = ssm.mamba2_decode(p, xa, cfg, zero)
    _, sb = ssm.mamba2_decode(p, xb, cfg, zero)
    ya, _ = ssm.mamba2_decode(p, xq, cfg, sa)
    yb, _ = ssm.mamba2_decode(p, xq, cfg, sb)
    assert float(jnp.abs(ya - yb).max()) > 1e-3
