"""Fetch pipeline: speculative prefetch, radix/score warm-up, and the
issued/exposed fabric split (serving/prefetch.py + hisparse warm inserts).

Acceptance properties (ISSUE 2):
  - warm inserts never change results: decoded tokens are bit-identical
    with the pipeline on vs off (the pool stays authoritative);
  - ``issued_fabric_s >= exposed_fabric_s >= 0`` everywhere, and exposed
    is STRICTLY below issued on the CXL backend once overlap is on;
  - wasted-prefetch accounting is consistent: prefetched == useful +
    wasted, measured in-graph by the HiSparse pf_* counters;
  - on the shared drift trace of tests/test_engine_buffer.py, the
    engine-measured hit rate with prefetch + warm-up STRICTLY beats the
    LRU-only buffer;
  - the simulator's analytic overlap model (transfer.PipelineModel, the
    exact object simulate() uses) agrees with the engine-measured
    exposed time on the same trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from parity import assert_parity, build_engine, drift_parity, \
    drift_requests, run_to_completion

from repro.configs import get_config
from repro.core import hisparse
from repro.serving.engine import Engine
from repro.serving.prefetch import FetchPlanner, analytic_prefetch
from repro.serving.request import sharegpt_trace
from repro.serving.simulator import hit_rate


def _trace(cfg, n=4, ctx=40, out=6, seed=3):
    return sharegpt_trace(n, context_len=ctx, output_len=out, seed=seed,
                          ctx_jitter=0.0, vocab=cfg.vocab)


def _pool(B, S, d, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, S, d),
                             jnp.bfloat16)


# ---------------------------------------------------------------------------
# warm_insert unit semantics
# ---------------------------------------------------------------------------


def test_warm_insert_is_insert_without_read():
    """Warm inserts make positions resident but count no hits/misses and
    advance no clock; a later demand read then hits."""
    B, S, d, buf, w = 1, 32, 4, 8, 4
    state = hisparse.init_buffer(B, buf, S, d)
    pool = _pool(B, S, d)
    idx = jnp.array([[3, 5, 7, 9]], jnp.int32)
    vals = jnp.take_along_axis(pool, idx[..., None], axis=1)
    state2, ins = hisparse.warm_insert(state, idx, vals,
                                       jnp.ones((B, w), bool))
    assert int(ins[0]) == w
    assert int(state2.pf_inserted[0]) == w and int(state2.pf_used[0]) == 0
    assert int(state2.clock[0]) == int(state.clock[0])
    _, hit = hisparse.lookup(state2, idx)
    assert bool(hit.all())
    # demand read: all four are hits, and all four consume their pf flag
    _, state3, hits, misses = hisparse.read_through(
        state2, idx, vals, jnp.ones((B, w), bool))
    assert int(hits[0]) == w and int(misses[0]) == 0
    assert int(state3.pf_used[0]) == w
    assert not bool(state3.pf_flag.any())        # flags consumed once


def test_warm_insert_never_evicts_current_step_hits():
    """A warm insert after a demand swap-in must evict older LRU slots,
    never the entries the current step just touched."""
    B, S, d, buf = 1, 64, 4, 4
    state = hisparse.init_buffer(B, buf, S, d)
    pool = _pool(B, S, d)

    def demand(state, positions):
        idx = jnp.array([positions], jnp.int32)
        f = jnp.take_along_axis(pool, idx[..., None], axis=1)
        return hisparse.swap_in(state, idx, f, jnp.ones_like(idx, bool))[0]

    state = demand(state, [0, 1])        # clock 1 (older)
    state = demand(state, [2, 3])        # clock 2: current step {2, 3}
    idx = jnp.array([[10, 11, 12]], jnp.int32)
    vals = jnp.take_along_axis(pool, idx[..., None], axis=1)
    state, ins = hisparse.warm_insert(state, idx, vals,
                                      jnp.ones_like(idx, bool))
    # only 2 evictable slots (0 and 1): the third candidate is dropped
    # rather than evicting the protected current-step entries
    assert int(ins[0]) == 2
    _, hit = hisparse.lookup(state, jnp.array([[2, 3]], jnp.int32))
    assert bool(hit.all())
    _, hit01 = hisparse.lookup(state, jnp.array([[0, 1]], jnp.int32))
    assert not bool(hit01.any())


def test_warm_insert_skips_resident_positions():
    B, S, d, buf = 1, 32, 4, 8
    state = hisparse.init_buffer(B, buf, S, d)
    pool = _pool(B, S, d)
    idx = jnp.array([[4, 5]], jnp.int32)
    vals = jnp.take_along_axis(pool, idx[..., None], axis=1)
    state, ins = hisparse.warm_insert(state, idx, vals,
                                      jnp.ones_like(idx, bool))
    assert int(ins[0]) == 2
    # same positions again: nothing inserted, counters unchanged
    state, ins2 = hisparse.warm_insert(state, idx, vals,
                                       jnp.ones_like(idx, bool))
    assert int(ins2[0]) == 0
    assert int(state.pf_inserted[0]) == 2


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_property_warm_insert_preserves_read_values(data):
    """Interleaved warm inserts never change read_through values, keep
    the page table consistent, and keep pf accounting exact:
    used <= inserted and both monotone (wasted = inserted - used >= 0)."""
    B = data.draw(st.integers(1, 2))
    S = data.draw(st.sampled_from([16, 32]))
    buf = data.draw(st.sampled_from([4, 8]))
    k = data.draw(st.sampled_from([2, 4]))
    w = data.draw(st.sampled_from([1, 3]))
    d = 4
    pool = _pool(B, S, d, seed=data.draw(st.integers(0, 99)))
    state = hisparse.init_buffer(B, buf, S, d)
    rng = np.random.default_rng(data.draw(st.integers(0, 99)))
    for _ in range(data.draw(st.integers(1, 5))):
        idx = jnp.asarray(rng.integers(0, S, (B, k)), jnp.int32)
        valid = jnp.asarray(rng.random((B, k)) < 0.9)
        fetched = jnp.take_along_axis(pool, idx[..., None], axis=1)
        vals, state, _, _ = hisparse.read_through(state, idx, fetched, valid)
        v = np.asarray(valid)
        np.testing.assert_array_equal(
            np.asarray(vals, np.float32)[v],
            np.asarray(fetched, np.float32)[v])
        widx = jnp.asarray(rng.integers(0, S, (B, w)), jnp.int32)
        wvals = jnp.take_along_axis(pool, widx[..., None], axis=1)
        state, _ = hisparse.warm_insert(
            state, widx, wvals, jnp.asarray(rng.random((B, w)) < 0.9))
        ins = np.asarray(state.pf_inserted)
        used = np.asarray(state.pf_used)
        assert (used <= ins).all() and (used >= 0).all()
        # residency maps stay bijective under mixed demand/warm updates
        pt = np.asarray(state.page_table)
        sp = np.asarray(state.slot_pos)
        for b in range(B):
            for slot in range(buf):
                if sp[b, slot] >= 0:
                    assert pt[b, sp[b, slot]] == slot
            for pos in range(S):
                if pt[b, pos] >= 0:
                    assert sp[b, pt[b, pos]] == pos


# ---------------------------------------------------------------------------
# engine: bit-identity + accounting invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "minicpm-2b"])
def test_tokens_bit_identical_prefetch_on_off(arch):
    """The fetch pipeline changes traffic and timing, never results."""
    cfg = get_config(arch).reduced()
    engines = [Engine(cfg, slots=2, max_ctx=96, seed=2, prefetch=pf)
               for pf in (True, False)]
    for eng in engines:
        for r in _trace(cfg, n=2, ctx=40, out=50, seed=7):
            eng.submit(r)
        for _ in range(10):
            eng.step()
    on, off = engines
    assert on.slot_tokens == off.slot_tokens
    assert on.stats.prefetched_entries > 0


@settings(max_examples=3, deadline=None)
@given(st.data())
def test_property_prefetch_bit_identity_random_configs(data):
    """Random (arch, seed, trace) draws: greedy token streams match
    prefetch-on vs prefetch-off exactly."""
    arch = data.draw(st.sampled_from(["qwen2-1.5b", "gemma3-12b"]))
    seed = data.draw(st.integers(0, 5))
    tseed = data.draw(st.integers(0, 5))
    cfg = get_config(arch).reduced()
    streams = []
    for pf in (True, False):
        eng = Engine(cfg, slots=1, max_ctx=64, seed=seed, prefetch=pf)
        for r in _trace(cfg, n=1, ctx=24, out=20, seed=tseed):
            eng.submit(r)
        for _ in range(6):
            eng.step()
        streams.append([t[:] for t in eng.slot_tokens])
    assert streams[0] == streams[1]


def test_engine_accounting_invariants_with_prefetch():
    """issued >= exposed >= 0; prefetched == useful + wasted; prefetch
    entries are part of the unified entries_fetched tally."""
    cfg = get_config("qwen2-1.5b").reduced()
    eng = Engine(cfg, slots=2, max_ctx=96, prefetch=True)
    out = eng.run(_trace(cfg, n=4))
    assert out["n_done"] == 4
    s = eng.stats
    assert s.issued_fabric_s >= s.exposed_fabric_s >= 0.0
    assert s.exposed_fabric_s < s.issued_fabric_s     # CXL: overlap hides
    assert s.prefetched_entries == s.prefetch_useful + s.prefetch_wasted
    assert s.prefetch_useful > 0                      # speculation lands
    assert s.prefetch_wasted >= 0
    # unified schema: fabric entries = demand misses + prefetched
    assert s.pool_entries_fetched == s.buffer_misses + s.prefetched_entries
    assert s.traffic.prefetch_bytes > 0
    assert s.traffic.bytes_fetched >= s.traffic.prefetch_bytes


def test_engine_virtual_clock_is_deterministic():
    """Engine latency metrics come from the virtual clock (modeled
    compute + exposed fabric): two identical runs report identical
    TTFT/TBT, and timestamps are strictly positive/ordered."""
    cfg = get_config("qwen2-1.5b").reduced()
    outs = []
    for _ in range(2):
        eng = Engine(cfg, slots=2, max_ctx=96, seed=1)
        reqs = _trace(cfg, n=4)
        outs.append(eng.run(reqs))
        assert eng.clock_s > 0
        for r in reqs:
            assert 0 <= r.dispatch_s < r.first_token_s <= r.finish_s
    assert outs[0]["ttft_mean_s"] == outs[1]["ttft_mean_s"]
    assert outs[0]["tbt_mean_s"] == outs[1]["tbt_mean_s"]
    assert outs[0]["throughput_tok_s"] == outs[1]["throughput_tok_s"]


def test_warmup_plan_merges_scores_and_radix():
    cfg = get_config("qwen2-1.5b").reduced()
    planner = FetchPlanner(cfg, n_layers=2)
    warm = jnp.array([[1, 5, 9], [2, 6, 10]], jnp.int32)
    plan = planner.warmup_plan(warm, matched_tokens=4, prompt_len=40)
    assert plan is not None
    w_total = 3 + cfg.sac.warmup_radix
    assert plan.idx.shape == (2, w_total)
    assert bool(plan.valid[:, :3].all())
    # radix lanes: the 4 matched tail positions valid, earlier ones not
    radix_valid = np.asarray(plan.valid[:, 3:])
    assert radix_valid.sum(axis=1).tolist() == [4, 4]
    # no radix match, no scores -> no plan
    assert planner.warmup_plan(None, 0, 40) is None


def test_warmup_plan_masks_windowed_layers():
    """Radix warm-up lanes outside a windowed layer's decode mask are
    invalid — seeding them would be guaranteed waste."""
    cfg = get_config("gemma3-12b").reduced()   # kv layers: [local 32, global]
    planner = FetchPlanner(cfg, n_layers=2)
    assert planner.layer_windows == [cfg.local_window, 0]
    plan = planner.warmup_plan(None, matched_tokens=12, prompt_len=40)
    rv = np.asarray(plan.valid)
    r = cfg.sac.warmup_radix                   # prefix-tail positions 4..11
    # global layer keeps all tail lanes; the windowed layer only those
    # its decode mask (pos > prompt_len - window) can still select
    assert rv[1].sum() == r
    assert rv[0].sum() == sum(p > 40 - cfg.local_window
                              for p in range(12 - r, 12))
    assert 0 < rv[0].sum() < rv[1].sum()


def test_radix_warmup_seeds_shared_prefix():
    """Identical prompts through one slot: the recycled request's hot
    tier is pre-seeded from the radix-reused pages, so its cold-start
    misses drop vs the LRU-only engine."""
    cfg = get_config("qwen2-1.5b").reduced()
    runs = {}
    for pf in (False, True):
        eng = Engine(cfg, slots=1, max_ctx=96, seed=0, prefetch=pf)
        reqs = _trace(cfg, n=3, ctx=40, out=4)
        shared = reqs[0].prompt_tokens
        for r in reqs:
            r.prompt_tokens = shared.copy()
        out = eng.run(reqs)
        assert out["n_done"] == 3
        runs[pf] = eng.stats
    assert runs[True].buffer_misses < runs[False].buffer_misses
    assert runs[True].hit_rate > runs[False].hit_rate


# ---------------------------------------------------------------------------
# shared drift trace — now owned by the parity harness (tests/parity.py)
# ---------------------------------------------------------------------------


def test_drift_trace_prefetch_strictly_improves_hit_rate():
    """Acceptance: with prefetch + warm-up on, the engine-measured hit
    rate strictly beats the LRU-only buffer on the shared drift trace,
    and exposed < issued on the CXL backend."""
    for buf in (32, 64):
        runs = {}
        for pf in (False, True):
            eng = build_engine(buf, prefetch=pf)
            run_to_completion(eng, drift_requests(eng.cfg))
            runs[pf] = eng
        lru, pf = runs[False], runs[True]
        assert pf.stats.hit_rate > lru.stats.hit_rate, \
            (buf, pf.stats.hit_rate, lru.stats.hit_rate)
        assert pf.stats.buffer_misses < lru.stats.buffer_misses
        assert pf.stats.exposed_fabric_s < pf.stats.issued_fabric_s
        # speculation on this trace is near-perfect: most prefetched
        # entries are demand-hit the following step
        assert pf.stats.prefetch_precision > 0.5
        assert pf.stats.prefetched_entries == \
            pf.stats.prefetch_useful + pf.stats.prefetch_wasted


def test_sim_overlap_model_matches_engine_exposed():
    """Acceptance: the simulator's analytic overlap model — the exact
    PipelineModel simulate() evaluates — reproduces the engine-measured
    exposed seconds when driven by the engine's per-step issued traffic,
    and the hit-model-predicted issued total brackets the measured one.

    The measurement/replay loop and its tolerances now live in the
    parity harness (tests/parity.py assert_parity), shared with
    tests/test_engine_buffer.py and tests/test_parity_suite.py."""
    rep = drift_parity(32)
    assert_parity(rep)
    rep_pf = drift_parity(32, prefetch=True)
    assert_parity(rep_pf)
    # speculation issues extra fabric seconds on top of the LRU baseline
    assert rep_pf.measured_precision > 0.5


# ---------------------------------------------------------------------------
# analytic prefetch model (simulator side)
# ---------------------------------------------------------------------------


def test_analytic_prefetch_monotone_and_bounded():
    base = hit_rate(4096, 2048, 65536)
    h0, issued0 = analytic_prefetch(base, 0, 2048)
    assert h0 == base and issued0 == 0.0
    prev = base
    for w in (128, 512, 2048):
        h, issued = analytic_prefetch(base, w, 2048)
        assert base <= prev <= h <= 1.0
        assert issued > 0
        # consistency with the measured schema: the modeled useful
        # entries ((h - base) * topk) never exceed the modeled inserts
        assert (h - base) * 2048 <= issued + 1e-9
        prev = h


def test_simulator_prefetch_and_overlap_improve_cxl():
    from repro.serving.simulator import (SimConfig, default_backends,
                                         profile_from_config, simulate)
    model = profile_from_config(get_config("deepseek-v32"))
    b = default_backends()["cxl"]
    reqs = sharegpt_trace(48, context_len=65536, output_len=128, seed=1)
    base = simulate(reqs, model, b, SimConfig(concurrency=32))
    pipe = simulate(reqs, model, b, SimConfig(concurrency=32,
                                              overlap_frac=0.85,
                                              prefetch_width=512))
    assert base["n_done"] == pipe["n_done"] == 48
    # without an overlap model every issued second is exposed
    assert base["exposed_fabric_s"] == pytest.approx(
        base["issued_fabric_s"])
    assert pipe["exposed_fabric_s"] < pipe["issued_fabric_s"]
    assert pipe["sim_hit_rate"] > base["sim_hit_rate"]
    assert pipe["throughput_tok_s"] > base["throughput_tok_s"]
    # wasted-prefetch consistency holds for the analytic twin too:
    # prefetched >= useful >= 0 (wasted = prefetched - useful >= 0)
    assert pipe["prefetched_entries"] >= pipe["prefetch_useful"] >= 0
