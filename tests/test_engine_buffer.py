"""Real HiSparse hot buffer wired into the engine decode path.

Acceptance properties (paper §5.5 miss-only traffic):
  - measured buffer_hits/buffer_misses are live, nonzero numbers;
  - fabric time is charged on misses only (less than the cold-read
    convention's full top-k charge);
  - decoded tokens are bit-identical with the buffer on vs off (the hot
    tier changes traffic, never results);
  - parity: the simulator's analytic hit_rate() matches the
    engine-measured hit rate on a shared drifting-top-k trace.
"""

from parity import assert_parity, drift_parity

from repro.configs import get_config
from repro.serving.engine import Engine
from repro.serving.request import sharegpt_trace


def _trace(cfg, n=4, ctx=40, out=6, seed=3):
    return sharegpt_trace(n, context_len=ctx, output_len=out, seed=seed,
                          ctx_jitter=0.0, vocab=cfg.vocab)


def test_buffer_counters_are_live():
    cfg = get_config("qwen2-1.5b").reduced()
    eng = Engine(cfg, slots=2, max_ctx=96)      # buffer on by default
    out = eng.run(_trace(cfg, n=4))
    assert out["n_done"] == 4
    assert eng.stats.buffer_hits + eng.stats.buffer_misses > 0
    assert eng.stats.buffer_hits > 0            # top-k sets overlap
    assert 0.0 < eng.stats.hit_rate < 1.0
    # pool traffic is THE miss traffic: entries fetched == misses, and
    # bytes follow at entry granularity
    assert eng.stats.pool_entries_fetched == eng.stats.buffer_misses
    assert eng.stats.traffic.bytes_fetched == \
        eng.stats.buffer_misses * eng.sac.entry_bytes


def test_fabric_charged_on_misses_only():
    cfg = get_config("qwen2-1.5b").reduced()
    on = Engine(cfg, slots=2, max_ctx=96, seed=1)
    off = Engine(cfg, slots=2, max_ctx=96, seed=1, track_buffer=False)
    r_on = on.run(_trace(cfg, n=4))
    r_off = off.run(_trace(cfg, n=4))
    assert off.stats.buffer_hits == off.stats.buffer_misses == 0
    # buffered engine fetched strictly fewer entries over the fabric
    assert on.stats.pool_entries_fetched < off.stats.pool_entries_fetched
    assert r_on["fabric_time_s"] < r_off["fabric_time_s"]
    # both decoded the same number of tokens
    assert r_on["engine_tokens"] == r_off["engine_tokens"]


def test_tokens_bit_identical_buffer_on_off():
    """The hot tier changes traffic, never results: greedy streams match
    token-for-token."""
    cfg = get_config("minicpm-2b").reduced()
    engines = [Engine(cfg, slots=2, max_ctx=96, seed=2,
                      track_buffer=tb) for tb in (True, False)]
    for eng in engines:
        # long outputs: no slot finishes within the observed window, so
        # slot_tokens holds every decoded token
        for r in _trace(cfg, n=2, ctx=40, out=50, seed=7):
            eng.submit(r)
        for _ in range(12):
            eng.step()
    on, off = engines
    assert on.slot_tokens == off.slot_tokens
    assert on.stats.buffer_hits + on.stats.buffer_misses > 0


def test_slot_recycling_resets_buffer_lane():
    """Three requests through one slot: the recycled lane must start cold
    (no cross-request residency) and still complete correctly."""
    cfg = get_config("qwen2-1.5b").reduced()
    eng = Engine(cfg, slots=1, max_ctx=96, seed=0)
    out = eng.run(_trace(cfg, n=3, ctx=24, out=4))
    assert out["n_done"] == 3
    # every request's first decode step starts cold: >= one full-topk miss
    # burst per request
    assert eng.stats.buffer_misses >= 3 * min(cfg.sac.topk, 24)


def test_engine_hit_rate_parity_with_analytic_model():
    """Ground the simulator's analytic hit model against the ENGINE's
    measured hit rate on a shared trace.

    The analytic model assumes the paper-scale workload: consecutive
    top-k sets drift slowly.  Tiny reduced models churn far more (random
    init indexer over a tiny candidate pool), so the shared trace is the
    controlled drift of the parity harness (tests/parity.py) injected
    via the engine's topk_fn hook — the read path, buffer updates, and
    counters are the real jitted wiring."""
    for buf in (32, 64):
        assert_parity(drift_parity(buf))


def test_per_layer_buffer_sizing_is_transparent():
    """LayerSizer apportioning (serving/arbiter.py): a windowed arch gets
    non-uniform per-layer sizes summing to the uniform total, decoded
    tokens stay bit-identical, and the per-layer miss counters are live
    so the sizer's miss-rate signal exists."""
    import dataclasses
    # kv layers: [local (window 8), global] — the window is shrunk below
    # the uniform per-layer size so apportioning has room to act
    cfg = dataclasses.replace(get_config("gemma3-12b").reduced(),
                              local_window=8)
    engines = {}
    for sizing in ("uniform", "windowed"):
        eng = Engine(cfg, slots=1, max_ctx=96, seed=2, layer_sizing=sizing)
        for r in _trace(cfg, n=1, ctx=40, out=30, seed=7):
            eng.submit(r)
        for _ in range(8):
            eng.step()
        engines[sizing] = eng
    uni, win = engines["uniform"], engines["windowed"]
    assert uni.buffer_sizes is None
    assert win.buffer_sizes is not None
    buf = cfg.sac.device_buffer_size
    assert sum(win.buffer_sizes) == buf * 2
    # the windowed layer is capped at its selectable window; the surplus
    # went to the full-attention layer
    assert win.buffer_sizes[0] <= cfg.local_window
    assert win.buffer_sizes[1] > buf
    # sizing shapes traffic, never results
    assert uni.slot_tokens == win.slot_tokens
    # ... and the reapportioned tier never hits less: the windowed layer
    # cannot use slots beyond its window, the global layer can
    assert win.stats.hit_rate >= uni.stats.hit_rate
    # per-layer counters are live and consistent with the totals
    for eng in engines.values():
        tot = eng.stats.layer_hits + eng.stats.layer_misses
        assert tot.sum() == eng.stats.buffer_hits + eng.stats.buffer_misses
        assert (eng.stats.layer_miss_rates() >= 0).all()
