"""Engine↔simulator parity harness: one reusable fixture for the shared
drift/saturation traces that ground the simulator's analytic models
against the real engine.

Before PR 3 every parity check re-declared its own drift trace and
replay loop (tests/test_engine_buffer.py and tests/test_prefetch.py each
carried a copy).  This module owns them:

  - the **drift trace**: a controlled synthetic top-k stream (lane j
    re-points every T steps, staggered — ~K/T churn per step, the
    paper's slow salient-context drift) injected through the engine's
    ``topk_fn`` hook, so the read path, buffer updates, and counters are
    the real jitted wiring;
  - the **saturation trace**: the same drift demand plus deliberately
    wide speculation whose tail lanes are junk — the regime where
    unarbitrated prefetch floods the link and the budget arbiter
    (serving/arbiter.py) must cut exactly the useless share;
  - :func:`drift_parity` / :func:`assert_parity`: run the engine on a
    trace, evaluate the simulator-side analytic twins (``hit_rate``,
    ``analytic_prefetch``, ``PipelineModel``, the fabric models) on the
    same parameters, and compare hit rate, issued/exposed seconds, and
    prefetch precision within tolerance.
"""
import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.transfer import FABRICS, PipelineModel
from repro.serving.engine import Engine
from repro.serving.prefetch import analytic_prefetch
from repro.serving.request import sharegpt_trace
from repro.serving.simulator import hit_rate

# the shared drift-trace constants (PR 1's controlled workload)
K, T, CTX, OUT = 16, 32, 80, 40


def drift_topk(scores, cache_len):
    """Lane j re-points every T steps (staggered): ~K/T changes/step."""
    B = scores.shape[0]
    j = jnp.arange(K, dtype=jnp.int32)[None, :]
    t = cache_len[:, None]
    pos = (j * 7 + 131 * ((t + j) // T)) % CTX
    return pos.astype(jnp.int32), jnp.ones((B, K), bool)


def drift_prefetch(scores, cache_len):
    """Speculate the NEXT step's drift selection — the planner hook's
    analogue of score-based speculation for the synthetic workload."""
    idx, valid = drift_topk(scores, cache_len + 1)
    return idx, valid


def junk_prefetch(width: int):
    """Saturation-trace speculation: the first K lanes are next step's
    true drift selection, the remaining ``width - K`` lanes are junk
    positions that will never be demand-read.  Lanes are best-first, so
    an arbiter budget of K keeps exactly the useful share."""

    def fn(scores, cache_len):
        B = scores.shape[0]
        idx, _ = drift_topk(scores, cache_len + 1)
        j = jnp.arange(width - K, dtype=jnp.int32)[None, :]
        t = cache_len[:, None]
        junk = (j * 17 + t * 13 + 37) % CTX
        full = jnp.concatenate([idx, junk.astype(jnp.int32)], axis=1)
        return full, jnp.ones((B, width), bool)

    return fn


def lane_drift_topk(periods):
    """Per-lane drift: slot ``b`` re-points every ``periods[b]`` steps —
    small periods churn fast (heavy misses, heavy link demand), large
    periods are near-static.  The heterogeneous-pressure workload the
    pressure-aware placer and precision-weighted grants act on.
    Selections live on EVEN positions only, so the odd-position junk of
    ``mixed_junk_prefetch`` is provably never demand-read."""
    per = [int(p) for p in periods]

    def fn(scores, cache_len):
        B = scores.shape[0]
        j = jnp.arange(K, dtype=jnp.int32)[None, :]
        t = cache_len[:, None]
        p = jnp.asarray((per + [T] * B)[:B], jnp.int32)[:, None]
        pos = 2 * ((j * 7 + 131 * ((t + j) // p)) % (CTX // 2))
        return pos.astype(jnp.int32), jnp.ones((B, K), bool)

    return fn


def mixed_junk_prefetch(width: int, bad_lanes, topk_fn=None):
    """Per-slot speculation quality: good lanes speculate next step's
    true selection and NOTHING else (lanes beyond K invalid — a bigger
    grant cannot make them insert junk), bad lanes speculate junk across
    the full width.  Under a budget cut bad slots therefore waste their
    whole grant while good slots keep pure signal — the asymmetry
    precision-weighted grants exist to exploit."""
    tk = topk_fn or drift_topk
    bad = set(int(b) for b in bad_lanes)

    def fn(scores, cache_len):
        B = scores.shape[0]
        idx, _ = tk(scores, cache_len + 1)
        j = jnp.arange(width - K, dtype=jnp.int32)[None, :]
        t = cache_len[:, None]
        # odd positions: disjoint from the even-only demand stream
        junk = (2 * ((j * 17 + t * 13 + 37) % (CTX // 2)) + 1) \
            .astype(jnp.int32)
        good_idx = jnp.concatenate([idx, junk], axis=1)
        bad_idx = jnp.concatenate([junk, idx], axis=1)
        lane = jnp.arange(width, dtype=jnp.int32)[None, :]
        is_bad = jnp.asarray([b in bad for b in range(B)])[:, None]
        valid = jnp.where(is_bad, jnp.ones((B, width), bool), lane < K)
        return jnp.where(is_bad, bad_idx, good_idx), valid

    return fn


def drift_requests(cfg, n=1, ctx=CTX, out=OUT, seed=5):
    return sharegpt_trace(n, context_len=ctx, output_len=out, seed=seed,
                          ctx_jitter=0.0, vocab=cfg.vocab)


def build_engine(buf: int, *, arch: str = "qwen2-1.5b",
                 prefetch: bool = False, prefetch_fn="drift",
                 overlap: Optional[bool] = None,
                 arbiter: Optional[bool] = None,
                 sac_overrides: Optional[Dict] = None,
                 placement: Optional[str] = None,
                 topk_fn=drift_topk,
                 slots: int = 1, seed: int = 0) -> Engine:
    """A reduced engine wired to the controlled drift top-k stream."""
    cfg = get_config(arch).reduced()
    if sac_overrides:
        cfg = dataclasses.replace(
            cfg, sac=dataclasses.replace(cfg.sac, **sac_overrides))
    fn = drift_prefetch if prefetch_fn == "drift" else prefetch_fn
    return Engine(cfg, slots=slots, max_ctx=160, device_buffer=buf,
                  topk_fn=topk_fn, prefetch=prefetch,
                  prefetch_fn=fn if prefetch else None,
                  overlap=overlap, arbiter=arbiter,
                  placement=placement, seed=seed)


# the saturation-trace constants: hot tier strictly below the context so
# junk inserts churn the tier instead of eventually caching the whole
# prefix; speculation 3x wider than the useful share; near-zero hide
# window so every issued second is exposed
SAT_BUF, SAT_WIDTH = 40, 48
SAT_SAC = dict(prefetch_width=SAT_WIDTH, overlap_frac=0.05,
               warmup_entries=0, warmup_radix=0)


def build_saturation_engine(*, arbiter: bool, min_width: int = K,
                            link_budget_frac: Optional[float] = None,
                            seed: int = 0) -> Engine:
    """The saturation trace: drift demand + junk-tailed speculation."""
    sac = dict(SAT_SAC)
    if arbiter:
        sac["min_prefetch_width"] = min_width
    if link_budget_frac is not None:
        sac["link_budget_frac"] = link_budget_frac
    return build_engine(SAT_BUF, prefetch=True,
                        prefetch_fn=junk_prefetch(SAT_WIDTH),
                        sac_overrides=sac, arbiter=arbiter, seed=seed)


def shared_prefix_requests(cfg, n=6, prefix=24, suffix=8, out=6,
                           reuse_p=1.0, seed=3):
    """Shared-prefix engine trace (real tokens, literal sharing) — the
    radix prefix cache's workload (ISSUE 5)."""
    from repro.serving.request import shared_prefix_trace
    return shared_prefix_trace(n, prefix_len=prefix, suffix_len=suffix,
                               output_len=out, reuse_p=reuse_p, seed=seed,
                               vocab=cfg.vocab)


def build_radix_engine(*, radix: bool = True, slots: int = 1,
                       arch: str = "qwen2-1.5b", seed: int = 0) -> Engine:
    """Engine wired for the prefix-locality loop: radix_affinity
    placement when the cache is on, plain default when it is off (the
    A/B baseline the locality acceptance tests compare against)."""
    cfg = get_config(arch).reduced()
    return Engine(cfg, slots=slots, max_ctx=96, seed=seed, radix=radix,
                  placement="radix_affinity" if radix else None)


def mixed_requests(cfg, specs, seed: int = 5):
    """Requests with per-request (ctx, out) shapes, re-id'd in order —
    the heterogeneous trace the closed-loop fixtures decode."""
    reqs = []
    for i, (ctx, out) in enumerate(specs):
        r = sharegpt_trace(1, context_len=ctx, output_len=out,
                          seed=seed + i, ctx_jitter=0.0,
                          vocab=cfg.vocab)[0]
        r.request_id = i
        reqs.append(r)
    return reqs


# the closed-loop saturation trace (ISSUE 4 acceptance): slot 0 churns
# its top-k every HEAVY_PERIOD steps (heavy link demand, few pool bytes)
# and speculates junk-first (bad precision); the other slots drift
# slowly and speculate signal only.  Requests are shaped so a
# pressure-blind placer parks the late request on the heavy slot's
# device while a pressure-aware placer sees the live demand imbalance
# and routes it away.  CLOSED_FRAC puts the reduced model's entry
# budget between the floor and the full width so grants actually bind.
HEAVY_PERIOD = 2
CLOSED_FRAC = 800.0
CLOSED_SPECS = [(40, 80),    # r0: few bytes, heavy churn, decodes long
                (70, 80),    # r1: many bytes, light churn, decodes long
                (40, 8),     # r2: finishes early, freeing its slot
                (40, 20),    # r3: round-robin sends it to the idle link
                (40, 40)]    # r4: placed mid-trace — the decision probed


def build_closed_loop_engine(*, placement=None, precision_weighted=False,
                             seed: int = 0) -> Engine:
    """Saturation engine for the closed-loop comparison: heterogeneous
    per-slot drift + mixed speculation quality, arbiter always on."""
    periods = [HEAVY_PERIOD, T, T]
    tk = lane_drift_topk(periods)
    sac = dict(prefetch_width=SAT_WIDTH, overlap_frac=0.2,
               warmup_entries=0, warmup_radix=0, min_prefetch_width=4,
               link_budget_frac=CLOSED_FRAC,
               precision_weighted=precision_weighted)
    return build_engine(SAT_BUF, prefetch=True, slots=3,
                       prefetch_fn=mixed_junk_prefetch(SAT_WIDTH, {0},
                                                       topk_fn=tk),
                       sac_overrides=sac, arbiter=True,
                       placement=placement, topk_fn=tk, seed=seed)


def run_to_completion(eng: Engine, reqs, *, max_steps: int = 300,
                      on_step=None) -> int:
    """Submit ``reqs`` and step until drained; ``on_step(eng)`` runs
    after every step (per-step issued/exposed deltas for replays)."""
    for r in reqs:
        eng.submit(r)
    steps = 0
    while any(eng.slot_req) or eng.queue or eng._prefill_inflight():
        eng.step()
        steps += 1
        if on_step is not None:
            on_step(eng)
        assert steps < max_steps, "drift trace failed to drain"
    return steps


# ---------------------------------------------------------------------------
# the parity report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParityReport:
    """Engine-measured vs simulator-analytic numbers on one trace."""

    buf: int
    steps: int
    # hit rate (cold warm-up window excluded, as in PR 1's parity test)
    measured_hit: float
    modeled_hit: float
    # issued/exposed fabric seconds
    issued_s: float
    analytic_issued_s: float
    measured_exposed_s: float
    predicted_exposed_s: float
    # prefetch precision (0 when speculation is off)
    measured_precision: float
    modeled_precision: float


def drift_parity(buf: int, *, prefetch: bool = False, arch="qwen2-1.5b",
                 warmup_steps: int = 5) -> ParityReport:
    """Run the drift trace through the real engine and evaluate the
    simulator's analytic twins on the same parameters."""
    eng = build_engine(buf, arch=arch, prefetch=prefetch, overlap=True)
    assert eng.overlap_on
    pipeline = eng.pipeline                  # == simulate()'s PipelineModel
    assert isinstance(pipeline, PipelineModel)
    reqs = drift_requests(eng.cfg)
    t_comp = eng.step_compute_s(1)

    marks = {"steps": 0, "predicted": 0.0, "warm": (0, 0),
             "issued0": None, "exposed0": None, "last_issued": 0.0}

    def on_step(e):
        marks["steps"] += 1
        if marks["steps"] == 1:
            # cold first step (prefill + full-miss burst) starts the
            # replay window
            marks["issued0"] = e.stats.issued_fabric_s
            marks["exposed0"] = e.stats.exposed_fabric_s
        else:
            marks["predicted"] += pipeline.exposed_time(
                e.stats.issued_fabric_s - marks["last_issued"], t_comp)
        if marks["steps"] == warmup_steps:
            marks["warm"] = (e.stats.buffer_hits, e.stats.buffer_misses)
        marks["last_issued"] = e.stats.issued_fabric_s

    steps = run_to_completion(eng, reqs, on_step=on_step)

    h = eng.stats.buffer_hits - marks["warm"][0]
    m = eng.stats.buffer_misses - marks["warm"][1]
    measured_hit = h / max(h + m, 1)
    base = hit_rate(buf, K, CTX)
    width = eng.cfg.sac.prefetch_width if prefetch else 0
    modeled_hit, spec_issued = analytic_prefetch(base, width, K)
    modeled_prec = ((modeled_hit - base) * K / spec_issued
                    if spec_issued else 0.0)

    issued = eng.stats.issued_fabric_s - marks["issued0"]
    measured_exposed = eng.stats.exposed_fabric_s - marks["exposed0"]
    fabric = FABRICS["cxl"]
    per_step_entries = ((1 - modeled_hit) * K + spec_issued) \
        * eng.model.n_kv
    analytic_issued = steps * fabric.sparse_fetch_time(
        per_step_entries, eng.sac.entry_bytes)
    return ParityReport(
        buf=buf, steps=steps,
        measured_hit=measured_hit, modeled_hit=modeled_hit,
        issued_s=issued, analytic_issued_s=analytic_issued,
        measured_exposed_s=measured_exposed,
        predicted_exposed_s=marks["predicted"],
        measured_precision=eng.stats.prefetch_precision,
        modeled_precision=modeled_prec)


def assert_parity(rep: ParityReport, *, hit_tol: float = 0.08,
                  exposed_rel: float = 1e-6,
                  issued_band=(0.2, 5.0), precision_band=(0.25, 4.0)):
    """The acceptance bounds shared by every parity consumer:

    - hit rate: |measured - modeled| < hit_tol (PR 1's bound);
    - exposed seconds: the engine's queues must agree with a replay of
      the analytic PipelineModel split to float precision;
    - issued seconds: the analytic hit/speculation model brackets the
      measured total within a loose factor;
    - prefetch precision: same loose-factor bracket (0 ≡ 0 when off).
    """
    assert abs(rep.measured_hit - rep.modeled_hit) < hit_tol, rep
    assert 0.0 <= rep.measured_exposed_s <= rep.issued_s + 1e-12, rep
    np.testing.assert_allclose(rep.measured_exposed_s,
                               rep.predicted_exposed_s,
                               rtol=exposed_rel, atol=1e-12)
    lo, hi = issued_band
    assert lo * rep.analytic_issued_s < rep.issued_s \
        < hi * rep.analytic_issued_s, rep
    if rep.modeled_precision or rep.measured_precision:
        plo, phi = precision_band
        assert plo * rep.modeled_precision <= rep.measured_precision \
            <= phi * rep.modeled_precision, rep
