"""Training substrate: schedules, grad-accum equivalence, loss descent,
checkpoint fault tolerance (atomicity, corruption recovery, resume)."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models.model import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import batch_iterator, synthetic_batch
from repro.training.optimizer import (OptConfig, adamw_update,
                                      init_opt_state, schedule_lr)
from repro.training.train_loop import cross_entropy, make_train_step


def test_wsd_schedule_shape():
    cfg = OptConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                    total_steps=100, stable_frac=0.8, min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6          # warmup done
    assert abs(lrs[50] - 1.0) < 1e-6          # stable plateau
    assert lrs[95] < 0.7                      # decaying
    assert abs(lrs[100] - 0.1) < 0.05         # floor


def test_cosine_schedule_monotone_decay():
    cfg = OptConfig(lr=1.0, schedule="cosine", warmup_steps=5,
                    total_steps=50)
    lrs = [float(schedule_lr(cfg, jnp.int32(s))) for s in range(5, 51)]
    assert all(a >= b - 1e-9 for a, b in zip(lrs, lrs[1:]))


def test_grad_accum_equivalence(rng):
    """grad_accum=2 must equal a single big batch step (same data)."""
    cfg = get_config("minicpm-2b").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_batch(cfg, 8, 16, seed=1).items()}
    ocfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                     schedule="const")
    s1 = make_train_step(model, ocfg, grad_accum=1)
    s2 = make_train_step(model, ocfg, grad_accum=2)
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p2, _, m2 = s2(params, init_opt_state(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    diffs = [float(jnp.abs(a.astype(jnp.float32)
                           - b.astype(jnp.float32)).max())
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    assert max(diffs) < 5e-2  # bf16 params; update sign/step identical


def test_loss_decreases_200_steps(rng):
    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    opt = init_opt_state(params)
    ocfg = OptConfig(lr=2e-3, warmup_steps=10, total_steps=200,
                     schedule="wsd")
    step = jax.jit(make_train_step(model, ocfg, 1), donate_argnums=(0, 1))
    it = batch_iterator(cfg, ShapeConfig("t", 32, 16, "train"))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, losses[-5:]


def test_cross_entropy_matches_manual():
    logits = jnp.array([[[2.0, 0.0, -1.0], [0.0, 3.0, 0.0]]], jnp.float32)
    labels = jnp.array([[0, 1]], jnp.int32)
    got = float(cross_entropy(logits, labels))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    p1 = np.exp(3.0) / (np.exp(3.0) + 2)
    expect = -(np.log(p0) + np.log(p1)) / 2
    assert abs(got - expect) < 1e-5


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0)}
    cfg = OptConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=1,
                    schedule="const", weight_decay=0.0)
    p2, st, stats = adamw_update(params, grads, init_opt_state(params), cfg)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)
    assert np.all(np.abs(np.asarray(p2["w"])) < 1.5)


# ---- checkpoint fault tolerance ----

def test_checkpoint_atomic_and_corruption_recovery(tmp_path, rng):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(d, 1, tree, extras={"data_step": 1})
    tree2 = jax.tree.map(lambda x: x + 1, tree)
    ckpt.save(d, 2, tree2, extras={"data_step": 2})
    # corrupt the newest snapshot (torn write simulation)
    path2 = os.path.join(d, "step_000000002")
    with open(os.path.join(path2, "arr_00000.npy"), "wb") as f:
        f.write(b"garbage")
    restored, step, extras = ckpt.restore(d, tree)
    assert step == 1 and extras["data_step"] == 1  # fell back to consistent
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_prune_keeps_newest(tmp_path):
    d = str(tmp_path)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree)
    ckpt.prune(d, keep=2)
    assert ckpt.latest_step(d) == 5
    _, s, _ = ckpt.restore(d, tree)
    assert s == 5
    assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2


def test_train_resume_bitexact(tmp_path, rng):
    """Train 6 steps straight VS train 3 + checkpoint + restore + 3:
    identical params (restart-safe data cursor + state)."""
    cfg = get_config("granite-34b").reduced()
    model = build_model(cfg)
    ocfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                     schedule="const")
    step = jax.jit(make_train_step(model, ocfg, 1))
    it = lambda start: batch_iterator(cfg, ShapeConfig("t", 16, 4, "train"),
                                      start_step=start)

    p, o = model.init(rng), init_opt_state(model.init(rng))
    gen = it(0)
    for _ in range(6):
        b = {k: jnp.asarray(v) for k, v in next(gen).items()}
        p, o, _ = step(p, o, b)

    p2, o2 = model.init(rng), init_opt_state(model.init(rng))
    gen = it(0)
    for i in range(3):
        b = {k: jnp.asarray(v) for k, v in next(gen).items()}
        p2, o2, _ = step(p2, o2, b)
    ckpt.save(str(tmp_path), 3, {"p": p2, "o": o2}, extras={"data_step": 3})
    (restored, s, extras) = ckpt.restore(str(tmp_path), {"p": p2, "o": o2})
    p3, o3 = restored["p"], restored["o"]
    gen = it(extras["data_step"])
    for _ in range(3):
        b = {k: jnp.asarray(v) for k, v in next(gen).items()}
        p3, o3, _ = step(p3, o3, b)
    for a, b_ in zip(jax.tree.leaves(p), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
