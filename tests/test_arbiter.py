"""Fabric budget arbiter (serving/arbiter.py): grant properties, score-
threshold speculation, per-layer sizing, and the saturation trace.

Acceptance properties (ISSUE 3):
  - granted budgets are non-negative, never exceed ``prefetch_width``,
    and (with no floor) their per-device sum respects the link budget;
  - decoded tokens are bit-identical with the arbiter on vs off
    (arbitration shapes speculation traffic, never demand reads);
  - on a saturation trace (wide speculation whose tail is junk, tiny
    hide window) the arbiter strictly lowers exposed fabric seconds
    with no lower buffer hit rate than unarbitrated prefetch.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

import pytest

from hypothesis_compat import given, settings, st
from parity import (CLOSED_SPECS, K, SAT_BUF, SAT_SAC, SAT_WIDTH, T,
                    build_closed_loop_engine, build_engine,
                    build_saturation_engine, drift_requests,
                    junk_prefetch, lane_drift_topk, mixed_junk_prefetch,
                    mixed_requests, run_to_completion)

from repro.configs import get_config
from repro.core.transfer import PipelineModel
from repro.models import dsa
from repro.serving.arbiter import ArbiterConfig, BudgetArbiter, LayerSizer
from repro.serving.engine import Engine
from repro.serving.request import sharegpt_trace


def _arbiter(max_width=64, min_width=0, frac=1.0, entry_s=1e-6,
             n_layers=4, overlap=0.85, depth=2):
    return BudgetArbiter(
        ArbiterConfig(max_width=max_width, min_width=min_width,
                      link_budget_frac=frac),
        entry_s=entry_s, n_layers=n_layers,
        pipeline=PipelineModel(depth=depth, overlap_frac=overlap))


# ---------------------------------------------------------------------------
# grant unit semantics
# ---------------------------------------------------------------------------


def test_grant_idle_links_get_full_width():
    arb = _arbiter(max_width=64, entry_s=1e-9)   # entries are ~free
    grants = arb.grant(1e-3, [0.0, 0.0], {0: ["a", "b"], 1: ["c"]})
    assert grants == {"a": 64, "b": 64, "c": 64}


def test_grant_saturated_links_fall_to_floor():
    arb = _arbiter(max_width=64, min_width=8)
    # demand already exceeds the whole link budget on device 0 only
    grants = arb.grant(1e-3, [1.0, 0.0], {0: ["a", "b"], 1: ["c"]})
    assert grants["a"] == grants["b"] == 8     # saturated -> floor
    assert grants["c"] > 8                     # idle link keeps headroom


def test_grant_splits_headroom_across_requests():
    arb = _arbiter(max_width=1000, entry_s=1e-6, n_layers=1,
                   overlap=1.0, depth=2, frac=1.0)
    # hide window = compute = 1e-3 s -> 1000 entries of headroom
    one = arb.grant(1e-3, [0.0], {0: ["a"]})
    four = arb.grant(1e-3, [0.0], {0: list("abcd")})
    assert one["a"] == 1000
    assert all(w == 250 for w in four.values())


def test_grant_spends_remainder_largest_share_first():
    """ISSUE 4 bugfix: PR 3 floor-divided the device budget and silently
    dropped up to n_rids*n_layers - 1 entries of headroom; the remainder
    is now distributed one width unit at a time, largest share first."""
    # hide window = 1e-3 s, entry_s 1e-4 -> 10 entries, 1 layer: 10 width
    # units over 3 requests must come out (4, 3, 3), not (3, 3, 3)
    arb = _arbiter(max_width=100, min_width=0, entry_s=1e-4, n_layers=1,
                   overlap=1.0, depth=2, frac=1.0)
    grants = arb.grant(1e-3, [0.0], {0: ["a", "b", "c"]})
    assert sorted(grants.values(), reverse=True) == [4, 3, 3]
    assert sum(grants.values()) == 10          # the full budget is spent


def test_grant_precision_weighted_shifts_width():
    """With precision weighting on, a device's width budget tilts toward
    the precise speculator; without the flag precision input is ignored."""
    prec = {"good": 0.9, "bad": 0.0}
    uni = _arbiter(max_width=100, entry_s=1e-4, n_layers=1, overlap=1.0)
    g_uni = uni.grant(1e-3, [0.0], {0: ["good", "bad"]}, precision=prec)
    assert g_uni["good"] == g_uni["bad"] == 5
    warb = BudgetArbiter(
        ArbiterConfig(max_width=100, precision_weighted=True),
        entry_s=1e-4, n_layers=1,
        pipeline=PipelineModel(depth=2, overlap_frac=1.0))
    g_w = warb.grant(1e-3, [0.0], {0: ["good", "bad"]}, precision=prec)
    assert g_w["good"] > g_w["bad"]
    assert g_w["good"] + g_w["bad"] <= 10      # budget still respected


def test_grant_raises_on_out_of_range_device():
    """ISSUE 4 bugfix: ``dev % len(demand_s)`` silently charged the
    wrong link's budget; the arbiter now raises on a bad device id."""
    arb = _arbiter()
    with pytest.raises(ValueError):
        arb.grant(1e-3, [0.0, 0.0], {2: ["a"]})
    with pytest.raises(ValueError):
        arb.grant(1e-3, [0.0], {-1: ["a"]})
    # empty demand (no accounting yet) still grants optimistically
    assert arb.grant(1e-3, [], {3: ["a"]})["a"] == 64


def test_grant_warmup_caps_by_headroom():
    """Warm-up bursts draw from the same link budget: ample headroom
    passes the plan through, a saturated link cuts it to the floor."""
    arb = _arbiter(max_width=64, min_width=4, entry_s=1e-4, n_layers=1,
                   overlap=1.0)
    assert arb.grant_warmup(1e-3, [0.0], 0, 8) == 8      # 10 fit, 8 asked
    assert arb.grant_warmup(1e-3, [0.0], 0, 100) == 10   # capped at fit
    assert arb.grant_warmup(1e-3, [10.0], 0, 100) == 4   # saturated: floor
    assert arb.grant_warmup(1e-3, [10.0], 0, 2) == 2     # floor <= width
    assert arb.grant_warmup(1e-3, [0.0], 0, 0) == 0
    with pytest.raises(ValueError):
        arb.grant_warmup(1e-3, [0.0], 5, 8)


@settings(max_examples=80, deadline=None)
@given(st.data())
def test_property_grants_bounded_and_respect_link_budget(data):
    """Non-negative, <= max_width, >= floor; and with no floor the
    per-device spend never exceeds the positive headroom."""
    max_w = data.draw(st.integers(1, 512))
    min_w = data.draw(st.integers(0, 64))
    n_layers = data.draw(st.integers(1, 8))
    entry_s = data.draw(st.floats(1e-9, 1e-4))
    frac = data.draw(st.floats(0.0, 4.0))
    overlap = data.draw(st.floats(0.0, 1.0))
    compute_s = data.draw(st.floats(0.0, 1.0))
    n_dev = data.draw(st.integers(1, 4))
    demand = [data.draw(st.floats(0.0, 2.0)) for _ in range(n_dev)]
    device_requests = {
        d: [f"r{d}_{i}" for i in range(data.draw(st.integers(0, 6)))]
        for d in range(n_dev)}
    arb = _arbiter(max_width=max_w, min_width=min_w, frac=frac,
                   entry_s=entry_s, n_layers=n_layers,
                   overlap=overlap)
    grants = arb.grant(compute_s, demand, device_requests)
    assert set(grants) == {r for rs in device_requests.values() for r in rs}
    floor = min(min_w, max_w)
    for w in grants.values():
        assert isinstance(w, int)
        assert floor <= w <= max_w
    if min_w == 0:
        for d, rids in device_requests.items():
            if not rids:
                continue
            spend = sum(grants[r] for r in rids) * n_layers * arb.entry_s
            headroom = max(arb.link_budget_s(compute_s) - demand[d], 0.0)
            assert spend <= headroom + 1e-9, (spend, headroom)
            # no remainder dropped: the whole width budget is spent
            # (up to the per-request caps)
            total_w = int(arb.device_entry_budget(compute_s, demand[d])
                          // n_layers)
            assert sum(grants[r] for r in rids) \
                == min(total_w, len(rids) * max_w)


# ---------------------------------------------------------------------------
# score-threshold speculation (dsa.py)
# ---------------------------------------------------------------------------


def test_score_threshold_cuts_tail_below_margin():
    """A steep drop after the k-th score stops speculation early; a flat
    landscape keeps the rank window; the demand half never changes."""
    k, w = 4, 4
    steep = jnp.array([[9., 8., 7., 6., 1., .9, .8, .7]])
    plateau = jnp.array([[9., 8., 7., 6., 6., 6., 6., 6.]])
    cache_len = jnp.array([8], jnp.int32)
    for scores in (steep, plateau):
        d_rank, v_rank, _, tv_rank = dsa.topk_select_with_tail(
            scores, cache_len, k, w, -1.0)
        d_thr, v_thr, _, tv_thr = dsa.topk_select_with_tail(
            scores, cache_len, k, w, 1.0)
        np.testing.assert_array_equal(np.asarray(d_rank),
                                      np.asarray(d_thr))
        np.testing.assert_array_equal(np.asarray(v_rank),
                                      np.asarray(v_thr))
        assert bool(tv_rank.all())             # rank window: full tail
    # steep: s_k=6, margin*(s_max-s_k)=3 -> threshold 3 cuts the 1.0 tail
    _, _, _, tv = dsa.topk_select_with_tail(steep, cache_len, k, w, 1.0)
    assert int(tv.sum()) == 0
    # plateau at s_k: every tail score is within the margin
    _, _, _, tv = dsa.topk_select_with_tail(plateau, cache_len, k, w, 1.0)
    assert int(tv.sum()) == w
    # evenly-spaced scores: the threshold sits (k-1) steps below s_k, so
    # exactly k-1 of the tail lanes qualify regardless of the step size
    even = jnp.array([[9., 8.9, 8.8, 8.7, 8.6, 8.5, 8.4, 8.3]])
    _, _, _, tv = dsa.topk_select_with_tail(even, cache_len, k, w, 1.0)
    assert int(tv.sum()) == k - 1
    # standalone variant agrees with the fused tail
    idx_s, tv_s = dsa.speculate_next_topk(steep, cache_len, k, w, 1.0)
    assert int(tv_s.sum()) == 0


def test_budget_mask_caps_best_first():
    valid = jnp.ones((2, 6), bool)
    budget = jnp.array([2, 6], jnp.int32)
    out = np.asarray(dsa.budget_mask(valid, budget))
    assert out[0].tolist() == [True, True, False, False, False, False]
    assert out[1].all()


# ---------------------------------------------------------------------------
# LayerSizer
# ---------------------------------------------------------------------------


def test_layer_sizer_uniform_without_windows():
    sizer = LayerSizer(4, 4 * 32, topk=16)
    assert sizer.sizes() == [32, 32, 32, 32]


def test_layer_sizer_caps_windowed_layers():
    # windowed layer can never select more than 8 distinct positions
    sizer = LayerSizer(2, 64, layer_windows=[8, 0], topk=16)
    sizes = sizer.sizes()
    assert sum(sizes) == 64
    assert sizes[0] <= 8
    assert sizes[1] == 64 - sizes[0]


def test_layer_sizer_follows_measured_miss_rates():
    sizer = LayerSizer(3, 300, topk=16)
    sizes = sizer.sizes(miss_rates=[0.6, 0.3, 0.1])
    assert sum(sizes) == 300
    assert sizes[0] > sizes[1] > sizes[2] >= 1


def test_layer_sizer_sum_invariant_when_all_capped():
    # caps sum below the budget: the surplus still lands somewhere so
    # the total stays the comparability contract
    sizer = LayerSizer(2, 64, layer_windows=[4, 4], topk=16)
    assert sum(sizer.sizes()) == 64


def test_layer_sizer_surplus_rotates_by_weight():
    """ISSUE 4 bugfix: the all-capped surplus used to round-robin from
    layer 0 every call, biasing early layers regardless of pressure; it
    now rotates in descending weight order, so the heaviest-missing
    layer collects the odd unit."""
    sizer = LayerSizer(2, 13, layer_windows=[4, 4], topk=16)
    # caps [4, 4] hold 8; surplus 5 spreads 3:2 toward the heavy layer
    assert sizer.sizes(miss_rates=[0.1, 0.9]) == [6, 7]
    assert sizer.sizes(miss_rates=[0.9, 0.1]) == [7, 6]
    assert sum(sizer.sizes(miss_rates=[0.5, 0.5])) == 13


def test_layer_sizer_max_slots_is_a_hard_cap():
    """``max_slots`` (the static allocation width) survives even the
    past-window-caps surplus spread; the sum invariant still holds."""
    sizer = LayerSizer(4, 4 * 16, layer_windows=[4, 4, 4, 4], topk=16,
                       max_slots=32)
    sizes = sizer.sizes()
    assert sum(sizes) == 64 and max(sizes) <= 32
    sizes = sizer.sizes(miss_rates=[1.0, 0.0, 0.0, 0.0])
    assert sum(sizes) == 64 and max(sizes) <= 32
    with pytest.raises(AssertionError):
        LayerSizer(2, 64, max_slots=16)        # infeasible: 64 > 2*16


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_property_layer_sizer_sums_and_bounds(data):
    n = data.draw(st.integers(1, 12))
    per = data.draw(st.integers(1, 128))
    wins = [data.draw(st.sampled_from([0, 0, 4, 16, 64]))
            for _ in range(n)]
    topk = data.draw(st.integers(1, 64))
    max_slots = data.draw(st.sampled_from([None, per, 2 * per]))
    sizer = LayerSizer(n, n * per, layer_windows=wins, topk=topk,
                       max_slots=max_slots)
    rates = None
    if data.draw(st.booleans()):
        rates = [data.draw(st.floats(0.0, 1.0)) for _ in range(n)]
    sizes = sizer.sizes(rates)
    assert len(sizes) == n
    assert sum(sizes) == n * per
    assert all(s >= 1 for s in sizes)
    if max_slots is not None:
        assert all(s <= max_slots for s in sizes)


# ---------------------------------------------------------------------------
# engine: bit-identity + the saturation trace
# ---------------------------------------------------------------------------

def test_saturation_trace_arbiter_drops_exposed_not_hit_rate():
    """Acceptance: wide junk speculation over a tiny hide window — the
    arbiter cuts exactly the useless tail: exposed fabric seconds drop
    strictly, hit rate does not (the useful K lanes survive the floor),
    and speculation precision improves."""
    runs = {}
    for arb in (False, True):
        eng = build_saturation_engine(arbiter=arb)
        run_to_completion(eng, drift_requests(eng.cfg))
        runs[arb] = eng
    off, on = runs[False], runs[True]
    assert on.stats.exposed_fabric_s < off.stats.exposed_fabric_s
    assert on.stats.issued_fabric_s < off.stats.issued_fabric_s
    assert on.stats.hit_rate >= off.stats.hit_rate - 1e-9
    assert on.stats.prefetched_entries < off.stats.prefetched_entries
    assert on.stats.prefetch_precision > off.stats.prefetch_precision
    # grants on the saturated link sat at the floor
    assert on.last_grants and all(w == K for w in on.last_grants.values())


def test_tokens_bit_identical_arbiter_on_off():
    """Arbitration changes traffic/timing, never decoded tokens."""
    streams = {}
    for arb in (False, True):
        eng = build_saturation_engine(arbiter=arb)
        for r in drift_requests(eng.cfg, out=25):
            eng.submit(r)
        for _ in range(12):
            eng.step()
        streams[arb] = [t[:] for t in eng.slot_tokens]
    assert streams[False] == streams[True]


@settings(max_examples=3, deadline=None)
@given(st.data())
def test_property_arbiter_bit_identity_random_configs(data):
    """Random (arch, seed, trace) draws through the REAL scoring path
    (no hooks): greedy token streams match arbiter-on vs arbiter-off
    exactly, under random budget knobs."""
    arch = data.draw(st.sampled_from(["qwen2-1.5b", "gemma3-12b"]))
    seed = data.draw(st.integers(0, 5))
    tseed = data.draw(st.integers(0, 5))
    frac = data.draw(st.sampled_from([0.0, 1.0, 1e4]))
    min_w = data.draw(st.sampled_from([0, 2]))
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, sac=dataclasses.replace(cfg.sac, link_budget_frac=frac,
                                     min_prefetch_width=min_w))
    streams = []
    for arb in (False, True):
        eng = Engine(cfg, slots=1, max_ctx=64, seed=seed, prefetch=True,
                     arbiter=arb)
        for r in sharegpt_trace(1, context_len=24, output_len=20,
                                seed=tseed, ctx_jitter=0.0,
                                vocab=cfg.vocab):
            eng.submit(r)
        for _ in range(6):
            eng.step()
        streams.append([t[:] for t in eng.slot_tokens])
    assert streams[0] == streams[1]


# ---------------------------------------------------------------------------
# the closed loop (ISSUE 4): placement, precision weighting, warm-up
# ---------------------------------------------------------------------------


def test_closed_loop_beats_pr3_uniform_grants_on_saturation_trace():
    """ISSUE 4 acceptance: on the heterogeneous saturation trace,
    pressure-aware placement + precision-weighted grants reduce exposed
    fabric seconds vs PR 3's pressure-blind placement + uniform grants,
    at no loss of buffer hit rate."""
    runs = {}
    for closed in (False, True):
        eng = build_closed_loop_engine(
            placement="pressure_aware" if closed else None,
            precision_weighted=closed)
        reqs = mixed_requests(eng.cfg, CLOSED_SPECS)
        run_to_completion(eng, reqs)
        runs[closed] = (eng, reqs)
    (pr3, pr3_reqs), (closed, closed_reqs) = runs[False], runs[True]
    assert closed.stats.exposed_fabric_s < pr3.stats.exposed_fabric_s
    assert closed.stats.hit_rate >= pr3.stats.hit_rate - 0.02
    # the late request was routed off the heavy-churn request's link
    heavy_dev = pr3_reqs[0].pool_device
    assert pr3_reqs[-1].pool_device == heavy_dev
    assert closed_reqs[-1].pool_device != heavy_dev


def test_precision_weighted_grants_starve_the_junk_speculator():
    """Two co-located requests, one speculating signal, one junk: the
    weighted split shifts width to the precise one — less issued junk,
    HIGHER hit rate (the good slot keeps its churn coverage), better
    precision.  Uniform grants split the same budget evenly and lose."""
    runs = {}
    for weighted in (False, True):
        tk = lane_drift_topk([2, T])
        sac = dict(prefetch_width=SAT_WIDTH, overlap_frac=0.2,
                   warmup_entries=0, warmup_radix=0, min_prefetch_width=0,
                   link_budget_frac=1600.0, precision_weighted=weighted)
        eng = build_engine(SAT_BUF, prefetch=True, slots=2,
                           prefetch_fn=mixed_junk_prefetch(
                               SAT_WIDTH, {0}, topk_fn=tk),
                           sac_overrides=sac, arbiter=True,
                           placement="first_fit", topk_fn=tk)
        run_to_completion(eng, mixed_requests(eng.cfg,
                                              [(40, 60), (40, 60)]))
        runs[weighted] = eng
    uni, wtd = runs[False], runs[True]
    assert wtd.stats.exposed_fabric_s < uni.stats.exposed_fabric_s
    assert wtd.stats.hit_rate > uni.stats.hit_rate
    assert wtd.stats.prefetch_precision > uni.stats.prefetch_precision
    # the junk slot's grant collapsed, the signal slot kept its width
    assert wtd.last_grants[0] < wtd.last_grants[1]
    assert uni.last_grants[0] in (uni.last_grants[1],
                                  uni.last_grants[1] + 1)


def test_warmup_bursts_draw_from_the_link_budget():
    """With the arbiter on and a zero link budget, prefill warm-up is
    cut to nothing (it rides the same budget as speculation); with an
    ample budget the full plan goes through — tokens identical either
    way (warm-up is pure traffic shaping)."""
    sac = dict(SAT_SAC, warmup_entries=8, warmup_radix=4,
               min_prefetch_width=0)
    pf = {}
    for frac in (0.0, 1e6):
        eng = build_engine(SAT_BUF, prefetch=True,
                           prefetch_fn=junk_prefetch(SAT_WIDTH),
                           sac_overrides=dict(sac, link_budget_frac=frac),
                           arbiter=True)
        for r in drift_requests(eng.cfg, out=6):
            eng.submit(r)
        eng.step()                      # fills the slot: warm-up happens
        pf[frac] = (eng.stats.prefetched_entries,
                    [t[:] for t in eng.slot_tokens])
    assert pf[0.0][0] < pf[1e6][0]      # zero budget cut the warm burst
    assert pf[0.0][1] == pf[1e6][1]     # decoded tokens unchanged


def test_tokens_bit_identical_closed_loop_on_off():
    """The whole closed loop — pressure-aware placement, precision
    weighting, online resizing, warm-up arbitration — changes traffic
    and timing, never decoded tokens."""
    streams = {}
    for closed in (False, True):
        cfg_over = dict(SAT_SAC, min_prefetch_width=4)
        if closed:
            cfg_over.update(precision_weighted=True, resize_interval=3)
        eng = build_engine(SAT_BUF, prefetch=True, slots=3,
                           prefetch_fn=junk_prefetch(SAT_WIDTH),
                           sac_overrides=cfg_over,
                           arbiter=closed or None,
                           placement="pressure_aware" if closed else None)
        for r in drift_requests(eng.cfg, n=3, out=25):
            eng.submit(r)
        for _ in range(12):
            eng.step()
        streams[closed] = [t[:] for t in eng.slot_tokens]
    assert streams[False] == streams[True]


def test_engine_grants_track_link_budget_knob():
    """A huge link budget grants the full width even while decoding; a
    zero budget grants the floor."""
    for frac, expect in ((1e6, SAT_WIDTH), (0.0, K)):
        sac = dict(SAT_SAC, link_budget_frac=frac,
                   min_prefetch_width=K)
        eng = build_engine(SAT_BUF, prefetch=True,
                           prefetch_fn=junk_prefetch(SAT_WIDTH),
                           sac_overrides=sac, arbiter=True)
        for r in drift_requests(eng.cfg, out=8):
            eng.submit(r)
        for _ in range(4):
            eng.step()
        assert eng.last_grants
        assert all(w == expect for w in eng.last_grants.values()), \
            (frac, eng.last_grants)


# ---------------------------------------------------------------------------
# DemandTracker: per-link + per-request step deltas (ISSUE 5)
# ---------------------------------------------------------------------------


def test_demand_tracker_observe_deltas_and_departure():
    from repro.core.traffic import TrafficStats
    from repro.serving.arbiter import DemandTracker

    s = TrafficStats(n_devices=2)
    tr = DemandTracker(2)
    s.device_issued_s = [1.0, 0.5]
    s.request_demand_s = {"a": 0.8, "b": 0.7}
    assert tr.observe(s, ["a", "b"]) == [1.0, 0.5]
    s.device_issued_s = [1.6, 0.5]
    s.request_demand_s = {"a": 1.4, "b": 0.7}
    assert tr.observe(s, ["a", "b"]) == pytest.approx([0.6, 0.0])
    # "a" (0.6 of device 0's step) departs: its share leaves the link
    assert tr.depart("a", 0) == pytest.approx(0.6)
    assert tr.last_demand_s[0] == pytest.approx(0.0)
    # unknown keys / repeated departures are no-ops
    assert tr.depart("a", 0) == 0.0
    assert tr.depart("zzz", 1) == 0.0


def test_demand_tracker_set_step_mode_and_clamps():
    from repro.serving.arbiter import DemandTracker

    tr = DemandTracker(2)
    tr.set_step([0.3, 0.1], {"r": 0.5})        # share > link total
    assert tr.depart("r", 0) == 0.5
    assert tr.last_demand_s[0] == 0.0          # clamped, never negative
    tr.set_step([0.3], None)                   # short feeds zero-pad
    assert tr.last_demand_s == [0.3, 0.0]
    assert tr.depart("r", 7) == 0.0            # out-of-range device


def test_demand_tracker_prefetch_excluded_via_device_demand():
    """The tracker consumes device_demand_s() (issued minus prefetch):
    a prefetch-heavy step must not inflate the demand signal."""
    from repro.core.traffic import FabricAccountant
    from repro.core.transfer import FABRICS
    from repro.serving.arbiter import DemandTracker

    acct = FabricAccountant(FABRICS["cxl"], n_devices=1)
    tr = DemandTracker(1)
    acct.sparse_fetch(4, 128, device=0, key="r")
    demand_only = acct.stats.device_demand_s()[0]
    acct.prefetch_fetch(64, 128, device=0)
    tr.observe(acct.stats, ["r"])
    assert tr.last_demand_s[0] == pytest.approx(demand_only)


# ---------------------------------------------------------------------------
# resize hysteresis (ISSUE 5 satellite / PR 4 follow-up)
# ---------------------------------------------------------------------------


def test_resize_hysteresis_skips_stable_intervals():
    """On a steady drift trace the per-interval miss rates barely move:
    with a large epsilon the sizer evaluates once and then skips every
    interval; with epsilon=0 it re-evaluates every interval (the PR 4
    behavior).  Decoded tokens are identical either way."""
    streams = {}
    for eps in (0.0, 0.5):
        eng = build_engine(40, sac_overrides=dict(resize_interval=4,
                                                  resize_epsilon=eps))
        for r in drift_requests(eng.cfg, out=40):
            eng.submit(r)
        for _ in range(40):
            eng.step()
        streams[eps] = [t[:] for t in eng.slot_tokens]
        intervals = eng.stats.steps // 4
        if eps:
            # first interval evaluates (no reference yet), the steady
            # rest are skipped
            assert eng.stats.resize_skips >= intervals - 2, \
                (eng.stats.resize_skips, intervals)
        else:
            assert eng.stats.resize_skips == 0
    assert streams[0.0] == streams[0.5]


def test_resize_hysteresis_fires_on_real_shift():
    """A genuine miss-rate shift larger than epsilon must still resize:
    hysteresis suppresses jitter, not adaptation."""
    from repro.serving.engine import Engine  # noqa: F401  (import parity)

    eng = build_engine(40, sac_overrides=dict(resize_interval=4,
                                              resize_epsilon=0.05))
    for r in drift_requests(eng.cfg, out=30):
        eng.submit(r)
    for _ in range(8):
        eng.step()
    # the loop is live: the first interval evaluated and set the
    # hysteresis reference (an evaluation that changes no sizes bumps
    # neither counter — the reference is what records it)
    assert eng._resize_rates_ref is not None
    # force a reference far from any measurable rate: the next interval
    # MUST evaluate (delta > epsilon) and overwrite it, not skip
    sentinel = [9.0] * len(eng._resize_rates_ref)
    eng._resize_rates_ref = list(sentinel)
    skips0 = eng.stats.resize_skips
    for _ in range(4):
        eng.step()
    assert eng.stats.resize_skips == skips0
    assert eng._resize_rates_ref != sentinel
