"""Fabric budget arbiter (serving/arbiter.py): grant properties, score-
threshold speculation, per-layer sizing, and the saturation trace.

Acceptance properties (ISSUE 3):
  - granted budgets are non-negative, never exceed ``prefetch_width``,
    and (with no floor) their per-device sum respects the link budget;
  - decoded tokens are bit-identical with the arbiter on vs off
    (arbitration shapes speculation traffic, never demand reads);
  - on a saturation trace (wide speculation whose tail is junk, tiny
    hide window) the arbiter strictly lowers exposed fabric seconds
    with no lower buffer hit rate than unarbitrated prefetch.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from hypothesis_compat import given, settings, st
from parity import (K, SAT_BUF, SAT_SAC, SAT_WIDTH, build_engine,
                    build_saturation_engine, drift_requests,
                    junk_prefetch, run_to_completion)

from repro.configs import get_config
from repro.core.transfer import PipelineModel
from repro.models import dsa
from repro.serving.arbiter import ArbiterConfig, BudgetArbiter, LayerSizer
from repro.serving.engine import Engine
from repro.serving.request import sharegpt_trace


def _arbiter(max_width=64, min_width=0, frac=1.0, entry_s=1e-6,
             n_layers=4, overlap=0.85, depth=2):
    return BudgetArbiter(
        ArbiterConfig(max_width=max_width, min_width=min_width,
                      link_budget_frac=frac),
        entry_s=entry_s, n_layers=n_layers,
        pipeline=PipelineModel(depth=depth, overlap_frac=overlap))


# ---------------------------------------------------------------------------
# grant unit semantics
# ---------------------------------------------------------------------------


def test_grant_idle_links_get_full_width():
    arb = _arbiter(max_width=64, entry_s=1e-9)   # entries are ~free
    grants = arb.grant(1e-3, [0.0, 0.0], {0: ["a", "b"], 1: ["c"]})
    assert grants == {"a": 64, "b": 64, "c": 64}


def test_grant_saturated_links_fall_to_floor():
    arb = _arbiter(max_width=64, min_width=8)
    # demand already exceeds the whole link budget on device 0 only
    grants = arb.grant(1e-3, [1.0, 0.0], {0: ["a", "b"], 1: ["c"]})
    assert grants["a"] == grants["b"] == 8     # saturated -> floor
    assert grants["c"] > 8                     # idle link keeps headroom


def test_grant_splits_headroom_across_requests():
    arb = _arbiter(max_width=1000, entry_s=1e-6, n_layers=1,
                   overlap=1.0, depth=2, frac=1.0)
    # hide window = compute = 1e-3 s -> 1000 entries of headroom
    one = arb.grant(1e-3, [0.0], {0: ["a"]})
    four = arb.grant(1e-3, [0.0], {0: list("abcd")})
    assert one["a"] == 1000
    assert all(w == 250 for w in four.values())


@settings(max_examples=80, deadline=None)
@given(st.data())
def test_property_grants_bounded_and_respect_link_budget(data):
    """Non-negative, <= max_width, >= floor; and with no floor the
    per-device spend never exceeds the positive headroom."""
    max_w = data.draw(st.integers(1, 512))
    min_w = data.draw(st.integers(0, 64))
    n_layers = data.draw(st.integers(1, 8))
    entry_s = data.draw(st.floats(1e-9, 1e-4))
    frac = data.draw(st.floats(0.0, 4.0))
    overlap = data.draw(st.floats(0.0, 1.0))
    compute_s = data.draw(st.floats(0.0, 1.0))
    n_dev = data.draw(st.integers(1, 4))
    demand = [data.draw(st.floats(0.0, 2.0)) for _ in range(n_dev)]
    device_requests = {
        d: [f"r{d}_{i}" for i in range(data.draw(st.integers(0, 6)))]
        for d in range(n_dev)}
    arb = _arbiter(max_width=max_w, min_width=min_w, frac=frac,
                   entry_s=entry_s, n_layers=n_layers,
                   overlap=overlap)
    grants = arb.grant(compute_s, demand, device_requests)
    assert set(grants) == {r for rs in device_requests.values() for r in rs}
    floor = min(min_w, max_w)
    for w in grants.values():
        assert isinstance(w, int)
        assert floor <= w <= max_w
    if min_w == 0:
        for d, rids in device_requests.items():
            if not rids:
                continue
            spend = sum(grants[r] for r in rids) * n_layers * arb.entry_s
            headroom = max(arb.link_budget_s(compute_s) - demand[d], 0.0)
            assert spend <= headroom + 1e-9, (spend, headroom)


# ---------------------------------------------------------------------------
# score-threshold speculation (dsa.py)
# ---------------------------------------------------------------------------


def test_score_threshold_cuts_tail_below_margin():
    """A steep drop after the k-th score stops speculation early; a flat
    landscape keeps the rank window; the demand half never changes."""
    k, w = 4, 4
    steep = jnp.array([[9., 8., 7., 6., 1., .9, .8, .7]])
    plateau = jnp.array([[9., 8., 7., 6., 6., 6., 6., 6.]])
    cache_len = jnp.array([8], jnp.int32)
    for scores in (steep, plateau):
        d_rank, v_rank, _, tv_rank = dsa.topk_select_with_tail(
            scores, cache_len, k, w, -1.0)
        d_thr, v_thr, _, tv_thr = dsa.topk_select_with_tail(
            scores, cache_len, k, w, 1.0)
        np.testing.assert_array_equal(np.asarray(d_rank),
                                      np.asarray(d_thr))
        np.testing.assert_array_equal(np.asarray(v_rank),
                                      np.asarray(v_thr))
        assert bool(tv_rank.all())             # rank window: full tail
    # steep: s_k=6, margin*(s_max-s_k)=3 -> threshold 3 cuts the 1.0 tail
    _, _, _, tv = dsa.topk_select_with_tail(steep, cache_len, k, w, 1.0)
    assert int(tv.sum()) == 0
    # plateau at s_k: every tail score is within the margin
    _, _, _, tv = dsa.topk_select_with_tail(plateau, cache_len, k, w, 1.0)
    assert int(tv.sum()) == w
    # evenly-spaced scores: the threshold sits (k-1) steps below s_k, so
    # exactly k-1 of the tail lanes qualify regardless of the step size
    even = jnp.array([[9., 8.9, 8.8, 8.7, 8.6, 8.5, 8.4, 8.3]])
    _, _, _, tv = dsa.topk_select_with_tail(even, cache_len, k, w, 1.0)
    assert int(tv.sum()) == k - 1
    # standalone variant agrees with the fused tail
    idx_s, tv_s = dsa.speculate_next_topk(steep, cache_len, k, w, 1.0)
    assert int(tv_s.sum()) == 0


def test_budget_mask_caps_best_first():
    valid = jnp.ones((2, 6), bool)
    budget = jnp.array([2, 6], jnp.int32)
    out = np.asarray(dsa.budget_mask(valid, budget))
    assert out[0].tolist() == [True, True, False, False, False, False]
    assert out[1].all()


# ---------------------------------------------------------------------------
# LayerSizer
# ---------------------------------------------------------------------------


def test_layer_sizer_uniform_without_windows():
    sizer = LayerSizer(4, 4 * 32, topk=16)
    assert sizer.sizes() == [32, 32, 32, 32]


def test_layer_sizer_caps_windowed_layers():
    # windowed layer can never select more than 8 distinct positions
    sizer = LayerSizer(2, 64, layer_windows=[8, 0], topk=16)
    sizes = sizer.sizes()
    assert sum(sizes) == 64
    assert sizes[0] <= 8
    assert sizes[1] == 64 - sizes[0]


def test_layer_sizer_follows_measured_miss_rates():
    sizer = LayerSizer(3, 300, topk=16)
    sizes = sizer.sizes(miss_rates=[0.6, 0.3, 0.1])
    assert sum(sizes) == 300
    assert sizes[0] > sizes[1] > sizes[2] >= 1


def test_layer_sizer_sum_invariant_when_all_capped():
    # caps sum below the budget: the surplus still lands somewhere so
    # the total stays the comparability contract
    sizer = LayerSizer(2, 64, layer_windows=[4, 4], topk=16)
    assert sum(sizer.sizes()) == 64


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_property_layer_sizer_sums_and_bounds(data):
    n = data.draw(st.integers(1, 12))
    per = data.draw(st.integers(1, 128))
    wins = [data.draw(st.sampled_from([0, 0, 4, 16, 64]))
            for _ in range(n)]
    topk = data.draw(st.integers(1, 64))
    sizer = LayerSizer(n, n * per, layer_windows=wins, topk=topk)
    rates = None
    if data.draw(st.booleans()):
        rates = [data.draw(st.floats(0.0, 1.0)) for _ in range(n)]
    sizes = sizer.sizes(rates)
    assert len(sizes) == n
    assert sum(sizes) == n * per
    assert all(s >= 1 for s in sizes)


# ---------------------------------------------------------------------------
# engine: bit-identity + the saturation trace
# ---------------------------------------------------------------------------

def test_saturation_trace_arbiter_drops_exposed_not_hit_rate():
    """Acceptance: wide junk speculation over a tiny hide window — the
    arbiter cuts exactly the useless tail: exposed fabric seconds drop
    strictly, hit rate does not (the useful K lanes survive the floor),
    and speculation precision improves."""
    runs = {}
    for arb in (False, True):
        eng = build_saturation_engine(arbiter=arb)
        run_to_completion(eng, drift_requests(eng.cfg))
        runs[arb] = eng
    off, on = runs[False], runs[True]
    assert on.stats.exposed_fabric_s < off.stats.exposed_fabric_s
    assert on.stats.issued_fabric_s < off.stats.issued_fabric_s
    assert on.stats.hit_rate >= off.stats.hit_rate - 1e-9
    assert on.stats.prefetched_entries < off.stats.prefetched_entries
    assert on.stats.prefetch_precision > off.stats.prefetch_precision
    # grants on the saturated link sat at the floor
    assert on.last_grants and all(w == K for w in on.last_grants.values())


def test_tokens_bit_identical_arbiter_on_off():
    """Arbitration changes traffic/timing, never decoded tokens."""
    streams = {}
    for arb in (False, True):
        eng = build_saturation_engine(arbiter=arb)
        for r in drift_requests(eng.cfg, out=25):
            eng.submit(r)
        for _ in range(12):
            eng.step()
        streams[arb] = [t[:] for t in eng.slot_tokens]
    assert streams[False] == streams[True]


@settings(max_examples=3, deadline=None)
@given(st.data())
def test_property_arbiter_bit_identity_random_configs(data):
    """Random (arch, seed, trace) draws through the REAL scoring path
    (no hooks): greedy token streams match arbiter-on vs arbiter-off
    exactly, under random budget knobs."""
    arch = data.draw(st.sampled_from(["qwen2-1.5b", "gemma3-12b"]))
    seed = data.draw(st.integers(0, 5))
    tseed = data.draw(st.integers(0, 5))
    frac = data.draw(st.sampled_from([0.0, 1.0, 1e4]))
    min_w = data.draw(st.sampled_from([0, 2]))
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, sac=dataclasses.replace(cfg.sac, link_budget_frac=frac,
                                     min_prefetch_width=min_w))
    streams = []
    for arb in (False, True):
        eng = Engine(cfg, slots=1, max_ctx=64, seed=seed, prefetch=True,
                     arbiter=arb)
        for r in sharegpt_trace(1, context_len=24, output_len=20,
                                seed=tseed, ctx_jitter=0.0,
                                vocab=cfg.vocab):
            eng.submit(r)
        for _ in range(6):
            eng.step()
        streams.append([t[:] for t in eng.slot_tokens])
    assert streams[0] == streams[1]


def test_engine_grants_track_link_budget_knob():
    """A huge link budget grants the full width even while decoding; a
    zero budget grants the floor."""
    for frac, expect in ((1e6, SAT_WIDTH), (0.0, K)):
        sac = dict(SAT_SAC, link_budget_frac=frac,
                   min_prefetch_width=K)
        eng = build_engine(SAT_BUF, prefetch=True,
                           prefetch_fn=junk_prefetch(SAT_WIDTH),
                           sac_overrides=sac, arbiter=True)
        for r in drift_requests(eng.cfg, out=8):
            eng.submit(r)
        for _ in range(4):
            eng.step()
        assert eng.last_grants
        assert all(w == expect for w in eng.last_grants.values()), \
            (frac, eng.last_grants)
