"""HiSparse hierarchical buffer: unit + hypothesis property tests.

Invariants (the HiSparse swap-in contract):
  I1. page_table/slot_pos are mutually consistent bijections;
  I2. after swap_in, every (deduped, fillable) requested position is
      resident;
  I3. read_through values equal pure pool values (the buffer never
      changes results — only traffic);
  I4. hits + misses == number of valid deduped lanes;
  I5. current-step hits are never evicted by the same step's misses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import hisparse


def _consistent(state):
    B, buf = state.slot_pos.shape
    S = state.page_table.shape[1]
    pt = np.asarray(state.page_table)
    sp = np.asarray(state.slot_pos)
    for b in range(B):
        for slot in range(buf):
            pos = sp[b, slot]
            if pos >= 0:
                assert pt[b, pos] == slot, (b, slot, pos)
        for pos in range(S):
            slot = pt[b, pos]
            if slot >= 0:
                assert sp[b, slot] == pos, (b, pos, slot)


def _pool(B, S, d, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, S, d),
                             jnp.bfloat16)


def test_swap_in_basic_residency():
    B, S, d, buf, k = 2, 32, 8, 8, 4
    state = hisparse.init_buffer(B, buf, S, d)
    pool = _pool(B, S, d)
    idx = jnp.array([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    fetched = jnp.take_along_axis(pool, idx[..., None], axis=1)
    valid = jnp.ones((B, k), bool)
    state, hits, misses = hisparse.swap_in(state, idx, fetched, valid)
    assert (np.asarray(hits) == 0).all()
    assert (np.asarray(misses) == k).all()
    _consistent(state)
    slots, hit = hisparse.lookup(state, idx)
    assert bool(hit.all())
    # second time: all hits
    state, hits, misses = hisparse.swap_in(state, idx, fetched, valid)
    assert (np.asarray(hits) == k).all() and (np.asarray(misses) == 0).all()


def test_lru_eviction_order():
    B, S, d, buf = 1, 64, 4, 4
    state = hisparse.init_buffer(B, buf, S, d)
    pool = _pool(B, S, d)

    def touch(state, positions):
        idx = jnp.array([positions], jnp.int32)
        fetched = jnp.take_along_axis(pool, idx[..., None], axis=1)
        return hisparse.swap_in(state, idx, fetched,
                                jnp.ones_like(idx, bool))[0]

    state = touch(state, [0, 1])     # clock 1
    state = touch(state, [2, 3])     # clock 2: buffer full {0,1,2,3}
    state = touch(state, [0, 1])     # clock 3: refresh 0,1
    state = touch(state, [10, 11])   # clock 4: must evict 2,3 (LRU)
    _, hit = hisparse.lookup(state, jnp.array([[0, 1, 10, 11]], jnp.int32))
    assert bool(hit.all())
    _, hit23 = hisparse.lookup(state, jnp.array([[2, 3]], jnp.int32))
    assert not bool(hit23.any())


def test_protected_hits_not_evicted():
    B, S, d, buf = 1, 64, 4, 4
    state = hisparse.init_buffer(B, buf, S, d)
    pool = _pool(B, S, d)
    idx0 = jnp.array([[0, 1, 2, 3]], jnp.int32)
    f0 = jnp.take_along_axis(pool, idx0[..., None], axis=1)
    state, _, _ = hisparse.swap_in(state, idx0, f0, jnp.ones_like(idx0, bool))
    # step: 2 hits (0,1 — LRU-oldest) + 2 misses -> must evict 2,3 not 0,1
    idx1 = jnp.array([[0, 1, 20, 21]], jnp.int32)
    f1 = jnp.take_along_axis(pool, idx1[..., None], axis=1)
    state, hits, misses = hisparse.swap_in(state, idx1, f1,
                                           jnp.ones_like(idx1, bool))
    assert int(hits[0]) == 2 and int(misses[0]) == 2
    _, hit = hisparse.lookup(state, idx1)
    assert bool(hit.all())
    _consistent(state)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_read_through_equals_pool(data):
    """I3/I4: buffered reads bit-equal pool reads; accounting exact."""
    B = data.draw(st.integers(1, 3))
    S = data.draw(st.sampled_from([16, 32]))
    buf = data.draw(st.sampled_from([4, 8, 16]))
    k = data.draw(st.sampled_from([2, 4, 8]))
    d = 4
    steps = data.draw(st.integers(1, 5))
    pool = _pool(B, S, d, seed=data.draw(st.integers(0, 99)))
    state = hisparse.init_buffer(B, buf, S, d)
    rng = np.random.default_rng(data.draw(st.integers(0, 99)))
    for _ in range(steps):
        idx = jnp.asarray(rng.integers(0, S, (B, k)), jnp.int32)
        valid = jnp.asarray(rng.random((B, k)) < 0.9)
        fetched = jnp.take_along_axis(pool, idx[..., None], axis=1)
        vals, state, hits, misses = hisparse.read_through(
            state, idx, fetched, valid)
        # values identical to the pool for valid lanes
        expect = jnp.take_along_axis(pool, idx[..., None], axis=1)
        v = np.asarray(valid)
        np.testing.assert_array_equal(
            np.asarray(vals, np.float32)[v], np.asarray(expect, np.float32)[v])
        _consistent(state)
        # I4: hits+misses == valid deduped lanes
        for b in range(B):
            seen = set()
            dedup = 0
            for j in range(k):
                if v[b, j] and int(idx[b, j]) not in seen:
                    seen.add(int(idx[b, j]))
                    dedup += 1
            dup_hits = sum(1 for j in range(k)
                           if v[b, j] and list(np.asarray(idx[b])).index(
                               int(idx[b, j])) != j)
            total = int(hits[b]) + int(misses[b])
            assert total >= dedup and total <= dedup + dup_hits + k


def test_hit_rate_grounding():
    """The simulator's hit model must be in the ballpark of the real
    buffer under a drifting top-k workload (grounds serving/simulator)."""
    from repro.serving.simulator import hit_rate as model_hit
    B, S, d = 1, 2048, 4
    k, buf = 64, 192  # k/buf = 1/3 like 2048/6144
    state = hisparse.init_buffer(B, buf, S, d)
    pool = _pool(B, S, d)
    rng = np.random.default_rng(0)
    # drifting top-k: mostly same set, a few swaps per step
    current = rng.choice(S, size=k, replace=False)
    hits = misses = 0
    for step in range(60):
        n_swap = rng.integers(0, max(2, k // 16))
        drop = rng.choice(k, size=n_swap, replace=False)
        newpos = rng.integers(0, S, n_swap)
        current[drop] = newpos
        idx = jnp.asarray(current[None, :], jnp.int32)
        fetched = jnp.take_along_axis(pool, idx[..., None], axis=1)
        _, state, h, m = hisparse.read_through(
            state, idx, fetched, jnp.ones((1, k), bool))
        if step >= 10:  # skip warmup
            hits += int(h[0]); misses += int(m[0])
    real = hits / (hits + misses)
    modeled = model_hit(buf, k, 32768)
    assert abs(real - modeled) < 0.12, (real, modeled)


# ---------------------------------------------------------------------------
# online re-sizing (ISSUE 4: hisparse.resize_layers)
# ---------------------------------------------------------------------------


def _layered_consistent(state):
    L, B = state.slot_pos.shape[:2]
    for layer in range(L):
        _consistent(hisparse.BufferState(*(t[layer] for t in state)))


def test_resize_layers_grow_shrink_preserves_residents():
    st = hisparse.init_layered_buffer(2, 1, [4, 2], 16, 3, buf_max=6)
    idx = jnp.array([[0, 1, 2], [3, 4, 5]], jnp.int32)
    vals = jnp.ones((2, 3, 3), jnp.bfloat16)
    st, ins = hisparse.warm_lane(st, 0, idx, vals, jnp.ones((2, 3), bool))
    assert int(ins) == 5                       # layer 1 capped at 2 slots
    st2 = hisparse.resize_layers(st, [2, 5])
    _layered_consistent(st2)
    sp = np.asarray(st2.slot_pos)[:, 0]
    pt = np.asarray(st2.page_table)[:, 0]
    # layer 0 shrank: slots 0-1 keep their positions, 2+ disabled and
    # their position unmapped
    assert sp[0].tolist() == [0, 1, -2, -2, -2, -2]
    assert pt[0][2] == -1
    # layer 1 grew: residents kept, new slots open EMPTY
    assert sp[1].tolist() == [3, 4, -1, -1, -1, -2]
    assert pt[1][3] == 0 and pt[1][4] == 1
    # entries in surviving slots are untouched
    np.testing.assert_array_equal(
        np.asarray(st2.entries[:, 0, :2], np.float32),
        np.asarray(st.entries[:, 0, :2], np.float32))


def test_resize_layers_roundtrip_restores_capacity_not_residency():
    st = hisparse.init_layered_buffer(1, 2, [4], 8, 2)
    idx = jnp.array([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    vals = jnp.ones((2, 4, 2), jnp.bfloat16)
    st, _, _ = hisparse.swap_in(
        hisparse.BufferState(*(t[0] for t in st)), idx, vals,
        jnp.ones((2, 4), bool))
    st = hisparse.BufferState(*(t[None] for t in st))
    shrunk = hisparse.resize_layers(st, [1])
    back = hisparse.resize_layers(shrunk, [4])
    _layered_consistent(back)
    sp = np.asarray(back.slot_pos)[0]
    # capacity restored, but the evicted residents are honestly gone
    assert (sp >= -1).all()
    assert (sp >= 0).sum() == 2                # one survivor per lane


def test_resize_layers_read_through_stays_bit_identical():
    """After an arbitrary resize, demand reads still return pool values
    exactly — displaced entries just miss (traffic, not tokens)."""
    B, S, d = 2, 12, 4
    st = hisparse.init_layered_buffer(1, B, [6], S, d)
    pool = _pool(B, S, d)
    rng = np.random.default_rng(3)
    flat = hisparse.BufferState(*(t[0] for t in st))
    for step in range(8):
        idx = jnp.asarray(rng.integers(0, S, (B, 4)), jnp.int32)
        fetched = jax.vmap(lambda p, i: p[i])(pool, idx)
        vals, flat, _, _ = hisparse.read_through(
            flat, idx, fetched, jnp.ones((B, 4), bool))
        np.testing.assert_array_equal(np.asarray(vals, np.float32),
                                      np.asarray(fetched, np.float32))
        if step == 3:
            layered = hisparse.BufferState(*(t[None] for t in flat))
            layered = hisparse.resize_layers(layered, [3])
            _layered_consistent(layered)
            flat = hisparse.BufferState(*(t[0] for t in layered))


def test_init_layered_buffer_buf_max_headroom():
    st = hisparse.init_layered_buffer(2, 1, [4, 2], 8, 3, buf_max=7)
    assert st.entries.shape[2] == 7
    sp = np.asarray(st.slot_pos)[:, 0]
    assert (sp[0] == -1).sum() == 4 and (sp[0] == -2).sum() == 3
    assert (sp[1] == -1).sum() == 2 and (sp[1] == -2).sum() == 5
    with pytest.raises(AssertionError):
        hisparse.init_layered_buffer(1, 1, [4], 8, 3, buf_max=2)
