"""Open-loop serving (PR 8): arrival-gated admission, chunked prefill,
disaggregated prefill, and the honest metrics they are judged on.

The correctness properties this suite guards:

  - **arrival gating**: no request is ever dispatched before its
    ``arrival_s`` — neither by the engine's ``_fill_slots`` (the
    pre-PR 8 open-loop bug: the queue was drained into freed slots
    regardless of arrival time, so "open-loop" traces were silently
    closed-loop and every TTFT was flattered) nor by a caller driving
    ``Scheduler.try_admit`` directly;
  - **bit identity**: chunked prefill and prefill/decode disaggregation
    change timing and traffic, never decoded tokens — the same trace
    produces identical ``out_tokens`` per request across chunk sizes
    {full, ctx/2, ctx/8} x disagg {off, on};
  - **engine <-> analytic-twin parity**: on a rolling-admission trace
    the real engine's per-request dispatch/first-token/finish timeline
    matches ``replay_engine_timeline`` to float precision in all three
    modes (monolithic / chunked / disagg);
  - **metric honesty**: ``summarize`` always returns the full
    ``SUMMARY_KEYS`` set (zeros on empty), arrival-anchored TTFT is
    never below dispatch-anchored, and the chunked-prefill win shows up
    where it actually lives — the worst single inter-token gap;
  - **workload generator**: ``diurnal_trace`` is deterministic per
    seed, arrivals are nondecreasing with genuine burst clumps, the
    heavy context tail respects its cap, and prefix reuse never
    crosses a tenant boundary.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import Engine
from repro.serving.request import (SUMMARY_KEYS, Request, diurnal_trace,
                                   sharegpt_trace, summarize)
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.simulator import (SimConfig, default_backends,
                                     profile_from_config,
                                     replay_engine_timeline, simulate)


def _reduced():
    return get_config("qwen2-1.5b").reduced()


def _parity_cfg():
    """Pin the analytic-replay regime: warm-up and prefetch traffic off
    (radix stays on — random prompts never match, so it is inert)."""
    cfg = _reduced()
    return dataclasses.replace(cfg, sac=dataclasses.replace(
        cfg.sac, warmup_entries=0, warmup_radix=0, prefetch_width=0))


# ---------------------------------------------------------------------------
# arrival gating (the bugfix)
# ---------------------------------------------------------------------------

def test_engine_never_dispatches_before_arrival():
    """Regression for the open-loop bug: a late-arriving request must
    not be dispatched into a freed slot before its arrival time, even
    when the engine is otherwise idle."""
    cfg = _reduced()
    reqs = sharegpt_trace(3, context_len=48, output_len=6, seed=1,
                          ctx_jitter=0.0, vocab=cfg.vocab)
    late = 1e6                       # long after the others finish
    reqs[2].arrival_s = late
    eng = Engine(cfg, slots=2, max_ctx=96)
    out = eng.run(reqs)
    assert out["n_done"] == 3
    for r in reqs:
        assert r.dispatch_s >= r.arrival_s - 1e-9, r
    # the engine idled (clock jump), it did not cheat
    assert reqs[2].dispatch_s >= late - 1e-9
    assert reqs[2].finish_s > late


@pytest.mark.parametrize("chunk,disagg", [(16, False), (0, True)])
def test_arrival_gate_holds_in_chunked_and_disagg_modes(chunk, disagg):
    cfg = _reduced()
    reqs = sharegpt_trace(4, context_len=48, output_len=5, seed=2,
                          arrival_rate=3.0, ctx_jitter=0.0,
                          vocab=cfg.vocab)
    eng = Engine(cfg, slots=2, max_ctx=96,
                 prefill_chunk_tokens=chunk, disagg=disagg)
    out = eng.run(reqs)
    assert out["n_done"] == 4
    for r in reqs:
        assert r.dispatch_s >= r.arrival_s - 1e-9, r


def test_scheduler_try_admit_gates_on_arrival():
    """A caller driving the scheduler directly must never see a
    dispatch before arrival (defensive twin of the engine gate)."""
    sched = Scheduler(SchedulerConfig(concurrency=4,
                                      bytes_per_token=1024.0))
    early = Request(0, 0.0, 64, 8)
    late = Request(1, 100.0, 64, 8)
    sched.submit(early)
    sched.submit(late)
    admitted = sched.try_admit(now_s=1.0)
    assert [r.request_id for r in admitted] == [0]
    assert not sched.try_admit(now_s=99.0)       # still in the future
    assert [r.request_id for r in sched.try_admit(now_s=100.0)] == [1]


# ---------------------------------------------------------------------------
# summarize / trace-generator satellites
# ---------------------------------------------------------------------------

def test_summarize_empty_returns_full_key_set():
    out = summarize([])
    assert set(out) == set(SUMMARY_KEYS)
    assert all(v == 0.0 for v in out.values())
    # unfinished-only input takes the same path
    out = summarize([Request(0, 0.0, 64, 8)])
    assert set(out) == set(SUMMARY_KEYS)
    assert out["n_done"] == 0.0


def test_sharegpt_trace_clamps_ctx_before_prompt():
    """The pre-PR 8 bug clamped ctx AFTER generating the prompt, so a
    tiny jittered context produced len(prompt) != context_len."""
    reqs = sharegpt_trace(16, context_len=16, output_len=4, seed=0,
                          ctx_jitter=0.9, vocab=100)
    for r in reqs:
        assert r.context_len >= 16
        assert len(r.prompt_tokens) == r.context_len


def test_diurnal_trace_deterministic_and_shaped():
    kw = dict(prefix_len=32, suffix_len=32, output_len=4, base_rate=5.0,
              seed=11, n_tenants=3, burst_p=0.25, burst_size=4,
              ctx_tail_alpha=2.0, max_ctx_mult=4.0, vocab=50)
    a = diurnal_trace(64, **kw)
    b = diurnal_trace(64, **kw)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert [r.context_len for r in a] == [r.context_len for r in b]
    assert [r.prefix_group for r in a] == [r.prefix_group for r in b]
    ts = [r.arrival_s for r in a]
    assert all(t1 <= t2 for t1, t2 in zip(ts, ts[1:]))      # nondecreasing
    # burst clumps land ~1e-4 s apart — far below the ~0.13 s mean gap
    gaps = np.diff(ts)
    assert (gaps < 1e-3).sum() >= 3, "no burst clumps generated"
    # heavy tail: suffix multiplier capped at max_ctx_mult
    assert all(64 <= r.context_len <= 32 + 32 * 4 for r in a)
    assert any(r.context_len > 64 for r in a)               # tail exists


def test_diurnal_trace_tenants_never_share_prefixes():
    reqs = diurnal_trace(48, prefix_len=24, suffix_len=8, output_len=2,
                         base_rate=10.0, seed=3, n_tenants=4,
                         reuse_p=0.8, vocab=64)
    by_group = {}
    for r in reqs:
        p = tuple(int(x) for x in r.prompt_tokens[:24])
        by_group.setdefault(r.prefix_group, set()).add(p)
    # same group -> byte-identical prefix; distinct groups -> distinct
    assert all(len(s) == 1 for s in by_group.values())
    prefixes = [next(iter(s)) for s in by_group.values()]
    assert len(set(prefixes)) == len(prefixes)
    assert len(by_group) > 1                 # reuse did not collapse all


# ---------------------------------------------------------------------------
# bit identity: chunking / disaggregation never change tokens
# ---------------------------------------------------------------------------

def _decode_tokens(cfg, chunk, disagg):
    reqs = sharegpt_trace(6, context_len=48, output_len=8, seed=5,
                          arrival_rate=50.0, ctx_jitter=0.2,
                          vocab=cfg.vocab)
    eng = Engine(cfg, slots=2, max_ctx=128, seed=0,
                 prefill_chunk_tokens=chunk, disagg=disagg)
    out = eng.run(reqs)
    assert out["n_done"] == 6
    for r in reqs:
        assert r.dispatch_s >= r.arrival_s - 1e-9
        assert len(r.out_tokens) == r.output_len
    return {r.request_id: [int(t) for t in r.out_tokens] for r in reqs}


def test_chunked_disagg_bit_identity():
    """Same trace through chunk {full, ctx/2, ctx/8} x disagg {off, on}:
    identical decoded streams per request — the PR 8 invariant that
    prefill scheduling is a pure timing/traffic concern."""
    cfg = _reduced()
    ref = _decode_tokens(cfg, 0, False)      # monolithic colocated
    for chunk, disagg in [(24, False), (6, False), (0, True), (24, True)]:
        assert _decode_tokens(cfg, chunk, disagg) == ref, (chunk, disagg)


# ---------------------------------------------------------------------------
# engine <-> analytic twin parity on a rolling-admission trace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk,disagg", [(0, False), (16, False),
                                          (0, True)])
def test_rolling_admission_engine_replay_parity(chunk, disagg):
    cfg = _parity_cfg()
    reqs = sharegpt_trace(8, context_len=64, output_len=10, seed=7,
                          arrival_rate=2000.0, ctx_jitter=0.2,
                          vocab=cfg.vocab)
    eng = Engine(cfg, slots=2, max_ctx=160, device_buffer=0, seed=0,
                 overlap=False, prefill_chunk_tokens=chunk, disagg=disagg)
    out = eng.run(reqs)
    assert out["n_done"] == 8
    rep = replay_engine_timeline(eng, reqs)
    for r, q in zip(sorted(reqs, key=lambda r: r.request_id), rep):
        assert r.request_id == q.request_id
        assert abs(r.dispatch_s - q.dispatch_s) < 1e-9, r.request_id
        assert abs(r.first_token_s - q.first_token_s) < 1e-9, r.request_id
        assert abs(r.finish_s - q.finish_s) < 1e-9, r.request_id


# ---------------------------------------------------------------------------
# open-loop metrics: the chunked/disagg win, measured honestly
# ---------------------------------------------------------------------------

_SIM_MODEL = profile_from_config(get_config("deepseek-v32"))
_CXL = default_backends()["cxl"]


def _sim_cell(reqs, *, round1=False, colocated=False, chunk=0):
    cfg = SimConfig(concurrency=16, device_buffer=2048, round1=round1,
                    colocated_prefill=colocated,
                    prefill_chunk_tokens=chunk)
    return simulate([dataclasses.replace(r) for r in reqs],
                    _SIM_MODEL, _CXL, cfg)


def _burst_trace(n=64):
    return diurnal_trace(n, prefix_len=4096, suffix_len=4096,
                         output_len=64, base_rate=0.5, seed=2,
                         n_tenants=2, burst_p=0.15, burst_size=6,
                         ctx_tail_alpha=2.5, max_ctx_mult=3.0)


def test_chunked_prefill_bounds_worst_gap_open_loop():
    """On a burst trace, monolithic colocated prefill stalls decoding
    requests for whole prompts; chunking bounds the worst single
    inter-token gap, and disaggregation removes it entirely."""
    reqs = _burst_trace()
    mono = _sim_cell(reqs, colocated=True)
    chk = _sim_cell(reqs, colocated=True, chunk=1024)
    dis = _sim_cell(reqs, round1=True)
    assert mono["n_done"] == chk["n_done"] == dis["n_done"] == len(reqs)
    assert chk["tbt_max_p99_s"] < 0.6 * mono["tbt_max_p99_s"]
    assert dis["tbt_max_p99_s"] < chk["tbt_max_p99_s"]


def test_arrival_anchored_ttft_is_honest():
    """Arrival-anchored TTFT includes queueing delay, so its p99 can
    never be below the dispatch-anchored p99 — a violation means a
    request was dispatched before it arrived."""
    reqs = _burst_trace()
    for cell in (_sim_cell(reqs, colocated=True),
                 _sim_cell(reqs, colocated=True, chunk=1024),
                 _sim_cell(reqs, round1=True)):
        assert cell["ttft_arrival_p99_s"] >= cell["ttft_p99_s"] - 1e-9
        assert cell["ttft_arrival_mean_s"] >= cell["ttft_mean_s"] - 1e-9


def test_engine_records_worst_token_gap():
    cfg = _reduced()
    reqs = sharegpt_trace(4, context_len=48, output_len=6, seed=9,
                          arrival_rate=20.0, ctx_jitter=0.0,
                          vocab=cfg.vocab)
    eng = Engine(cfg, slots=2, max_ctx=96)
    out = eng.run(reqs)
    assert out["n_done"] == 4
    for r in reqs:
        assert r.tbt_max_s > 0.0             # a worst gap was observed
        assert r.tbt_max_s >= r.tbt_s - 1e-12   # max >= mean
    assert out["tbt_max_p99_s"] >= out["tbt_p99_s"] - 1e-12
