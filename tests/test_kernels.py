"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref.py
oracles (interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gather_kv import gather_kv, gather_kv_pages
from repro.kernels.indexer import indexer_scores
from repro.kernels.scatter_kv import scatter_kv
from repro.kernels.sparse_attn import sparse_attn

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("S,d,k", [(64, 32, 16), (128, 64, 32),
                                   (256, 128, 64), (64, 576, 8)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_gather_kv_sweep(S, d, k, dtype):
    kv = jax.random.normal(KEY, (S, d), dtype)
    idx = jax.random.randint(KEY, (k,), 0, S)
    out = gather_kv(kv, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.gather_kv_ref(kv, idx),
                                          np.float32))


@pytest.mark.parametrize("page", [4, 16])
def test_gather_pages(page):
    S, d, n = 128, 64, 4
    kv = jax.random.normal(KEY, (S, d), jnp.bfloat16)
    pidx = jnp.array([0, 3, 5, 7], jnp.int32)
    out = gather_kv_pages(kv, pidx, page=page)
    expect = kv.reshape(S // page, page, d)[pidx].reshape(n * page, d)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32))


@pytest.mark.parametrize("S,di,H", [(512, 64, 4), (1024, 128, 8),
                                    (512, 32, 2)])
def test_indexer_sweep(S, di, H):
    q = jax.random.normal(KEY, (H, di), jnp.bfloat16)
    w = jax.random.normal(KEY, (H,), jnp.bfloat16)
    keys = jax.random.normal(KEY, (S, di), jnp.bfloat16)
    out = indexer_scores(q, w, keys, block_s=256)
    expect = ref.indexer_scores_ref(q, w, keys)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("k,H,dq,dv,block", [(256, 8, 64, 48, 128),
                                             (512, 16, 128, 128, 256),
                                             (128, 4, 576, 512, 128)])
def test_sparse_attn_sweep(k, H, dq, dv, block):
    q = jax.random.normal(KEY, (H, dq), jnp.bfloat16)
    keys = jax.random.normal(KEY, (k, dq), jnp.bfloat16)
    vals = jax.random.normal(KEY, (k, dv), jnp.bfloat16)
    valid = jax.random.bernoulli(KEY, 0.8, (k,)).at[0].set(True)
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)
    scale = 1.0 / np.sqrt(dq)
    out = sparse_attn(q, keys, vals, bias, scale=scale, block_k=block)
    # oracle: dense softmax attention over valid entries
    s = (q.astype(jnp.float32) @ keys.astype(jnp.float32).T) * scale
    s = jnp.where(valid[None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    expect = p @ vals.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-2)


def test_scatter_inplace_semantics():
    S, d, k = 64, 32, 8
    pool = jax.random.normal(KEY, (S, d), jnp.bfloat16)
    entries = jax.random.normal(jax.random.PRNGKey(7), (k, d), jnp.bfloat16)
    idx = jnp.array([1, 5, 9, 13, 17, 21, 25, 29], jnp.int32)
    out = scatter_kv(pool, entries, idx)
    expect = ref.scatter_kv_ref(pool, entries, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32))


# ---- batched ops wrappers: pallas vs ref dispatch equivalence ----

def test_ops_mla_equivalence():
    B, H, k, dc, dr = 2, 8, 32, 48, 16
    q_lat = jax.random.normal(KEY, (B, H, dc), jnp.bfloat16)
    q_pe = jax.random.normal(KEY, (B, H, dr), jnp.bfloat16)
    entries = jax.random.normal(KEY, (B, k, dc + dr), jnp.bfloat16)
    valid = jax.random.bernoulli(KEY, 0.7, (B, k)).at[:, 0].set(True)
    a = ops.batched_sparse_mla(q_lat, q_pe, entries, valid, dc=dc,
                               scale=0.11, use_pallas=True)
    b = ops.batched_sparse_mla(q_lat, q_pe, entries, valid, dc=dc,
                               scale=0.11, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-2, atol=2e-2)


def test_ops_gqa_equivalence():
    B, H, n_kv, hd, k = 2, 8, 4, 32, 16
    q = jax.random.normal(KEY, (B, H, hd), jnp.bfloat16)
    entries = jax.random.normal(KEY, (B, k, 2 * n_kv * hd), jnp.bfloat16)
    valid = jnp.ones((B, k), bool)
    a = ops.batched_sparse_gqa(q, entries, valid, n_kv=n_kv, use_pallas=True)
    b = ops.batched_sparse_gqa(q, entries, valid, n_kv=n_kv, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=3e-2, atol=3e-2)


def test_gqa_ref_matches_model_decode():
    """ref.sparse_gqa_attn_ref is the same math as dsa.gqa_sparse_decode
    (modulo projections): cross-check on raw tensors."""
    from repro.models import dsa
    from repro.configs import get_config
    cfg = get_config("qwen2-1.5b").reduced()
    B, k = 2, 8
    entries = jax.random.normal(KEY, (B, k, dsa.gqa_entry_dim(cfg)),
                                jnp.bfloat16)
    valid = jnp.ones((B, k), bool)
    q = jax.random.normal(KEY, (B, cfg.n_heads, cfg.hd), jnp.bfloat16)
    out_ref = jax.vmap(
        lambda qq, ee, vv: ref.sparse_gqa_attn_ref(qq, ee, vv,
                                                   cfg.n_kv_heads)
    )(q, entries, valid)
    assert out_ref.shape == (B, cfg.n_heads, cfg.hd)
    assert not jnp.isnan(out_ref).any()
