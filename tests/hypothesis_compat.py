"""Guarded hypothesis import: property tests skip cleanly where the
package is absent (pytest.importorskip semantics, but scoped to the
``@given`` tests instead of nuking whole modules that also hold plain
unit tests).

CI sets ``REQUIRE_HYPOTHESIS=1`` (.github/workflows/ci.yml): there a
missing hypothesis is a hard error instead of a silent skip, so the
property tests — bit-identity, budget bounds, buffer invariants —
actually run on every push.  Local runs keep the auto-skip fallback.

Usage:  ``from hypothesis_compat import given, settings, st``
"""
import os

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise RuntimeError(
            "REQUIRE_HYPOTHESIS is set but hypothesis is not importable: "
            "the property tests would silently skip.  Install it "
            "(pip install -r requirements.txt) or unset the variable.")

    class _StrategyStub:
        """Evaluates strategy expressions at decoration time to harmless
        placeholders (the decorated test is skipped anyway)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*a, **k):
        return lambda fn: fn
