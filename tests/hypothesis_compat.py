"""Guarded hypothesis import: property tests skip cleanly where the
package is absent (pytest.importorskip semantics, but scoped to the
``@given`` tests instead of nuking whole modules that also hold plain
unit tests).

Usage:  ``from hypothesis_compat import given, settings, st``
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Evaluates strategy expressions at decoration time to harmless
        placeholders (the decorated test is skipped anyway)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*a, **k):
        return lambda fn: fn
