"""Shared placement substrate (core/placement.py): unit + seeded property
tests (hypothesis-free so they run everywhere).

The headline property: SACSystem (page-granular) and Scheduler
(byte-granular) placement decisions AGREE for the same policy and request
sequence — there is exactly one placement implementation and every layer
consumes it.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.placement import (Placer, interleaved_assignment,
                                  pages_for_tokens, policy_for_interleave)
from repro.core.sac import SACSystem
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler, SchedulerConfig


# ---- Placer unit tests ----

def test_round_robin_cycles_devices():
    p = Placer(3, policy="round_robin")
    assert [p.place(i) for i in range(6)] == [0, 1, 2, 0, 1, 2]
    assert p.max_imbalance() == 0


def test_first_fit_stacks_on_device_zero():
    p = Placer(3, policy="first_fit")
    assert [p.place(i) for i in range(4)] == [0, 0, 0, 0]


def test_byte_capacity_skips_full_device():
    p = Placer(2, policy="round_robin", capacity_bytes=100.0)
    assert p.place(0, n_bytes=80.0) == 0
    assert p.place(1, n_bytes=80.0) == 1
    # both have 80/100 booked: a 30-byte request fits nowhere
    assert p.place(2, n_bytes=30.0) is None
    # a 20-byte request fits; round-robin pointer sits at device 0
    assert p.place(3, n_bytes=20.0) == 0


def test_page_capacity_independent_of_bytes():
    p = Placer(2, policy="round_robin", capacity_pages=4)
    assert p.place(0, n_pages=3) == 0
    assert p.place(1, n_pages=3) == 1
    assert p.place(2, n_pages=2) is None       # 3+2 > 4 on both
    assert p.place(3, n_pages=1) == 0
    p.release(0)
    # rr pointer sits at 1, but device 1 has 3 booked and 3 more won't
    # fit; falls through to device 0, which holds 1 page post-release
    assert p.place(4, n_pages=3) == 0


def test_least_loaded_balances_bytes():
    p = Placer(3, policy="least_loaded")
    assert p.place(0, n_bytes=100.0) == 0
    assert p.place(1, n_bytes=10.0) == 1
    assert p.place(2, n_bytes=10.0) == 2
    # device 1 and 2 tie at 10 bytes; tie breaks to lower index
    assert p.place(3, n_bytes=5.0) == 1
    assert p.place(4, n_bytes=1.0) == 2


def test_release_returns_device_and_frees():
    p = Placer(2, policy="round_robin", capacity_bytes=10.0)
    assert p.place(7, n_bytes=10.0) == 0
    assert p.place(8, n_bytes=10.0) == 1
    assert p.place(9, n_bytes=1.0) is None
    assert p.release(7) == 0
    assert p.release(7) is None                # idempotent
    assert p.place(9, n_bytes=1.0) == 0


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        Placer(2, policy="random")


def test_policy_for_interleave_mapping():
    assert policy_for_interleave(True) == "round_robin"
    assert policy_for_interleave(False) == "first_fit"


def test_pages_for_tokens():
    assert pages_for_tokens(0, 16) == 1
    assert pages_for_tokens(16, 16) == 1
    assert pages_for_tokens(17, 16) == 2


def test_interleaved_assignment_compat():
    # same helper is re-exported from core.pool for back-compat
    from repro.core.pool import interleaved_assignment as via_pool
    assert interleaved_assignment([0, 1, 2, 3], 2) == [0, 1, 0, 1]
    assert interleaved_assignment([0, 1, 2, 3], 2, enabled=False) == [0] * 4
    assert via_pool is interleaved_assignment


# ---- cross-layer agreement (the substrate property) ----

def _agree_one_seed(seed: int, policy: str, n_dev: int = 3,
                    n_ops: int = 120):
    """SACSystem and Scheduler must pick the same device for the same
    request sequence under the same policy (ample capacity: the decision
    is pure policy state, which both delegate to the shared Placer)."""
    cfg = get_config("qwen2-1.5b").reduced()
    sac = SACSystem(cfg, n_pool_devices=n_dev, device_bytes=1 << 40,
                    placement=policy)
    # byte scale proportional to the SACSystem's page bytes so
    # least-loaded orderings match: context lengths are page-aligned and
    # bytes_per_token equals the per-token pool footprint
    per_token = sac.page_bytes / sac.page_tokens
    sched = Scheduler(SchedulerConfig(
        concurrency=1 << 30, n_pool_devices=n_dev, placement=policy,
        pool_device_bytes=float(1 << 40), bytes_per_token=per_token))
    rng = np.random.default_rng(seed)
    live = {}
    for i in range(n_ops):
        if live and rng.random() < 0.35:
            rid = list(live)[int(rng.integers(len(live)))]
            sac.release(rid)
            sched.finish(live.pop(rid))
        n_tok = int(rng.integers(1, 40)) * sac.page_tokens
        rp = sac.place(i, n_tok)
        req = Request(i, 0.0, n_tok, 0)
        sched.submit(req)
        admitted = sched.try_admit(0.0)
        assert rp is not None and len(admitted) == 1
        assert admitted[0].pool_device == rp.device, \
            (seed, policy, i, admitted[0].pool_device, rp.device)
        live[i] = req


@pytest.mark.parametrize("policy", ["round_robin", "first_fit",
                                    "least_loaded"])
def test_sacsystem_and_scheduler_placement_agree(policy):
    for seed in range(5):
        _agree_one_seed(seed, policy)


# ---- pressure-aware placement (ISSUE 4 closed loop) ----

def test_pressure_aware_prefers_low_pressure_link():
    pressure = [0.9, 0.1, 0.5]
    p = Placer(3, policy="pressure_aware", pressure_fn=lambda: pressure)
    assert p.place(0) == 1
    # the in-flight correction books one average request's pressure on
    # device 1; with a fresh snapshot device 1 wins again
    pressure = [0.9, 0.1, 0.6]
    assert p.place(1) == 1


def test_pressure_aware_degrades_to_least_loaded_without_feed():
    a = Placer(3, policy="pressure_aware")
    b = Placer(3, policy="least_loaded")
    for i, n_bytes in enumerate([100.0, 10.0, 10.0, 5.0, 1.0]):
        assert a.place(i, n_bytes=n_bytes) == b.place(i, n_bytes=n_bytes)


def test_pressure_aware_in_flight_correction_prevents_herding():
    """Several placements against one stale snapshot must not all herd
    onto the same device: each booking charges its device one average
    request's pressure, so the next placement sees the previous one."""
    p = Placer(2, policy="pressure_aware", pressure_fn=lambda: [0.2, 0.4])
    assert p.place(0, n_bytes=1.0) == 0
    # same stale snapshot, but d0 is now corrected by one average
    # request (sum(pressure)/1 active = 0.6): 0.8 > 0.4 -> spill to d1
    assert p.place(1, n_bytes=1.0) == 1
    # corrected: d0 = 0.2 + 0.3, d1 = 0.4 + 0.3 -> back to d0
    assert p.place(2, n_bytes=1.0) == 0


def test_pressure_epoch_resets_correction_on_equal_readings():
    """A fresh measurement that EQUALS the previous one is still fresh:
    ``note_pressure_update`` (called once per engine/simulator step)
    resets the in-flight correction, so steady-state traces that repeat
    pressure values exactly do not accumulate synthetic load that the
    new reading already includes."""
    p = Placer(2, policy="pressure_aware", pressure_fn=lambda: [0.2, 0.4])
    assert p.place(0, n_bytes=1.0) == 0
    # without an epoch bump the stale-snapshot correction spills to d1
    assert p.place(1, n_bytes=1.0) == 1
    # re-measured (same values): correction resets, d0 wins again
    p.note_pressure_update()
    assert p.place(2, n_bytes=1.0) == 0


def test_pressure_feed_reaches_sacsystem_and_scheduler():
    cfg = get_config("qwen2-1.5b").reduced()
    feed = [5.0, 0.0]
    sac = SACSystem(cfg, n_pool_devices=2, placement="pressure_aware")
    sac.set_pressure_fn(lambda: feed)
    assert sac.place(0, 16).device == 1
    sched = Scheduler(SchedulerConfig(n_pool_devices=2,
                                      placement="pressure_aware",
                                      bytes_per_token=1.0))
    sched.set_pressure_fn(lambda: feed)
    sched.submit(Request(0, 0.0, 16, 4))
    assert sched.try_admit(0.0)[0].pool_device == 1


def test_pressure_aware_never_violates_capacity():
    """ISSUE 4 satellite: pressure ordering NEVER overrides the byte and
    page budgets — a full device is skipped no matter how idle its link
    looks (seeded random pressures, sizes, and releases)."""
    rng = np.random.default_rng(7)
    for trial in range(20):
        n_dev = int(rng.integers(2, 5))
        cap_b, cap_p = float(rng.integers(50, 200)), int(rng.integers(3, 9))
        pressure = [0.0] * n_dev
        p = Placer(n_dev, policy="pressure_aware", capacity_bytes=cap_b,
                   capacity_pages=cap_p, pressure_fn=lambda: pressure)
        live = []
        for i in range(120):
            pressure = list(rng.random(n_dev))
            if live and rng.random() < 0.3:
                p.release(live.pop(int(rng.integers(len(live)))))
            nb = float(rng.integers(1, 60))
            npg = int(rng.integers(0, 4))
            dev = p.place(i, n_bytes=nb, n_pages=npg)
            if dev is not None:
                live.append(i)
            else:
                # refused only because NO device fits
                assert not any(p.fits(d, nb, npg)
                               for d in range(n_dev)), (trial, i)
            for d in range(n_dev):
                assert p.bytes_used[d] <= cap_b + 1e-9
                assert p.pages_used[d] <= cap_p


def test_round_robin_imbalance_bounded():
    """Admission-only round-robin keeps per-device load imbalance <= 1
    (the paper's §4.3.3 link-balancing property), for any device count
    and any request sizes that fit."""
    rng = np.random.default_rng(0)
    for n_dev in (1, 2, 3, 5):
        p = Placer(n_dev, policy="round_robin")
        for i in range(int(rng.integers(5, 60))):
            p.place(i, n_bytes=float(rng.integers(1, 1000)))
            assert p.max_imbalance() <= 1, (n_dev, i, p.device_loads())


# ---- radix-affinity placement (ISSUE 5 prefix-locality loop) ----

def test_radix_affinity_prefers_cached_device_within_bonus():
    pressure = [0.5, 0.2]
    p = Placer(2, policy="radix_affinity", pressure_fn=lambda: pressure)
    # device 0 holds the prefix; its extra pressure (0.3) is under the
    # locality bonus -> locality wins
    assert p.place(0, affinity=0, affinity_s=0.4) == 0
    p.note_pressure_update()
    # bonus below the pressure gap -> the slammed link repels the request
    assert p.place(1, affinity=0, affinity_s=0.1) == 1
    p.note_pressure_update()
    # no hint: plain pressure order
    assert p.place(2) == 1


def test_radix_affinity_capacity_always_wins():
    p = Placer(2, policy="radix_affinity", capacity_pages=2,
               pressure_fn=lambda: [0.0, 0.0])
    assert p.place(0, n_pages=2, affinity=0, affinity_s=9.9) == 0
    # affinity device full: the hint may NOT override the page budget
    assert p.place(1, n_pages=2, affinity=0, affinity_s=9.9) == 1
    assert p.place(2, n_pages=2, affinity=0, affinity_s=9.9) is None


def test_radix_affinity_degrades_without_feed_or_hint():
    a = Placer(3, policy="radix_affinity")
    b = Placer(3, policy="least_loaded")
    for i, nb in enumerate([100.0, 10.0, 10.0, 5.0, 1.0]):
        assert a.place(i, n_bytes=nb) == b.place(i, n_bytes=nb)


def test_affinity_hint_ignored_by_pressure_blind_policies():
    p = Placer(3, policy="round_robin")
    assert p.place(0, affinity=2, affinity_s=9.0) == 0
    assert p.place(1, affinity=2, affinity_s=9.0) == 1
    assert p.affinity_hint is None       # transient, always cleared


def test_note_departure_subtracts_share_immediately():
    """ISSUE 5 per-request attribution: when a request departs, its own
    demand share leaves the link's smoothed pressure at once — the next
    placement must see the corrected ordering, not the EMA tail."""
    pressure = [1.0, 0.4]
    p = Placer(2, policy="pressure_aware", pressure_fn=lambda: pressure)
    assert p.place(0, n_bytes=1.0) == 1
    p.note_pressure_update()
    # the request holding 0.9 of device 0's pressure departs
    p.release(0)
    p.note_departure(0, 0.9)
    # EMA for d0 collapsed to ~0.1 < d1's 0.4: d0 wins WITHOUT waiting
    # for fresh (decayed) snapshots
    assert p.place(1, n_bytes=1.0) == 0


def test_note_departure_noop_for_pressure_blind_policies():
    p = Placer(2, policy="round_robin")
    p.note_departure(0, 5.0)             # must not raise or change state
    assert p.place(0) == 0


# ---- Scheduler.finish idempotence (ISSUE 5 satellite) ----

def test_scheduler_finish_is_idempotent():
    """Double finish (or finishing a never-admitted request) must not
    drive the byte accounting below truth or double-release the placer."""
    cfg = get_config("qwen2-1.5b").reduced()
    sched = Scheduler(SchedulerConfig(n_pool_devices=2, bytes_per_token=1.0,
                                      local_dram_bytes=1e6,
                                      hbm_kv_bytes=1e6))
    a, b = Request(0, 0.0, 100, 10), Request(1, 0.0, 50, 10)
    for r in (a, b):
        sched.submit(r)
    assert len(sched.try_admit(0.0)) == 2
    booked = sum(sched.device_bytes)
    sched.finish(a)
    sched.finish(a)                       # duplicate: must be a no-op
    never = Request(99, 0.0, 70, 5)
    sched.finish(never)                   # never admitted: no-op
    assert sched.local_bytes == booked - 110.0
    assert sched.hbm_bytes == booked - 110.0
    assert sum(sched.device_bytes) == booked - 110.0
    sched.finish(b)
    assert sched.local_bytes == 0.0 and sched.hbm_bytes == 0.0
    assert all(db == 0.0 for db in sched.device_bytes)


def test_round_robin_imbalance_bounded_with_releases():
    """With arbitrary releases, imbalance stays bounded by the number of
    in-flight removals + 1 — it never drifts unboundedly."""
    rng = np.random.default_rng(1)
    p = Placer(4, policy="round_robin")
    live = []
    nxt = 0
    max_seen = 0
    for step in range(400):
        if live and rng.random() < 0.4:
            p.release(live.pop(int(rng.integers(len(live)))))
        else:
            p.place(nxt)
            live.append(nxt)
            nxt += 1
        max_seen = max(max_seen, p.max_imbalance())
    # releases can dent one device, but round-robin refills the dents:
    # imbalance stays small relative to 400 operations (deterministic
    # seed; the observed max is 9 — this guards against linear drift)
    assert max_seen <= 12, max_seen
