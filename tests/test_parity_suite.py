"""Engine↔simulator parity suite (tests/parity.py): both serving layers
evaluated on the shared drift and saturation traces, with hit-rate,
issued/exposed, prefetch-precision, and arbiter-grant agreement asserted
through one reusable fixture instead of per-test copies.

The engine runs the traces for real (jitted decode, real HiSparse
buffer, real overlap queues); the "simulator side" is the exact set of
analytic models ``simulate()`` composes — ``hit_rate``,
``analytic_prefetch``, ``PipelineModel``, the calibrated fabric models,
and the ``BudgetArbiter`` grant function — evaluated on the same trace
parameters.
"""
import pytest

from parity import (K, SAT_WIDTH, assert_parity, build_saturation_engine,
                    drift_parity, drift_requests, run_to_completion)

from repro.configs import get_config
from repro.serving.arbiter import ArbiterConfig, BudgetArbiter
from repro.serving.request import sharegpt_trace
from repro.serving.simulator import (SimConfig, default_backends,
                                     profile_from_config, simulate)


# ---------------------------------------------------------------------------
# drift traces: the full grid through the one fixture
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("buf,prefetch", [(48, False), (48, True)])
def test_drift_parity_grid(buf, prefetch):
    """Hit rate, issued/exposed seconds, and prefetch precision agree
    between the engine measurement and the analytic twins on the shared
    drift trace (the PR 1/PR 2 parity bounds, one fixture)."""
    assert_parity(drift_parity(buf, prefetch=prefetch))


# ---------------------------------------------------------------------------
# saturation trace: engine and simulator agree on what arbitration does
# ---------------------------------------------------------------------------


def _sim_saturation(arbiter: bool):
    model = profile_from_config(get_config("deepseek-v32"))
    b = default_backends()["cxl"]
    reqs = sharegpt_trace(48, context_len=65536, output_len=96, seed=1)
    return simulate(reqs, model, b,
                    SimConfig(concurrency=48, overlap_frac=0.2,
                              prefetch_width=512, arbiter=arbiter,
                              min_prefetch_width=32))


def test_saturation_trace_both_layers_agree_on_arbitration():
    """Directional agreement on the saturation regime: in BOTH layers,
    arbitration strictly cuts issued fabric seconds, does not raise
    exposed seconds, keeps the hit rate within tolerance, and does not
    lower prefetch precision."""
    eng = {}
    for arb in (False, True):
        e = build_saturation_engine(arbiter=arb)
        run_to_completion(e, drift_requests(e.cfg))
        eng[arb] = e.stats
    sim = {arb: _sim_saturation(arb) for arb in (False, True)}

    # engine (measured)
    assert eng[True].issued_fabric_s < eng[False].issued_fabric_s
    assert eng[True].exposed_fabric_s <= eng[False].exposed_fabric_s
    assert eng[True].hit_rate >= eng[False].hit_rate - 0.02
    assert eng[True].prefetch_precision >= eng[False].prefetch_precision

    # simulator (analytic) — same directions under the same policy
    assert sim[True]["issued_fabric_s"] < sim[False]["issued_fabric_s"]
    assert sim[True]["exposed_fabric_s"] \
        <= sim[False]["exposed_fabric_s"] + 1e-9
    assert sim[True]["sim_hit_rate"] >= sim[False]["sim_hit_rate"] - 0.02
    p_on = (sim[True]["prefetch_useful"]
            / max(sim[True]["prefetched_entries"], 1))
    p_off = (sim[False]["prefetch_useful"]
             / max(sim[False]["prefetched_entries"], 1))
    assert p_on >= p_off - 1e-9
    # the arbiter actually bit: mean granted width below the full one
    assert 0 < sim[True]["arbiter_width_mean"] < 512
    assert sim[True]["n_done"] == sim[False]["n_done"] == 48


def test_arbiter_grant_logic_identical_across_layers():
    """The engine's granted widths are exactly what the analytic grant
    function (the one simulate() evaluates) returns on the engine's own
    measured inputs — the arbiter is ONE policy, not two."""
    eng = build_saturation_engine(arbiter=True)
    for r in drift_requests(eng.cfg, out=20):
        eng.submit(r)
    for _ in range(3):
        eng.step()
    # an analytic twin built from the engine's own constants
    twin = BudgetArbiter(
        ArbiterConfig(max_width=SAT_WIDTH, min_width=K,
                      link_budget_frac=eng.cfg.sac.link_budget_frac),
        entry_s=eng.arbiter.entry_s, n_layers=eng.model.n_kv,
        pipeline=eng.pipeline)
    for _ in range(5):
        # inputs the NEXT step's grant will consume
        demand = list(eng._last_demand_s)
        occupied = [s for s in range(eng.slots) if eng.slot_req[s]]
        t_comp = eng.step_compute_s(len(occupied))
        dev_slots = {}
        for s in occupied:
            dev = eng.sac.device_of(eng.slot_req[s].request_id)
            dev_slots.setdefault(dev, []).append(s)
        expected = twin.grant(t_comp, demand, dev_slots)
        eng.step()
        assert eng.last_grants == expected, (eng.last_grants, expected)


# ---------------------------------------------------------------------------
# online LayerSizer re-sizing (ISSUE 4): parity + bit-identity
# ---------------------------------------------------------------------------


def test_resize_mid_trace_tokens_unchanged_hit_tracks_analytic():
    """Re-apportion the hot tier MID-TRACE: decoded tokens must be
    bit-identical to an untouched engine, and the post-resize measured
    hit rate must track the analytic ``hit_rate`` at the new per-layer
    capacities (the simulator's re-sized model) within the PR 1 bound."""
    import numpy as np

    from parity import CTX, K, build_engine, drift_requests
    from repro.core import hisparse
    from repro.serving.simulator import hit_rate

    new_sizes = [24, 64]
    marks = {}
    streams = {}
    for resize in (False, True):
        # resize_interval allocates the width headroom (2x44=88 >= 64)
        # but is too large to fire on its own — the test drives the
        # resize by hand at a known step
        eng = build_engine(44, sac_overrides=dict(resize_interval=10_000))
        assert not resize or eng.buffer_width >= max(new_sizes)
        reqs = drift_requests(eng.cfg, out=60)
        for r in reqs:
            eng.submit(r)
        for step in range(60):
            eng.step()
            if step == 19 and resize:
                eng.state["hot_buf"] = hisparse.resize_layers(
                    eng.state["hot_buf"], new_sizes)
                eng.buffer_sizes = new_sizes
            if step == 24:      # post-resize warm-up window excluded
                marks[resize] = (eng.stats.buffer_hits,
                                 eng.stats.buffer_misses)
        streams[resize] = [t[:] for t in eng.slot_tokens]
        h = eng.stats.buffer_hits - marks[resize][0]
        m = eng.stats.buffer_misses - marks[resize][1]
        measured = h / max(h + m, 1)
        sizes = new_sizes if resize else [44, 44]
        modeled = sum(hit_rate(s, K, CTX) for s in sizes) / len(sizes)
        assert abs(measured - modeled) < 0.08, (resize, measured, modeled)
    assert streams[False] == streams[True]


def test_engine_auto_resize_reapportions_from_measured_rates():
    """The engine's own resize loop fires every ``resize_interval``
    steps and keeps the sum invariant; the realized DISABLED layout
    matches the sizes it reports."""
    import numpy as np

    from parity import build_engine, drift_requests, run_to_completion

    eng = build_engine(40, sac_overrides=dict(resize_interval=5))
    total = 40 * eng.model.n_kv
    run_to_completion(eng, drift_requests(eng.cfg, out=20))
    assert isinstance(eng.buffer_sizes, list)
    assert sum(eng.buffer_sizes) == total
    sp = np.asarray(eng.state["hot_buf"].slot_pos)
    for layer, size in enumerate(eng.buffer_sizes):
        enabled = (sp[layer, 0] != -2).sum()
        assert enabled == size, (layer, size, enabled)


# ---------------------------------------------------------------------------
# simulator: the closed loop evaluated analytically
# ---------------------------------------------------------------------------


def test_sim_pressure_aware_placement_beats_least_loaded_when_skewed():
    """The analytic twin of the placement loop: on a trace with one
    mega-context request per admission wave (bytes misrepresent link
    pressure) the pressure-aware placer lowers exposed fabric seconds
    at an identical hit rate."""
    from repro.serving.request import Request

    model = profile_from_config(get_config("deepseek-v32"))
    b = default_backends()["cxl"]
    reqs = [Request(i, 0.0, 131072 if i % 16 == 0 else 16384, 192)
            for i in range(64)]
    out = {}
    for pol in ("least_loaded", "pressure_aware"):
        out[pol] = simulate(reqs, model, b,
                            SimConfig(concurrency=16, overlap_frac=0.3,
                                      device_buffer=2048, placement=pol))
    assert out["pressure_aware"]["exposed_fabric_s"] \
        < out["least_loaded"]["exposed_fabric_s"]
    assert out["pressure_aware"]["sim_hit_rate"] \
        == pytest.approx(out["least_loaded"]["sim_hit_rate"], abs=1e-9)
    assert out["pressure_aware"]["n_done"] == 64


def test_sim_closed_loop_flags_run_to_completion():
    """precision_weighted + resize_interval + placement are accepted
    together and preserve the schema invariants."""
    model = profile_from_config(get_config("deepseek-v32"))
    b = default_backends()["cxl"]
    reqs = sharegpt_trace(24, context_len=32768, output_len=48, seed=2)
    out = simulate(reqs, model, b,
                   SimConfig(concurrency=12, overlap_frac=0.3,
                             prefetch_width=256, arbiter=True,
                             min_prefetch_width=16,
                             precision_weighted=True,
                             placement="pressure_aware",
                             layer_buffer_sizes=[4096] * 30 + [8192] * 31,
                             resize_interval=8, warmup_entries=256))
    assert out["n_done"] == 24
    assert 0.0 < out["sim_hit_rate"] <= 1.0
    assert out["issued_fabric_s"] >= out["exposed_fabric_s"] >= 0.0
    assert out["prefetched_entries"] >= out["prefetch_useful"] >= 0
    assert 0 < out["arbiter_width_mean"] <= 256


# ---------------------------------------------------------------------------
# shared-prefix trace: the radix loop's engine↔simulator agreement
# ---------------------------------------------------------------------------


def test_shared_prefix_radix_parity_engine_vs_sim():
    """ISSUE 5 acceptance: on the same shared-prefix trace the engine's
    real RadixIndex loop and the simulator's analytic twin agree on the
    reused tokens exactly, and each side's prefill write-byte saving
    equals its own per-token write cost times those tokens.  Both sides
    cut TTFT; neither changes its hit-rate accounting."""
    from parity import build_radix_engine, shared_prefix_requests

    cfg = get_config("qwen2-1.5b").reduced()
    # a deliberately UNALIGNED shared prefix: both layers must floor the
    # credit to whole pages (26 -> 24 at page_size 4), or they diverge
    PREFIX, SUFFIX, OUT, N = 26, 8, 6, 6
    PAGED = (PREFIX // cfg.sac.page_size) * cfg.sac.page_size

    def trace():
        return shared_prefix_requests(cfg, n=N, prefix=PREFIX,
                                      suffix=SUFFIX, out=OUT)

    eng_out = {}
    for radix in (True, False):
        eng_out[radix] = build_radix_engine(radix=radix).run(trace())
    model = profile_from_config(cfg)
    backend = default_backends()["cxl"]
    sim_out = {}
    for radix in (True, False):
        sim_out[radix] = simulate(
            trace(), model, backend,
            SimConfig(concurrency=N, round1=True, device_buffer=32,
                      page_size=cfg.sac.page_size, radix_affinity=radix))

    hits_eng = eng_out[True]["radix_hit_tokens"]
    hits_sim = sim_out[True]["radix_hit_tokens"]
    # every request after the first reuses the shared prefix, floored
    # to page granularity — both layers must count exactly that
    assert hits_eng == hits_sim == (N - 1) * PAGED
    assert eng_out[False]["radix_hit_tokens"] == 0
    assert sim_out[False]["radix_hit_tokens"] == 0

    # write-byte savings equal reused tokens x own per-token write cost
    eng_per_tok = (cfg.kv_bytes_per_token_layer + 2 * cfg.sac.d_idx) \
        * max(cfg.n_attn_layers, 1)
    saved_eng = (eng_out[False]["bytes_written"]
                 - eng_out[True]["bytes_written"])
    assert saved_eng == pytest.approx(hits_eng * eng_per_tok)
    saved_sim = (sim_out[False]["bytes_written"]
                 - sim_out[True]["bytes_written"])
    assert saved_sim == pytest.approx(hits_sim * model.kv_bytes_per_token())

    # timing moves the same direction on both layers, hit-rate does not
    assert eng_out[True]["ttft_mean_s"] < eng_out[False]["ttft_mean_s"]
    assert sim_out[True]["ttft_mean_s"] < sim_out[False]["ttft_mean_s"]
    assert sim_out[True]["sim_hit_rate"] == \
        pytest.approx(sim_out[False]["sim_hit_rate"], abs=1e-9)


def test_dedup_pool_saving_parity_engine_vs_sim():
    """PR 6 acceptance: refcounted page dedup saves pool bytes on BOTH
    serving layers, and each layer's saving is exactly its own shared
    volume — (pool_off - pool_on) * n equals the engine's shared pages
    in bytes and the simulator's shrunk booking bytes.  Reuse
    accounting and the decoded streams are untouched by the knob."""
    from parity import shared_prefix_requests

    from repro.serving.engine import Engine

    cfg = get_config("qwen2-1.5b").reduced()
    PREFIX, SUFFIX, OUT, N = 24, 8, 6, 6

    def trace():
        return shared_prefix_requests(cfg, n=N, prefix=PREFIX,
                                      suffix=SUFFIX, out=OUT)

    eng_out = {}
    for dedup in (True, False):
        eng = Engine(cfg, slots=2, max_ctx=96, seed=0, radix=True,
                     placement="radix_affinity", dedup_pages=dedup)
        eng_out[dedup] = eng.run(trace())
        assert eng_out[dedup]["n_done"] == N
    model = profile_from_config(cfg)
    backend = default_backends()["cxl"]
    sim_out = {}
    for dedup in (True, False):
        sim_out[dedup] = simulate(
            trace(), model, backend,
            SimConfig(concurrency=N, round1=True, device_buffer=32,
                      page_size=cfg.sac.page_size, radix_affinity=True,
                      dedup_pages=dedup))

    # dedup only re-books bytes: reuse and output accounting identical
    assert (eng_out[True]["radix_hit_tokens"]
            == eng_out[False]["radix_hit_tokens"] > 0)
    assert sim_out[True]["radix_hit_tokens"] == \
        pytest.approx(sim_out[False]["radix_hit_tokens"])
    assert sim_out[True]["radix_hit_tokens"] > 0
    assert eng_out[True]["engine_tokens"] == eng_out[False]["engine_tokens"]

    # the engine's saving IS its shared pages, in bytes
    saved_eng = (eng_out[False]["pool_bytes_per_req"]
                 - eng_out[True]["pool_bytes_per_req"]) * N
    shared_pages = eng_out[True]["dedup_shared_pages"]
    assert shared_pages > 0
    page_bytes = (cfg.sac.page_size
                  * (cfg.kv_bytes_per_token_layer + 2 * cfg.sac.d_idx)
                  * max(cfg.n_attn_layers, 1))
    assert saved_eng == pytest.approx(shared_pages * page_bytes)
    assert eng_out[False]["dedup_shared_pages"] == 0

    # the simulator's saving IS its shrunk booking bytes
    saved_sim = (sim_out[False]["pool_bytes_per_req"]
                 - sim_out[True]["pool_bytes_per_req"]) * N
    assert sim_out[True]["dedup_shared_bytes"] > 0
    assert saved_sim == pytest.approx(sim_out[True]["dedup_shared_bytes"])
    assert sim_out[False]["dedup_shared_bytes"] == 0
