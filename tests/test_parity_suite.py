"""Engine↔simulator parity suite (tests/parity.py): both serving layers
evaluated on the shared drift and saturation traces, with hit-rate,
issued/exposed, prefetch-precision, and arbiter-grant agreement asserted
through one reusable fixture instead of per-test copies.

The engine runs the traces for real (jitted decode, real HiSparse
buffer, real overlap queues); the "simulator side" is the exact set of
analytic models ``simulate()`` composes — ``hit_rate``,
``analytic_prefetch``, ``PipelineModel``, the calibrated fabric models,
and the ``BudgetArbiter`` grant function — evaluated on the same trace
parameters.
"""
import pytest

from parity import (K, SAT_WIDTH, assert_parity, build_saturation_engine,
                    drift_parity, drift_requests, run_to_completion)

from repro.configs import get_config
from repro.serving.arbiter import ArbiterConfig, BudgetArbiter
from repro.serving.request import sharegpt_trace
from repro.serving.simulator import (SimConfig, default_backends,
                                     profile_from_config, simulate)


# ---------------------------------------------------------------------------
# drift traces: the full grid through the one fixture
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("buf,prefetch", [(48, False), (48, True)])
def test_drift_parity_grid(buf, prefetch):
    """Hit rate, issued/exposed seconds, and prefetch precision agree
    between the engine measurement and the analytic twins on the shared
    drift trace (the PR 1/PR 2 parity bounds, one fixture)."""
    assert_parity(drift_parity(buf, prefetch=prefetch))


# ---------------------------------------------------------------------------
# saturation trace: engine and simulator agree on what arbitration does
# ---------------------------------------------------------------------------


def _sim_saturation(arbiter: bool):
    model = profile_from_config(get_config("deepseek-v32"))
    b = default_backends()["cxl"]
    reqs = sharegpt_trace(48, context_len=65536, output_len=96, seed=1)
    return simulate(reqs, model, b,
                    SimConfig(concurrency=48, overlap_frac=0.2,
                              prefetch_width=512, arbiter=arbiter,
                              min_prefetch_width=32))


def test_saturation_trace_both_layers_agree_on_arbitration():
    """Directional agreement on the saturation regime: in BOTH layers,
    arbitration strictly cuts issued fabric seconds, does not raise
    exposed seconds, keeps the hit rate within tolerance, and does not
    lower prefetch precision."""
    eng = {}
    for arb in (False, True):
        e = build_saturation_engine(arbiter=arb)
        run_to_completion(e, drift_requests(e.cfg))
        eng[arb] = e.stats
    sim = {arb: _sim_saturation(arb) for arb in (False, True)}

    # engine (measured)
    assert eng[True].issued_fabric_s < eng[False].issued_fabric_s
    assert eng[True].exposed_fabric_s <= eng[False].exposed_fabric_s
    assert eng[True].hit_rate >= eng[False].hit_rate - 0.02
    assert eng[True].prefetch_precision >= eng[False].prefetch_precision

    # simulator (analytic) — same directions under the same policy
    assert sim[True]["issued_fabric_s"] < sim[False]["issued_fabric_s"]
    assert sim[True]["exposed_fabric_s"] \
        <= sim[False]["exposed_fabric_s"] + 1e-9
    assert sim[True]["sim_hit_rate"] >= sim[False]["sim_hit_rate"] - 0.02
    p_on = (sim[True]["prefetch_useful"]
            / max(sim[True]["prefetched_entries"], 1))
    p_off = (sim[False]["prefetch_useful"]
             / max(sim[False]["prefetched_entries"], 1))
    assert p_on >= p_off - 1e-9
    # the arbiter actually bit: mean granted width below the full one
    assert 0 < sim[True]["arbiter_width_mean"] < 512
    assert sim[True]["n_done"] == sim[False]["n_done"] == 48


def test_arbiter_grant_logic_identical_across_layers():
    """The engine's granted widths are exactly what the analytic grant
    function (the one simulate() evaluates) returns on the engine's own
    measured inputs — the arbiter is ONE policy, not two."""
    eng = build_saturation_engine(arbiter=True)
    for r in drift_requests(eng.cfg, out=20):
        eng.submit(r)
    for _ in range(3):
        eng.step()
    # an analytic twin built from the engine's own constants
    twin = BudgetArbiter(
        ArbiterConfig(max_width=SAT_WIDTH, min_width=K,
                      link_budget_frac=eng.cfg.sac.link_budget_frac),
        entry_s=eng.arbiter.entry_s, n_layers=eng.model.n_kv,
        pipeline=eng.pipeline)
    for _ in range(5):
        # inputs the NEXT step's grant will consume
        demand = list(eng._last_demand_s)
        occupied = [s for s in range(eng.slots) if eng.slot_req[s]]
        t_comp = eng.step_compute_s(len(occupied))
        dev_slots = {}
        for s in occupied:
            dev = eng.sac.device_of(eng.slot_req[s].request_id)
            dev_slots.setdefault(dev, []).append(s)
        expected = twin.grant(t_comp, demand, dev_slots)
        eng.step()
        assert eng.last_grants == expected, (eng.last_grants, expected)
