"""Shared traffic substrate (core/traffic.py): device-id validation at
the accounting boundary and per-request prefetch attribution.

ISSUE 4 regression: the pre-PR 4 accountant aliased out-of-range device
ids with ``dev % n_devices``, silently charging (and later reading) the
WRONG link's budget — the arbiter and the pressure-aware placer would
then act on a corrupted per-link signal.  The boundary now clamps once,
counts the anomaly, and everything downstream indexes directly; the
OverlapQueue below the boundary raises instead of aliasing.
"""
import pytest

from repro.core.traffic import FabricAccountant, OverlapQueue, TrafficStats
from repro.core.transfer import FABRICS, PipelineModel


def _acct(n_devices=2, overlap=False):
    acct = FabricAccountant(FABRICS["cxl"], n_devices=n_devices)
    if overlap:
        acct.enable_overlap(PipelineModel(depth=2, overlap_frac=0.5))
    return acct


# ---------------------------------------------------------------------------
# device-id validation
# ---------------------------------------------------------------------------


def test_out_of_range_device_is_clamped_and_counted():
    acct = _acct(n_devices=2)
    acct.sparse_fetch(4, 128, device=7)        # would alias to dev 1 via %
    assert acct.stats.device_anomalies == 1
    # clamped to the LAST device (nearest valid), not dev 7 % 2
    assert acct.stats.device_issued_s[1] > 0
    assert acct.stats.device_issued_s[0] == 0.0
    acct.bulk_fetch(1024.0, device=-3)
    acct.write_back(1024.0, device=5)
    acct.add_step_demand(9, 100.0)
    assert acct.stats.device_anomalies == 4
    # negative ids clamp to device 0
    assert acct.stats.device_demand_bytes[0] > 0


def test_in_range_devices_never_count_anomalies():
    acct = _acct(n_devices=3)
    for d in range(3):
        acct.sparse_fetch(2, 64, device=d)
        acct.write_back(64.0, device=d)
        acct.add_step_demand(d, 10.0)
    assert acct.stats.device_anomalies == 0
    assert all(t > 0 for t in acct.stats.device_issued_s)


def test_prefetch_fetch_charges_the_clamped_device_consistently():
    """The prefetch split must land on the SAME (clamped) device as the
    issued seconds, or device_demand_s() would go negative on one link
    and overcount another."""
    acct = _acct(n_devices=2)
    acct.prefetch_fetch(8, 256, device=11)
    demand = acct.stats.device_demand_s()
    assert all(d >= -1e-12 for d in demand)
    assert acct.stats.device_prefetch_s[1] == acct.stats.device_issued_s[1]


def test_overlap_queue_raises_below_the_boundary():
    q = OverlapQueue(2, PipelineModel())
    with pytest.raises(IndexError):
        q.issue(2, 1.0)
    with pytest.raises(IndexError):
        q.issue(-1, 1.0)
    q.issue(1, 1.0)
    assert q.pending_s == 1.0


def test_overlap_path_books_clamped_device():
    """With overlap on, a clamped id must reach the queue as a VALID id
    (the boundary clamps before ``_book_time``)."""
    acct = _acct(n_devices=2, overlap=True)
    acct.sparse_fetch(4, 128, device=99)
    assert acct.stats.device_anomalies == 1
    assert acct.overlap.pending_s > 0


# ---------------------------------------------------------------------------
# per-request prefetch attribution (precision-weighted grants)
# ---------------------------------------------------------------------------


def test_record_prefetch_attributes_per_request():
    acct = _acct()
    acct.record_prefetch(10, 2, key="a")
    acct.record_prefetch(5, 5, key="b")
    acct.record_prefetch(3, 1)                 # unkeyed: totals only
    s = acct.stats
    assert s.prefetched_entries == 18 and s.prefetch_useful == 8
    assert s.request_pf["a"] == [10.0, 2.0]
    assert s.request_pf["b"] == [5.0, 5.0]
    assert set(s.request_pf) == {"a", "b"}


def test_request_precision_smoothed_toward_prior():
    s = TrafficStats()
    # no data: the optimistic prior
    assert s.request_precision("fresh") == 1.0
    s.request_pf["junk"] = [400.0, 0.0]
    assert s.request_precision("junk") < 0.05
    s.request_pf["good"] = [20.0, 18.0]
    assert s.request_precision("good") > 0.8
    # a single unlucky insert must NOT collapse to zero (cold-start
    # starvation guard): smoothing keeps it near the prior
    s.request_pf["young"] = [1.0, 0.0]
    assert s.request_precision("young") > 0.5


def test_drop_request_forgets_attribution():
    s = TrafficStats()
    s.request_pf["a"] = [10.0, 2.0]
    s.request_demand_s["a"] = 0.5
    s.drop_request("a")
    s.drop_request("a")                        # idempotent
    assert "a" not in s.request_pf
    assert "a" not in s.request_demand_s
    assert s.request_precision("a") == 1.0


# ---------------------------------------------------------------------------
# per-request demand attribution (ISSUE 5: departure-aware pressure)
# ---------------------------------------------------------------------------


def test_demand_ops_attribute_per_request():
    """Keyed demand ops split the issued seconds per request, and the
    per-request shares sum to the per-device totals."""
    acct = _acct(n_devices=2)
    acct.sparse_fetch(8, 128, device=0, key="a")
    acct.sparse_fetch(4, 128, device=0, key="b")
    acct.write_back(4096.0, device=1, key="b")
    acct.bulk_fetch(2048.0, device=1, key="c")
    s = acct.stats
    assert set(s.request_demand_s) == {"a", "b", "c"}
    assert all(v > 0 for v in s.request_demand_s.values())
    total = sum(s.request_demand_s.values())
    assert abs(total - sum(s.device_demand_s())) < 1e-12


def test_prefetch_never_charges_request_demand():
    """Speculation is not the request's demand share: subtracting it at
    departure would over-credit the link (the arbiter already shapes
    prefetch separately via device_prefetch_s)."""
    acct = _acct(n_devices=2)
    acct.prefetch_fetch(16, 256, device=0)
    assert acct.stats.request_demand_s == {}
    acct.sparse_fetch(2, 256, device=0, key="r")
    assert set(acct.stats.request_demand_s) == {"r"}


def test_unkeyed_ops_attribute_nothing():
    acct = _acct(n_devices=1)
    acct.sparse_fetch(8, 128)
    acct.write_back(4096.0)
    assert acct.stats.request_demand_s == {}
