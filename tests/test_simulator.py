"""Simulator behaviour + paper-claims validation (DESIGN.md §8).

Fast variants here (reduced trace); the full paper-scale sweep lives in
benchmarks/ (fig9-fig14) and EXPERIMENTS.md.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.request import sharegpt_trace, summarize
from repro.serving.simulator import (BackendProfile, SimConfig,
                                     default_backends, hit_rate,
                                     profile_from_config, simulate)

MODEL = profile_from_config(get_config("deepseek-v32"))
B = default_backends()


def _run(backend, ctx=65536, conc=64, n=128, out=256, **sim_kw):
    reqs = sharegpt_trace(n, context_len=ctx, output_len=out, seed=1)
    return simulate(reqs, MODEL, backend, SimConfig(concurrency=conc,
                                                    **sim_kw))


def test_all_requests_complete():
    for name in ("cxl", "rdma", "dram", "hbm"):
        res = _run(B[name], n=64)
        assert res["n_done"] == 64, (name, res)


def test_cxl_beats_rdma_and_gap_grows_with_context():
    gaps = []
    for ctx in (16384, 65536, 131072):
        c = _run(B["cxl"], ctx=ctx)
        r = _run(B["rdma"], ctx=ctx)
        gaps.append(c["throughput_tok_s"] / r["throughput_tok_s"])
    assert gaps[0] > 1.0
    assert gaps[-1] > gaps[0], gaps          # P1 worsens with context


def test_cxl_close_to_dram_upper_bound():
    c = _run(B["cxl"])
    d = _run(B["dram"])
    ratio = c["throughput_tok_s"] / d["throughput_tok_s"]
    assert 0.80 < ratio <= 1.0, ratio        # paper: 91%


def test_rdma_ttft_dominated_by_prefetch():
    c = _run(B["cxl"], ctx=65536)
    r = _run(B["rdma"], ctx=65536)
    assert r["ttft_mean_s"] > 3 * c["ttft_mean_s"]


def test_hbm_capacity_plateau():
    """Fig 12: HBM-only throughput stops scaling once KV capacity caps
    the resident batch."""
    lo = _run(B["hbm"], ctx=131072, conc=16, n=64)
    hi = _run(B["hbm"], ctx=131072, conc=128, n=64)
    cx_lo = _run(B["cxl"], ctx=131072, conc=16, n=64)
    cx_hi = _run(B["cxl"], ctx=131072, conc=128, n=64)
    hbm_scale = hi["throughput_tok_s"] / lo["throughput_tok_s"]
    cxl_scale = cx_hi["throughput_tok_s"] / cx_lo["throughput_tok_s"]
    assert cxl_scale > hbm_scale + 0.5, (cxl_scale, hbm_scale)


def test_interleaving_positive_gain():
    two = _run(B["cxl"], ctx=131072)
    one = _run(dataclasses.replace(B["cxl"], n_pool_devices=1,
                                   interleave=False), ctx=131072)
    gain = two["throughput_tok_s"] / one["throughput_tok_s"] - 1
    assert 0.03 < gain < 0.35, gain          # paper: +9.2% avg, +14.2% @128K


def test_buffer_size_gain():
    b6 = _run(B["cxl"], device_buffer=6144)
    b4 = _run(B["cxl"], device_buffer=4096)
    gain = b6["throughput_tok_s"] / b4["throughput_tok_s"] - 1
    assert 0.03 < gain < 0.30, gain          # paper: +10.4%


def test_concurrency_scaling_cxl():
    """Fig 11: SAC throughput grows with concurrency."""
    t = [_run(B["cxl"], conc=c, n=96)["throughput_tok_s"]
         for c in (8, 32, 64)]
    assert t[0] < t[1] < t[2], t


def test_round1_prefill_backends_comparable():
    """Fig 9: cold-cache round — all backends within ~15% (prefill is
    compute-bound; pool write is small)."""
    outs = {n: _run(B[n], ctx=16384, n=48, out=64, round1=True)
            for n in ("cxl", "rdma", "dram")}
    thr = [o["throughput_tok_s"] for o in outs.values()]
    assert max(thr) / min(thr) < 1.3, outs


def test_hit_rate_monotone():
    assert hit_rate(6144, 2048, 131072) > hit_rate(4096, 2048, 131072)
    assert hit_rate(6144, 2048, 16384) >= hit_rate(6144, 2048, 131072)
    assert 0.9 < hit_rate(6144, 2048, 16384) < 1.0
    assert hit_rate(0, 2048, 16384) == 0.0


def test_warmup_reduces_cold_start_misses():
    """Prefill warm-up's cold-start miss reduction (ROADMAP follow-up):
    a request's FIRST decode step runs against a cold hot tier; seeding
    it with ``warmup_entries`` raises the modeled first-step hit rate
    monotonically, and the aggregate hit rate follows."""
    from repro.serving.prefetch import analytic_warmup

    outs = {w: _run(B["cxl"], n=48, out=64, warmup_entries=w)
            for w in (0, 256, 1024)}
    assert outs[0]["cold_hit_rate"] == 0.0
    assert (outs[0]["cold_hit_rate"] < outs[256]["cold_hit_rate"]
            < outs[1024]["cold_hit_rate"])
    assert outs[256]["sim_hit_rate"] > outs[0]["sim_hit_rate"]
    assert outs[1024]["sim_hit_rate"] > outs[256]["sim_hit_rate"]
    # the per-step model itself is monotone and bounded
    prev = 0.0
    for w in (0, 128, 1024, 4096, 1 << 20):
        h = analytic_warmup(w, 2048, 6144)
        assert 0.0 <= prev <= h <= 1.0
        prev = h
    # cold-step traffic is visible: warm-up charges prefetch entries,
    # and the first-step hit keeps useful <= prefetched
    assert outs[1024]["prefetched_entries"] >= outs[1024]["prefetch_useful"]


def test_layer_buffer_sizes_mean_hit():
    """Per-layer sizing (LayerSizer apportioning) evaluated analytically:
    uniform sizes reproduce the uniform hit rate; skewed sizes at equal
    total shift it by the mean of per-layer rates."""
    uni = _run(B["cxl"], n=48, out=64)
    same = _run(B["cxl"], n=48, out=64,
                layer_buffer_sizes=[6144] * MODEL.n_attn_layers)
    assert same["sim_hit_rate"] == pytest.approx(uni["sim_hit_rate"])
    skew = [4096, 8192] * (MODEL.n_attn_layers // 2) \
        + [6144] * (MODEL.n_attn_layers % 2)
    mixed = _run(B["cxl"], n=48, out=64, layer_buffer_sizes=skew)
    # hit_rate is concave in buf, so the skewed mean sits strictly below
    assert mixed["sim_hit_rate"] < uni["sim_hit_rate"]
    assert mixed["n_done"] == 48
