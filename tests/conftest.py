"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device;
distributed behaviour is tested via subprocesses (test_distributed.py)."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _clear_jit_caches():
    """Bound resident memory: compiled executables accumulate ~36 GB over
    the full suite on this 35 GB container (OOM-killed twice).  Dropping
    caches after every test keeps RSS flat at the cost of recompiles."""
    yield
    jax.clear_caches()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running validation tests")
