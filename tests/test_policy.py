"""The shared serving-policy core (PR 10, src/repro/serving/policy/).

What this suite guards:

  - **object identity across the twins**: the engine, the scheduler
    ``simulate()`` drives, and ``replay_engine_timeline`` all construct
    their admission / prefill-schedule decisions through the SAME
    classes from the policy package — the replay literally consumes
    ``eng.admission_policy`` / ``eng.prefill_schedule``, so parity is
    asserted at the object level, not re-proved float by float;
  - **pure-policy invariants** (hypothesis): ``select`` always returns
    an eligible index, ``order`` is a stable permutation, ``shed``
    drops only arrived requests and keeps exactly the
    ``shed_queue_depth`` earliest deadlines;
  - **admission choice never changes decoded tokens** (hypothesis over
    {fcfs, radix, edf} x seeds): prefill recomputes the full prompt
    in-graph, so the order requests enter slots is a pure
    timing/traffic concern — the PR 10 analogue of the PR 8 chunk/
    disagg bit-identity invariant;
  - **EDF load shedding** behaves identically in the engine, the
    analytic replay of that same engine, and the scheduler-driven
    simulator: the same requests leave the queue, never decode, and
    are excluded from ``summarize``.
"""
import dataclasses

import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.serving.engine import Engine
from repro.serving.policy import (ARRIVAL_EPS, AdmissionPolicy,
                                  EDFAdmission, FCFSAdmission,
                                  LocalityBonus, PrefillSchedule,
                                  RadixAdmission, ReplicationPolicy,
                                  WarmupPressureSeed, arrived,
                                  make_admission)
from repro.serving.request import Request, sharegpt_trace
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.simulator import (SimConfig, default_backends,
                                     profile_from_config,
                                     replay_engine_timeline, simulate)


def _reduced():
    return get_config("qwen2-1.5b").reduced()


def _parity_cfg(**sac):
    cfg = _reduced()
    return dataclasses.replace(cfg, sac=dataclasses.replace(
        cfg.sac, warmup_entries=0, warmup_radix=0, prefetch_width=0,
        **sac))


def _queue(arrivals):
    return [Request(i, a, 64, 8) for i, a in enumerate(arrivals)]


# ---------------------------------------------------------------------------
# the factory: one construction path for all three consumers
# ---------------------------------------------------------------------------


class TestMakeAdmission:
    def test_legacy_mapping(self):
        assert isinstance(make_admission(None), FCFSAdmission)
        p = make_admission(None, radix_admission=True, score_fn=len)
        assert isinstance(p, RadixAdmission) and p.score_fn is len

    def test_radix_without_cache_degrades_to_fcfs(self):
        # the same gating Engine.admission_on always applied
        assert isinstance(
            make_admission("radix", score_fn=len, has_radix=False),
            FCFSAdmission)
        assert isinstance(make_admission("radix", score_fn=None),
                          FCFSAdmission)

    def test_edf_carries_its_knobs(self):
        p = make_admission("edf", slo_ttft_s=0.25, shed_queue_depth=3)
        assert isinstance(p, EDFAdmission)
        assert p.slo_ttft_s == 0.25 and p.shed_queue_depth == 3

    def test_unknown_name_rejected(self):
        with pytest.raises(ValueError, match="unknown admission"):
            make_admission("sjf")


# ---------------------------------------------------------------------------
# pure-policy semantics
# ---------------------------------------------------------------------------


class TestAdmissionSemantics:
    def test_arrival_gate_is_the_single_epsilon(self):
        r = Request(0, 1.0, 64, 8)
        assert not arrived(r, 1.0 - 1e-6)
        assert arrived(r, 1.0)
        assert arrived(r, 1.0 - ARRIVAL_EPS / 2)

    def test_eligible_respects_clock_and_preserves_order(self):
        q = _queue([0.0, 5.0, 1.0, 9.0])
        assert AdmissionPolicy().eligible(q, 4.0) == [0, 2]

    def test_radix_select_prefers_longest_match_fcfs_ties(self):
        scores = {0: 2.0, 1: 8.0, 2: 8.0, 3: 1.0}
        pol = RadixAdmission(lambda r: scores[r.request_id])
        q = _queue([0.0] * 4)
        assert pol.select(q, [0, 1, 2, 3]) == 1      # tie -> earlier pos
        assert pol.order(q)[0].request_id == 1

    def test_select_short_circuits_without_scorer(self):
        calls = []
        pol = RadixAdmission(lambda r: calls.append(r) or 0.0)
        q = _queue([0.0, 0.0])
        assert pol.select(q, [1]) == 1 and not calls  # single candidate
        pol.score_fn = None
        assert pol.select(q, [0, 1]) == 0 and not calls

    def test_edf_orders_by_deadline(self):
        pol = EDFAdmission(slo_ttft_s=1.0)
        q = _queue([3.0, 1.0, 2.0])
        assert [r.request_id for r in pol.order(q)] == [1, 2, 0]
        assert pol.select(q, [0, 2]) == 2

    def test_edf_shed_keeps_earliest_deadlines(self):
        pol = EDFAdmission(slo_ttft_s=1.0, shed_queue_depth=2)
        q = _queue([0.0, 3.0, 1.0, 100.0, 2.0])
        # at t=5 request 3 has not arrived: shed ranks {0,1,2,4} and
        # keeps the 2 earliest deadlines (0 and 2)
        assert pol.shed(q, 5.0) == [1, 4]
        # backlog within depth -> no shedding; depth 0 -> disabled
        assert pol.shed(q[:2], 5.0) == []
        assert EDFAdmission(1.0, 0).shed(q, 5.0) == []

    def test_base_policies_never_shed(self):
        q = _queue([0.0] * 8)
        assert FCFSAdmission().shed(q, 1.0) == []
        assert RadixAdmission(lambda r: 1.0).shed(q, 1.0) == []

    @given(arrivals=st.lists(st.floats(0.0, 10.0), min_size=1,
                             max_size=12),
           clock=st.floats(0.0, 10.0),
           name=st.sampled_from(["fcfs", "radix", "edf"]))
    @settings(max_examples=60, deadline=None)
    def test_select_and_order_invariants(self, arrivals, clock, name):
        """select() returns an eligible index; order() is a stable
        permutation of the queue — for every policy."""
        pol = make_admission(name, slo_ttft_s=0.5, shed_queue_depth=0,
                             score_fn=lambda r: float(r.request_id % 3))
        q = _queue(arrivals)
        elig = pol.eligible(q, clock)
        assert elig == sorted(elig)
        if elig:
            assert pol.select(q, elig) in elig
        ordered = pol.order(q)
        assert sorted(r.request_id for r in ordered) == list(range(len(q)))
        keys = [pol.sort_key(r, 0, pol.score(r))[:1] for r in ordered]
        assert keys == sorted(keys)

    @given(arrivals=st.lists(st.floats(0.0, 10.0), min_size=1,
                             max_size=12),
           clock=st.floats(0.0, 10.0),
           depth=st.integers(1, 6))
    @settings(max_examples=60, deadline=None)
    def test_shed_invariants(self, arrivals, clock, depth):
        """shed() drops only ARRIVED requests, keeps exactly
        min(arrived, depth) of them, and always the earliest
        deadlines."""
        pol = EDFAdmission(slo_ttft_s=0.5, shed_queue_depth=depth)
        q = _queue(arrivals)
        drop = pol.shed(q, clock)
        assert drop == sorted(set(drop))
        waiting = [i for i, r in enumerate(q) if arrived(r, clock)]
        assert set(drop) <= set(waiting)
        kept = [i for i in waiting if i not in drop]
        assert len(kept) == min(len(waiting), depth)
        if drop:
            worst_kept = max((pol.deadline(q[i]), i) for i in kept)
            best_drop = min((pol.deadline(q[i]), i) for i in drop)
            assert worst_kept < best_drop


# ---------------------------------------------------------------------------
# the other policy objects
# ---------------------------------------------------------------------------


class TestSupportPolicies:
    def test_prefill_schedule_from_knobs_precedence(self):
        assert PrefillSchedule.from_knobs(False, 0, 1).mode == "monolithic"
        s = PrefillSchedule.from_knobs(False, 16, 1)
        assert s.chunked and s.chunk_take(40) == 16 and s.chunk_take(5) == 5
        d = PrefillSchedule.from_knobs(True, 16, 2)      # disagg wins
        assert d.disagg and d.lanes == 2 and d.chunk_take(40) == 40

    def test_warm_seed_inactive_is_zero_copy(self):
        seed = WarmupPressureSeed(False, 2)
        base = [1.0, 2.0]
        assert seed.apply(base) is base          # the raw feed, unaliased
        on = WarmupPressureSeed(True, 2)
        on.note_admission([1], 0.5)
        assert on.apply(base) == [1.0, 2.5]
        on.deactivate()
        assert on.apply(base) is base
        on.note_admission([0], 9.9)              # post-warm-up: ignored
        assert on.extra == [0.0, 0.5]

    def test_replication_pick_and_fire(self):
        pol = ReplicationPolicy(horizon_steps=64)
        pressure = [5.0, 1.0, 3.0]
        assert pol.pick(pressure, [0, 2], [1], [0.0] * 3) == (2, 1)
        assert pol.pick(pressure, [], [1], [0.0] * 3) is None
        assert pol.should_fire(5.0, 1.0, bonus_s=1.0, copy_cost_s=0.5)
        assert not pol.should_fire(5.0, 1.0, bonus_s=0.4, copy_cost_s=0.5)
        assert not pol.should_fire(1.0, 5.0, bonus_s=1.0, copy_cost_s=0.5)

    def test_locality_bonus_zero_without_match(self):
        bonus = LocalityBonus(prefill_s=lambda n: 0.01 * n,
                              write_s=lambda n: 0.001 * n)
        assert bonus(100, 0) == 0.0
        assert bonus(100, 40) == pytest.approx(0.01 * 40 + 0.001 * 40)


# ---------------------------------------------------------------------------
# identity across the three consumers
# ---------------------------------------------------------------------------


class TestSharedObjectIdentity:
    def test_engine_resolves_through_the_factory(self):
        cfg = _parity_cfg()
        eng = Engine(cfg, slots=2, max_ctx=96)
        assert isinstance(eng.admission_policy, FCFSAdmission)
        assert isinstance(eng.prefill_schedule, PrefillSchedule)
        edf = Engine(cfg, slots=2, max_ctx=96, admission="edf",
                     shed_queue_depth=4)
        assert isinstance(edf.admission_policy, EDFAdmission)
        assert edf.admission_policy.shed_queue_depth == 4
        rad = Engine(cfg, slots=2, max_ctx=96, radix_admission=True)
        assert isinstance(rad.admission_policy, RadixAdmission)
        assert rad.admission_on

    def test_scheduler_holds_the_installed_object(self):
        sched = Scheduler(SchedulerConfig(concurrency=4,
                                          bytes_per_token=1024.0))
        assert isinstance(sched.admission, FCFSAdmission)
        pol = EDFAdmission(slo_ttft_s=0.1, shed_queue_depth=2)
        sched.set_admission_policy(pol)
        assert sched.admission is pol            # identity, not a copy
        sched.set_reuse_fn(len)                  # back-compat wrapper
        assert isinstance(sched.admission, RadixAdmission)
        sched.set_reuse_fn(None)
        assert isinstance(sched.admission, FCFSAdmission)

    def test_replay_consumes_the_engines_own_policy(self):
        """replay_engine_timeline must take its admission and prefill
        decisions from the engine instance — not rebuild them — so the
        twins cannot drift.  Witnessed through a sentinel subclass: the
        replay calls THE object the engine holds."""
        calls = []

        class Witness(FCFSAdmission):
            def eligible(self, queue, clock_s):
                calls.append(clock_s)
                return super().eligible(queue, clock_s)

        cfg = _parity_cfg()
        reqs = sharegpt_trace(3, context_len=48, output_len=5, seed=3,
                              arrival_rate=100.0, ctx_jitter=0.0,
                              vocab=cfg.vocab)
        eng = Engine(cfg, slots=2, max_ctx=96, device_buffer=0,
                     overlap=False)
        eng.run(reqs)
        eng.admission_policy = Witness()
        assert not calls
        replay_engine_timeline(eng, reqs)
        assert calls                             # the replay used it

    def test_scheduler_edf_sheds_into_shed_log(self):
        sched = Scheduler(SchedulerConfig(concurrency=1,
                                          bytes_per_token=1024.0))
        sched.set_admission_policy(
            EDFAdmission(slo_ttft_s=0.1, shed_queue_depth=1))
        for r in _queue([0.0, 0.0, 0.0]):
            sched.submit(r)
        admitted = sched.try_admit(now_s=1.0)
        # keep the single earliest deadline (req 0), shed the rest
        assert [r.request_id for r in admitted] == [0]
        assert sorted(r.request_id for r in sched.shed_log) == [1, 2]
        assert not sched.queue


# ---------------------------------------------------------------------------
# admission choice never changes decoded tokens (the PR 10 invariant)
# ---------------------------------------------------------------------------

_TOKEN_CACHE = {}


def _decoded(admission, seed):
    key = (admission, seed)
    if key not in _TOKEN_CACHE:
        cfg = _reduced()
        reqs = sharegpt_trace(4, context_len=48, output_len=5, seed=seed,
                              arrival_rate=50.0, ctx_jitter=0.2,
                              vocab=cfg.vocab)
        eng = Engine(cfg, slots=2, max_ctx=96, seed=0,
                     admission=admission, radix_admission=True)
        out = eng.run(reqs)
        assert out["n_done"] == 4
        _TOKEN_CACHE[key] = {r.request_id: [int(t) for t in r.out_tokens]
                             for r in reqs}
    return _TOKEN_CACHE[key]


def test_admission_bit_identity_smoke():
    """Deterministic twin of the property below (runs where hypothesis
    is absent): one seed through all three policies."""
    for admission in ("radix", "edf"):
        assert _decoded(admission, 11) == _decoded("fcfs", 11), admission


@given(admission=st.sampled_from(["radix", "edf"]),
       seed=st.sampled_from([11, 12]))
@settings(max_examples=4, deadline=None)
def test_admission_choice_never_changes_decoded_tokens(admission, seed):
    """{fcfs, radix, edf} on the same trace: identical decoded streams
    per request.  Ordering requests into slots is pure timing — prefill
    recomputes the full prompt in-graph, so no request's own stream can
    depend on its neighbours' schedule."""
    assert _decoded(admission, seed) == _decoded("fcfs", seed)


# ---------------------------------------------------------------------------
# EDF load shedding end to end: engine, replay, simulator
# ---------------------------------------------------------------------------


def test_engine_and_replay_shed_the_same_requests():
    """A burst beyond shed_queue_depth: the engine sheds, the analytic
    replay of that same engine sheds the SAME requests (it consumes
    eng.admission_policy), survivors' timelines still agree to float
    precision, and summarize() never counts the shed."""
    cfg = _parity_cfg(slo_ttft_s=0.05)
    reqs = sharegpt_trace(8, context_len=64, output_len=10, seed=7,
                          ctx_jitter=0.2, vocab=cfg.vocab)
    for r in reqs[6:]:
        r.arrival_s = 1e5      # a second wave long after the first drains
    eng = Engine(cfg, slots=2, max_ctx=160, device_buffer=0, seed=0,
                 overlap=False, admission="edf", shed_queue_depth=2)
    out = eng.run(reqs)
    shed_ids = sorted(r.request_id for r in eng.shed)
    # wave 1: six arrived at t=0 against depth 2 -> shed four; wave 2
    # stays within depth and is served normally after the idle jump
    assert len(shed_ids) == 4 and max(shed_ids) < 6
    assert out["shed_requests"] == len(shed_ids)
    assert out["n_done"] == 8 - len(shed_ids)    # summarize excludes shed
    assert reqs[6].finish_s > 1e5                # wave 2 was not shed
    for r in eng.shed:
        assert r.finish_s < 0 and not r.out_tokens

    rep = replay_engine_timeline(eng, reqs)
    rep_by_id = {r.request_id: r for r in rep}
    for r in reqs:
        q = rep_by_id[r.request_id]
        if r.request_id in shed_ids:
            assert q.finish_s < 0, r.request_id  # replay shed it too
        else:
            assert abs(r.dispatch_s - q.dispatch_s) < 1e-9
            assert abs(r.first_token_s - q.first_token_s) < 1e-9
            assert abs(r.finish_s - q.finish_s) < 1e-9


def test_sim_edf_sheds_under_burst_and_terminates():
    """The scheduler-driven simulator honours the same policy object
    family: a burst beyond shed_queue_depth sheds, the run still
    drains, and shed requests are excluded from the summary."""
    model = profile_from_config(get_config("deepseek-v32"))
    b = default_backends()["cxl"]
    # a pure t=0 burst: 32 arrived against depth 4 -> the first wave
    # keeps the 4 earliest deadlines and sheds the backlog
    reqs = sharegpt_trace(32, context_len=16384, output_len=48, seed=4)
    out = simulate(reqs, model, b,
                   SimConfig(concurrency=4, admission="edf",
                             slo_ttft_s=0.05, shed_queue_depth=4))
    assert out["shed_requests"] > 0
    assert out["n_done"] == 32 - out["shed_requests"]
    # no shedding when the backlog stays within depth
    calm = simulate([dataclasses.replace(r) for r in reqs], model, b,
                    SimConfig(concurrency=48, admission="edf",
                              slo_ttft_s=0.05, shed_queue_depth=48))
    assert calm["shed_requests"] == 0 and calm["n_done"] == 32
