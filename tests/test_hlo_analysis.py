"""Trip-count-aware HLO analyzer: ground truth on synthetic programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.hlo_analysis import hlo_metrics


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_trip_multiplied():
    N, L = 128, 7

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    comp = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                    jax.ShapeDtypeStruct((L, N, N), jnp.float32))
    m = hlo_metrics(comp.as_text())
    expect = 2 * N ** 3 * L
    assert abs(m["flops"] - expect) / expect < 0.05, m["flops"]


def test_nested_scan_flops():
    N, L1, L2 = 64, 3, 5

    def f(x, w):
        def outer(c, wi):
            def inner(c2, wj):
                return jnp.tanh(c2 @ wj), None
            return jax.lax.scan(inner, c, wi)[0], None
        return jax.lax.scan(outer, x, w)[0]

    comp = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                    jax.ShapeDtypeStruct((L1, L2, N, N), jnp.float32))
    m = hlo_metrics(comp.as_text())
    expect = 2 * N ** 3 * L1 * L2
    assert abs(m["flops"] - expect) / expect < 0.05, m["flops"]


def test_single_dot_flops_and_bytes():
    M, K, N = 64, 128, 32

    def f(a, b):
        return a @ b

    comp = _compile(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                    jax.ShapeDtypeStruct((K, N), jnp.float32))
    m = hlo_metrics(comp.as_text())
    assert m["flops"] == 2 * M * K * N
    expect_bytes = 4 * (M * K + K * N + M * N)
    assert m["bytes"] >= expect_bytes
    assert m["bytes"] < 3 * expect_bytes


def test_no_collectives_single_device():
    comp = _compile(lambda x: x * 2 + 1,
                    jax.ShapeDtypeStruct((32,), jnp.float32))
    m = hlo_metrics(comp.as_text())
    assert m["collective_bytes"] == 0
    assert m["collective_counts"] == {}
