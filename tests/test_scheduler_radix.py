"""Scheduler + radix cache property tests (hypothesis)."""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.serving.radix import RadixIndex
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler, SchedulerConfig


def _req(i, ctx=100, out=10):
    return Request(i, 0.0, ctx, out)


def test_interleave_round_robin():
    cfg = SchedulerConfig(concurrency=8, n_pool_devices=4, interleave=True,
                          pool_device_bytes=1e12, bytes_per_token=1.0)
    s = Scheduler(cfg)
    for i in range(8):
        s.submit(_req(i))
    admitted = s.try_admit(0.0)
    assert [r.pool_device for r in admitted] == [0, 1, 2, 3, 0, 1, 2, 3]
    assert s.max_imbalance() == 0


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_scheduler_invariants(data):
    """Capacity never exceeded; interleave bounds imbalance; FCFS order."""
    n_dev = data.draw(st.integers(1, 4))
    conc = data.draw(st.integers(1, 16))
    cap = data.draw(st.sampled_from([1e3, 1e4, 1e5]))
    cfg = SchedulerConfig(concurrency=conc, n_pool_devices=n_dev,
                          interleave=True, pool_device_bytes=cap,
                          bytes_per_token=1.0)
    s = Scheduler(cfg)
    nxt = 0
    for step in range(20):
        n_new = data.draw(st.integers(0, 4))
        for _ in range(n_new):
            s.submit(_req(nxt, ctx=data.draw(st.integers(10, 400))))
            nxt += 1
        admitted = s.try_admit(float(step))
        # invariants: concurrency cap, per-device capacity, accounting
        assert len(s.active) <= conc
        for dev_bytes in s.device_bytes:
            assert -1e-9 <= dev_bytes <= cap + 1e-9
        booked = sum(s.device_bytes)
        held = sum((r.context_len + r.output_len) for r in s.active.values())
        assert abs(booked - held) < 1e-6
        # random finishes
        for rid in list(s.active):
            if data.draw(st.booleans()):
                s.finish(s.active[rid])
    assert all(b >= -1e-9 for b in s.device_bytes)


def test_interleave_imbalance_bounded_without_finishes():
    """Admission-only: round-robin keeps per-device load imbalance <= 1
    (the paper's link-balancing property)."""
    cfg = SchedulerConfig(concurrency=64, n_pool_devices=3, interleave=True,
                          pool_device_bytes=1e12, bytes_per_token=1.0)
    s = Scheduler(cfg)
    for i in range(50):
        s.submit(_req(i, ctx=10 + i % 7))
    s.try_admit(0.0)
    assert s.max_imbalance() <= 1


def test_radix_prefix_match_and_split():
    r = RadixIndex(page_size=4)
    r.insert([1, 2, 3, 4, 5, 6, 7, 8], device=0, pages=[0, 1])
    n, pages = r.match_prefix([1, 2, 3, 4, 5, 6, 7, 8, 9, 9])
    assert n == 8 and pages[0][1] == [0, 1]
    # diverging suffix splits the edge
    r.insert([1, 2, 3, 4, 9, 9, 9, 9], device=1, pages=[7, 8])
    n2, pages2 = r.match_prefix([1, 2, 3, 4, 9, 9, 9, 9])
    assert n2 == 8 and pages2[-1][1] == [7, 8]
    n3, _ = r.match_prefix([1, 2, 3, 4, 5, 6, 7, 8])
    assert n3 == 8
    n4, _ = r.match_prefix([2, 2])
    assert n4 == 0


def test_radix_pin_blocks_eviction():
    r = RadixIndex(page_size=2)
    r.insert([1, 2, 3, 4], device=0, pages=[0, 1])
    r.pin([1, 2, 3, 4])
    assert r.evict_lru(4) == []          # pinned: nothing evictable
    r.release([1, 2, 3, 4])
    freed = r.evict_lru(4)
    assert freed and freed[0][1] == [0, 1]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.integers(0, 3), min_size=4, max_size=12),
                min_size=1, max_size=8))
def test_radix_property_match_is_prefix(seqs):
    """Whatever was inserted, match_prefix returns a length that is a
    valid prefix length and never exceeds the query."""
    r = RadixIndex(page_size=2)
    for i, s in enumerate(seqs):
        aligned = s[: len(s) // 2 * 2]
        if aligned:
            r.insert(aligned, device=0, pages=list(range(len(aligned) // 2)))
    for s in seqs:
        n, _ = r.match_prefix(s)
        assert 0 <= n <= len(s)
        if n:
            n2, _ = r.match_prefix(s[:n])
            assert n2 == n
