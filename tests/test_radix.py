"""Radix prefix cache lifecycle (PR 5, serving/radix.py + core/sac.py).

The correctness property this suite guards: **the index never returns a
(device, pages) tuple the PoolAllocator considers free**, under any
interleaving of admit / finish(retain) / evict — the pre-PR 5 engine
inserted fabricated page ids, never purged freed pages, and never called
pin/release/evict (unbounded growth, dead refcounting).

Sections:
  - RadixIndex unit semantics: token-granular match with page-granular
    credit, insert-dedupe and real-page registration, split-inherited
    refcounts, eviction cleanup (no leaked split nodes), invalidation;
  - replica semantics (PR 6): add_replica + MatchResult.copies, device
    eviction preferring replicas, primary demotion + promotion, the
    replica-map interleaving property (owner/replica maps consistent,
    never a double-free);
  - SACSystem page lifecycle: retention at release, eviction returning
    pages to the allocator, placement-pressure eviction, accounting
    consistency (placer == allocator == index views), replication and
    refcounted dedup accounting (PR 6: shared pages, sticky pages on
    owner departure, orphan reclamation);
  - the hypothesis interleaving property (stale pages, bounded nodes),
    extended with replicate/dedup ops;
  - engine regressions: requeue on pool exhaustion, page-granular hit
    credit, radix on/off bit-identity, the locality win, and the PR 6
    features (bit-identity with replication/dedup/admission on, dedup
    lifecycle drain, forced-pressure replication).
"""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.sac import SACSystem
from repro.serving.radix import RadixIndex
from repro.serving.request import Request, shared_prefix_trace


# ---------------------------------------------------------------------------
# RadixIndex unit semantics
# ---------------------------------------------------------------------------


def test_match_is_token_granular_but_credit_is_page_granular():
    """A prefix diverging MID-EDGE still matches (no split needed), but
    the credited reuse rounds down to whole pages."""
    r = RadixIndex(page_size=4)
    r.insert([1, 2, 3, 4, 5, 6, 7, 8], device=1, pages=[10, 11])
    # 6 shared tokens, diverging inside the edge: paged credit = 4
    m = r.match([1, 2, 3, 4, 5, 6, 99, 99])
    assert m.tokens == 6
    assert m.paged_tokens == 4
    assert m.device == 1 and m.pages == [10]
    # the backing node sits deeper than the match: pin ITS path
    assert m.pin_tokens == (1, 2, 3, 4, 5, 6, 7, 8)
    # full match returns the whole page list
    m2 = r.match([1, 2, 3, 4, 5, 6, 7, 8])
    assert m2.paged_tokens == 8 and m2.pages == [10, 11]


def test_match_through_pageless_split_uses_descendant_backing():
    """After a split, the mid node carries no pages; a query ending at
    the mid must still be credited from a paged descendant's leading
    page slice."""
    r = RadixIndex(page_size=4)
    r.insert([1, 2, 3, 4, 5, 6, 7, 8], device=0, pages=[0, 1])
    r.insert([1, 2, 3, 4, 9, 9, 9, 9], device=1, pages=[7, 8])
    m = r.match([1, 2, 3, 4])             # exactly the split point
    assert m.paged_tokens == 4
    assert (m.device, m.pages) in [(0, [0]), (1, [7])]
    # the raw tuple API never credits the page-less mid as paged reuse
    n, paged = r.match_prefix([1, 2, 3, 4])
    assert n == 4 and paged == []


def test_insert_registers_real_pages_and_dedupes():
    r = RadixIndex(page_size=2)
    assert r.insert([5, 6, 7, 8], device=0, pages=[100, 101]) == 2
    assert r.owns(0, 100) and r.owns(0, 101)
    # identical prefix: first copy wins, caller keeps its pages
    assert r.insert([5, 6, 7, 8], device=1, pages=[200, 201]) == 0
    assert not r.owns(1, 200)
    m = r.match([5, 6, 7, 8])
    assert m.device == 0 and m.pages == [100, 101]


def test_pin_blocks_eviction_and_split_inherits_refs():
    r = RadixIndex(page_size=2)
    r.insert([1, 2, 3, 4], device=0, pages=[0, 1])
    r.pin([1, 2, 3, 4])
    assert r.evict_lru(4) == []          # pinned: nothing evictable
    # a split UNDER the pin must keep the pinned path protected
    r.insert([1, 2, 9, 9], device=0, pages=[5, 6])
    mid = r.root.children[1]
    assert mid.refs == 1                 # inherited at split
    assert all(f == (0, [5, 6]) for f in r.evict_lru(8))  # only unpinned
    r.release([1, 2, 3, 4])
    freed = r.evict_lru(4)
    assert freed and freed[0] == (0, [0, 1])
    assert r.n_nodes() == 0              # tree collapsed, no debris


def test_evict_cleans_childless_pageless_split_nodes():
    """Satellite: the pre-PR 5 evict_lru left the page-less mid node
    behind after its last leaf was evicted — node count must collapse."""
    r = RadixIndex(page_size=2)
    r.insert([1, 2, 3, 4], device=0, pages=[0, 1])
    r.insert([1, 2, 8, 8], device=0, pages=[2, 3])   # splits at depth 2
    assert r.n_nodes() == 3
    freed = r.evict_lru(2)
    assert sorted(p for _, pg in freed for p in pg) == [0, 1, 2, 3]
    assert r.n_nodes() == 0, "split mid node leaked"


def test_evict_remerges_single_child_mid():
    """Evicting ONE branch of a split leaves a page-less unary mid —
    it must fold into its surviving child (radix property restored)."""
    r = RadixIndex(page_size=2)
    r.insert([1, 2, 3, 4], device=0, pages=[0, 1])
    r.insert([1, 2, 8, 8], device=0, pages=[2, 3])
    # make the [1,2,8,8] branch LRU and evict exactly one leaf
    r.match([1, 2, 3, 4])
    assert r.evict_lru(1) == [(0, [2, 3])]
    assert r.n_nodes() == 1              # mid + survivor merged
    m = r.match([1, 2, 3, 4])
    assert m.paged_tokens == 4 and m.pages == [0, 1]


def test_invalidate_pages_purges_and_cleans():
    r = RadixIndex(page_size=2)
    r.insert([1, 2, 3, 4], device=0, pages=[0, 1])
    r.insert([1, 2, 3, 4, 5, 6], device=0, pages=[4, 5, 6])
    assert r.invalidate_pages(0, [5]) == 1      # one page kills the node
    assert not r.owns(0, 4) and not r.owns(0, 6)
    assert r.match([1, 2, 3, 4, 5, 6]).paged_tokens == 4  # parent survives
    assert r.invalidate_pages(0, [0]) == 1
    assert r.match([1, 2, 3, 4]).paged_tokens == 0
    assert r.n_nodes() == 0
    assert r.invalidate_pages(0, [0, 1, 99]) == 0  # idempotent / unknown


# ---------------------------------------------------------------------------
# replica semantics (PR 6)
# ---------------------------------------------------------------------------


def test_add_replica_reports_copies_and_keeps_primary():
    r = RadixIndex(page_size=2)
    toks = [1, 2, 3, 4]
    r.insert(toks, device=0, pages=[0, 1])
    assert r.add_replica(toks, device=1, pages=[7, 8]) == 2
    m = r.match(toks)
    assert m.device == 0 and m.pages == [0, 1]          # primary slice
    assert m.copies == {0: [0, 1], 1: [7, 8]}
    assert r.owns(1, 7) and r.owns(1, 8)
    assert r.replica_pages(1) == 2
    # a second copy on the same device, a wrong page count, or an
    # uncached prefix are all refused (caller keeps its pages)
    assert r.add_replica(toks, device=1, pages=[9, 10]) == 0
    assert r.add_replica(toks, device=2, pages=[9]) == 0
    assert r.add_replica([9, 9, 9, 9], device=2, pages=[9, 10]) == 0


def test_device_evict_drops_replica_before_primary():
    r = RadixIndex(page_size=2)
    toks = [1, 2, 3, 4]
    r.insert(toks, device=0, pages=[0, 1])
    r.add_replica(toks, device=1, pages=[7, 8])
    freed = r.evict_lru(1, device=1)
    assert freed == [(1, [7, 8])]                       # replica went first
    m = r.match(toks)
    assert m.copies == {0: [0, 1]}                      # primary intact
    assert not r.owns(1, 7)


def test_primary_eviction_demotes_and_promotes_replica():
    """A device-restricted eviction of the primary frees its pages but
    keeps the prefix matchable: the hottest replica becomes primary."""
    r = RadixIndex(page_size=2)
    toks = [1, 2, 3, 4]
    r.insert(toks, device=0, pages=[0, 1])
    r.add_replica(toks, device=1, pages=[7, 8])
    freed = r.evict_lru(2, device=0)
    assert freed == [(0, [0, 1])]
    m = r.match(toks)
    assert m.hit and m.device == 1 and m.pages == [7, 8]
    assert m.copies == {1: [7, 8]}
    assert r.replica_pages() == 0                       # promoted, not copy


def test_invalidate_replica_page_keeps_primary():
    r = RadixIndex(page_size=2)
    toks = [1, 2, 3, 4]
    r.insert(toks, device=0, pages=[0, 1])
    r.add_replica(toks, device=1, pages=[7, 8])
    assert r.invalidate_pages(1, [7]) >= 1
    m = r.match(toks)
    assert m.device == 0 and m.copies == {0: [0, 1]}
    assert not r.owns(1, 8)                             # whole copy purged


def test_invalidate_primary_page_promotes_replica():
    r = RadixIndex(page_size=2)
    toks = [1, 2, 3, 4]
    r.insert(toks, device=0, pages=[0, 1])
    r.add_replica(toks, device=1, pages=[7, 8])
    assert r.invalidate_pages(0, [0]) >= 1
    m = r.match(toks)
    assert m.hit and m.device == 1 and m.pages == [7, 8]
    assert not r.owns(0, 1)


def _replica_views(r):
    """Every (device, page) each node claims, walked structurally."""
    claimed = []
    for n in r._all_nodes():
        if n.pages:
            claimed.extend((n.device, p) for p in n.pages)
        for dev, pgs in n.replicas.items():
            claimed.extend((dev, p) for p in pgs)
    return claimed


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_replica_maps_consistent_under_any_interleaving(data):
    """PR 6 satellite: under ANY interleaving of insert / add_replica /
    device-evict / global-evict / invalidate, the owner map and the
    per-node replica sets agree structurally, no page is ever claimed
    by two copies, and no page is freed twice."""
    r = RadixIndex(page_size=2)
    next_page = [0]
    freed_ever = set()
    paths = []

    def fresh(n):
        start = next_page[0]
        next_page[0] += n
        return list(range(start, start + n))

    for _ in range(30):
        op = data.draw(st.sampled_from(
            ["insert", "replicate", "evict_dev", "evict", "invalidate"]))
        if op == "insert":
            n_pg = data.draw(st.integers(1, 3))
            toks = [data.draw(st.integers(0, 2)) for _ in range(2 * n_pg)]
            dev = data.draw(st.integers(0, 2))
            if r.insert(toks, dev, fresh(n_pg)):
                paths.append(tuple(toks))
        elif op == "replicate" and paths:
            toks = list(data.draw(st.sampled_from(paths)))
            m = r.match(toks)
            if m.hit:
                dev = data.draw(st.integers(0, 2))
                if dev not in m.copies:
                    r.add_replica(list(m.pin_tokens), dev,
                                  fresh(len(m.copies[m.device])))
        elif op == "evict_dev":
            freed = r.evict_lru(data.draw(st.integers(1, 2)),
                                device=data.draw(st.integers(0, 2)))
            for dev, pgs in freed:
                for p in pgs:
                    assert (dev, p) not in freed_ever, "double free"
                    freed_ever.add((dev, p))
        elif op == "evict":
            for dev, pgs in r.evict_lru(data.draw(st.integers(1, 2))):
                for p in pgs:
                    assert (dev, p) not in freed_ever, "double free"
                    freed_ever.add((dev, p))
        elif op == "invalidate" and paths:
            # invalidate one page of a random live copy (the sac layer
            # does this when a pool page is reclaimed)
            claimed = _replica_views(r)
            if claimed:
                dev, page = data.draw(st.sampled_from(claimed))
                r.invalidate_pages(dev, [page])
        # structural agreement: the union of every node's primary +
        # replica claims IS the owner map, with no duplicate claims
        claimed = _replica_views(r)
        assert len(claimed) == len(set(claimed)), "page claimed twice"
        assert set(claimed) == set(r.cached_pages())
        assert not (set(claimed) & freed_ever), "freed page still cached"
        # every match agrees with the maps
        for toks in paths:
            m = r.match(list(toks))
            if m.hit:
                for dev, pgs in m.copies.items():
                    assert all(r.owns(dev, p) for p in pgs)
    # drain completely: everything freed exactly once
    while r.evict_lru(4):
        pass
    assert r.n_nodes() == 0
    assert not r.cached_pages()


# ---------------------------------------------------------------------------
# SACSystem page lifecycle
# ---------------------------------------------------------------------------


def _system(n_dev=2, pages_per_dev=24):
    cfg = get_config("qwen2-1.5b").reduced()
    probe = SACSystem(cfg, n_pool_devices=1)       # page_bytes only
    sac = SACSystem(cfg, n_pool_devices=n_dev,
                    device_bytes=pages_per_dev * probe.page_bytes,
                    placement="first_fit")
    radix = RadixIndex(page_size=cfg.sac.page_size)
    sac.attach_radix(radix)
    return sac, radix, cfg


def _page_free(sac, dev, page):
    return (page >= sac.allocator._next[dev]
            or page in sac.allocator._returned[dev])


def _assert_consistent(sac, radix):
    """The three views agree: no index page is allocator-free; the
    placer's page occupancy equals live bookings (minus pages BORROWED
    from the cache via dedup — those are booked to the cache, not the
    request) + cache-held pages + orphaned shared pages."""
    for (dev, page) in radix.cached_pages():
        assert not _page_free(sac, dev, page), (dev, page)
    for d in range(sac.n_devices):
        live = sum(len(rp.pages) for rp in sac.requests.values()
                   if rp.device == d)
        borrowed = sum(len(sac._shared_pages.get(rid, []))
                       for rid, rp in sac.requests.items()
                       if rp.device == d)
        held = sac.radix_held_pages(d)
        orphaned = len(sac._orphaned[d])
        want = live - borrowed + held + orphaned
        assert sac.placer.pages_used[d] == want, \
            (d, sac.placer.pages_used[d], live, borrowed, held, orphaned)
        in_alloc = (sac.allocator.pages_per_device
                    - sac.allocator.free_pages(d))
        assert in_alloc == want, (d, in_alloc, live, borrowed, held,
                                  orphaned)


def _admit(sac, radix, rid, tokens, out_tokens=0, dedup=False):
    """The engine's _fill_slots lifecycle, jax-free: match+pin, place,
    (optionally) dedup against a same-device copy, insert real pages,
    pin own path.  Returns (pins, keep) or None."""
    ps = radix.page_size
    m = radix.match(tokens)
    pins = []
    if m.hit:
        pins.append(list(m.pin_tokens))
        radix.pin(pins[-1])
    rp = sac.place(rid, len(tokens) + out_tokens,
                   affinity=m.device if m.hit else None)
    if rp is None:
        for p in pins:
            radix.release(p)
        return None
    dedup_n = 0
    if dedup and m.hit and rp.device in m.copies:
        shared = m.copies[rp.device][: m.paged_tokens // ps]
        dedup_n = sac.dedup_match(rid, shared)
    aligned = len(tokens) // ps * ps
    keep = 0
    if aligned and not dedup_n:
        own = list(tokens[:aligned])
        keep = radix.insert(own, rp.device, rp.pages[:aligned // ps])
        radix.pin(own)
        pins.append(own)
    return pins, keep


def _finish(sac, radix, rid, pins, keep, headroom=0.0):
    for p in pins:
        radix.release(p)
    sac.release(rid, keep_pages=keep)
    if headroom:
        sac.evict_to_headroom(headroom)


def test_release_retention_and_evict_roundtrip():
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=64)
    ps = cfg.sac.page_size
    toks = list(range(4 * ps))
    pins, keep = _admit(sac, radix, 0, toks)
    assert keep == 4
    _assert_consistent(sac, radix)
    _finish(sac, radix, 0, pins, keep)
    # retained: pages stay allocated, owned by the cache
    assert sac.radix_held_pages(0) == 4
    assert radix.match(toks).paged_tokens == 4 * ps
    _assert_consistent(sac, radix)
    # eviction hands them back to the allocator and forgets the prefix
    assert sac.radix_evict(1) == 4
    assert sac.radix_held_pages(0) == 0
    assert radix.match(toks).paged_tokens == 0
    _assert_consistent(sac, radix)


def test_place_evicts_cached_prefixes_under_pool_pressure():
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=8)
    ps = cfg.sac.page_size
    a = list(range(100, 100 + 4 * ps))
    pins, keep = _admit(sac, radix, 0, a)
    _finish(sac, radix, 0, pins, keep)       # 4 pages cache-held
    assert sac.radix_held_pages(0) == 4
    # a 6-page request only fits if the cache gives pages back
    got = _admit(sac, radix, 1, list(range(6 * ps)))
    assert got is not None
    assert sac.radix_held_pages(0) == 0
    assert radix.match(a).paged_tokens == 0  # prefix gone, not stale
    _assert_consistent(sac, radix)


def test_pinned_prefix_survives_pool_pressure_eviction():
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=8)
    ps = cfg.sac.page_size
    a = list(range(100, 100 + 4 * ps))
    pins_a, keep_a = _admit(sac, radix, 0, a)        # live + pinned
    # a second request that would need the pinned pages must fail
    # (placement refuses rather than evicting a pinned prefix)
    assert _admit(sac, radix, 1, list(range(6 * ps))) is None
    assert radix.match(a).paged_tokens == 4 * ps
    _assert_consistent(sac, radix)
    _finish(sac, radix, 0, pins_a, keep_a)
    assert _admit(sac, radix, 1, list(range(6 * ps))) is not None
    _assert_consistent(sac, radix)


def test_place_eviction_targets_the_blocked_device_only():
    """Pool-pressure eviction must not drain healthy devices' caches: a
    request that only device 0's cache pages can unblock evicts there,
    even when device 1 holds the globally-coldest prefix."""
    sac, radix, cfg = _system(n_dev=2, pages_per_dev=8)
    ps = cfg.sac.page_size
    a = list(range(100, 100 + 6 * ps))          # -> device 0 (first_fit)
    pins, keep = _admit(sac, radix, 0, a)
    _finish(sac, radix, 0, pins, keep)          # 6 pages cached on d0
    b = list(range(200, 200 + 2 * ps))          # fits d0 beside the cache?
    pins, keep = _admit(sac, radix, 1, b)       # 6+2=8: d0 exactly full
    _finish(sac, radix, 1, pins, keep)          # now d0: 8 cached
    # make d0's prefix the HOTTER one (d1's copy would be LRU)
    sac2_prefix = list(range(300, 300 + 3 * ps))
    pins, keep = _admit(sac, radix, 2, sac2_prefix)   # -> d1 (d0 full)
    _finish(sac, radix, 2, pins, keep)          # 3 pages cached on d1
    radix.match(a)                              # d0 copies most recent
    radix.match(b)
    held_d1 = sac.radix_held_pages(1)
    # a 4-page request: d1 has 5 free pages -> placed there WITHOUT
    # touching anyone's cache; then a 6-page request can only fit on d0
    # by evicting d0's cache — d1's (colder!) cache must survive
    got = _admit(sac, radix, 3, list(range(400, 400 + 4 * ps)))
    assert got is not None
    big = _admit(sac, radix, 4, list(range(500, 500 + 6 * ps)))
    assert big is not None
    assert sac.radix_held_pages(1) == held_d1, \
        "healthy device's cache was drained"
    _assert_consistent(sac, radix)


def test_place_eviction_survives_live_backed_lru_victim():
    """A victim whose pages are live-request-backed (inserted, never
    retained) frees nothing — eviction must keep going to the next
    victim instead of reporting the pool as full."""
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=12)
    ps = cfg.sac.page_size
    # cached prefix (cache-owned, 4 pages), touched recently
    a = list(range(100, 100 + 4 * ps))
    pins, keep = _admit(sac, radix, 0, a)
    _finish(sac, radix, 0, pins, keep)
    # live request whose node is UNPINNED and LRU (insert w/o pin)
    b = list(range(200, 200 + 4 * ps))
    rp = sac.place(1, len(b))
    b_keep = radix.insert(b, rp.device, rp.pages[:4])
    assert b_keep == 4
    radix.match(a)                               # cache copy is hotter
    # a 5-page request: 4 (cache) + 4 (live b) + 5 = 13 > 12 -> must
    # evict.  LRU victim is b's node (live-backed, frees 0 pages) — the
    # loop must continue to a's cache pages rather than give up.
    got = _admit(sac, radix, 2, list(range(300, 300 + 5 * ps)))
    assert got is not None
    assert sac.radix_held_pages(0) == 0          # cache reclaimed
    _assert_consistent(sac, radix)
    # b's pages stayed allocated to the live request
    assert len(sac.requests[1].pages) == 4


def test_place_eviction_feasibility_excludes_pinned_pages():
    """If draining the UNPINNED cache still cannot fit the request, the
    unpinned prefixes must survive — counting pinned (unevictable)
    pages in the feasibility guard would drain them for nothing."""
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=16)
    ps = cfg.sac.page_size
    a = list(range(100, 100 + 4 * ps))
    pins, keep = _admit(sac, radix, 0, a)
    _finish(sac, radix, 0, pins, keep)          # A: 4 cached pages
    # live request reusing A: pins A's backing path for its lifetime
    live = _admit(sac, radix, 1, a + list(range(900, 900 + 4 * ps)))
    assert live is not None                     # 8 pages, A now pinned
    b = list(range(200, 200 + 4 * ps))
    pins, keep = _admit(sac, radix, 2, b)
    _finish(sac, radix, 2, pins, keep)          # B: 4 cached, unpinned
    held = sac.radix_held_pages(0)              # 8 (A + B)
    # 5-page request: even with B's 4 evictable pages gone, 12 + 5 > 16
    # — infeasible, so B must NOT be sacrificed
    assert _admit(sac, radix, 3, list(range(300, 300 + 5 * ps))) is None
    assert sac.radix_held_pages(0) == held, \
        "unpinned cache drained for an unplaceable request"
    assert radix.match(b).paged_tokens == 4 * ps
    _assert_consistent(sac, radix)


def test_headroom_eviction_is_per_device():
    sac, radix, cfg = _system(n_dev=2, pages_per_dev=8)
    ps = cfg.sac.page_size
    pins, keep = _admit(sac, radix, 0, list(range(100, 100 + 6 * ps)))
    _finish(sac, radix, 0, pins, keep)           # d0: 6/8 cached
    pins, keep = _admit(sac, radix, 1, list(range(200, 200 + 2 * ps)))
    _finish(sac, radix, 1, pins, keep)           # d0: 8/8 cached
    pins, keep = _admit(sac, radix, 2, list(range(300, 300 + 2 * ps)))
    _finish(sac, radix, 2, pins, keep)           # d1: 2/8 cached (cold-er)
    freed = sac.evict_to_headroom(0.25)          # d0 needs 2 free pages
    assert freed >= 2
    assert sac.allocator.free_pages(0) >= 2
    assert sac.radix_held_pages(1) == 2, \
        "headroom relief drained the unpressured device"
    _assert_consistent(sac, radix)


def test_release_without_retention_purges_index():
    """keep_pages=0 frees everything the request registered — the index
    must drop the nodes in the same motion (the pre-PR 5 stale-page
    bug: freed pool memory advertised as cached)."""
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=32)
    ps = cfg.sac.page_size
    toks = list(range(3 * ps))
    pins, keep = _admit(sac, radix, 0, toks)
    assert keep == 3
    _finish(sac, radix, 0, pins, 0)          # caller retains nothing
    assert radix.match(toks).paged_tokens == 0
    assert sac.radix_held_pages() == 0
    _assert_consistent(sac, radix)


# ---------------------------------------------------------------------------
# SACSystem replication + dedup accounting (PR 6)
# ---------------------------------------------------------------------------


def test_replicate_prefix_books_copy_to_cache():
    """replicate_prefix allocates on the destination, registers the
    replica with the index, books the pages to the cache (placer truth
    per copy), and charges the copy traffic."""
    sac, radix, cfg = _system(n_dev=2, pages_per_dev=16)
    ps = cfg.sac.page_size
    toks = list(range(4 * ps))
    pins, keep = _admit(sac, radix, 0, toks)
    _finish(sac, radix, 0, pins, keep)           # 4 pages cached on d0
    fetched0 = sac.traffic.stats.bytes_fetched
    m = radix.match(toks)
    took = sac.replicate_prefix(list(m.pin_tokens), m.copies[m.device],
                                m.device, 1 - m.device)
    assert took == 4
    assert sac.replicated_pages == 4
    assert sac.radix_held_pages(0) == 4 and sac.radix_held_pages(1) == 4
    assert sac.traffic.stats.bytes_fetched > fetched0   # copy charged
    m2 = radix.match(toks)
    assert sorted(m2.copies) == [0, 1]
    _assert_consistent(sac, radix)
    # a second copy to the same device is refused, nothing leaks
    assert sac.replicate_prefix(list(m2.pin_tokens),
                                m2.copies[m2.device], 0, 1) == 0
    _assert_consistent(sac, radix)


def test_dedup_shares_pages_and_shrinks_booking():
    """A same-device match with dedup borrows the cached pages: the
    slot's booking shrinks by the shared pages, the allocator frees the
    private copies, and release returns only the private tail."""
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=32)
    ps = cfg.sac.page_size
    prefix = list(range(4 * ps))
    pins, keep = _admit(sac, radix, 0, prefix)
    _finish(sac, radix, 0, pins, keep)           # 4 pages cache-held
    used_before = sac.placer.pages_used[0]
    got = _admit(sac, radix, 1, prefix + [77] * ps, dedup=True)
    assert got is not None
    assert sac.dedup_shared_pages == 4
    assert len(sac._shared_pages[1]) == 4
    # booking: only the non-shared tail page is new occupancy (5 placed,
    # 4 returned to the allocator as the shared copies replace them)
    assert sac.placer.pages_used[0] == used_before + 1
    _assert_consistent(sac, radix)
    _finish(sac, radix, 1, *got)
    assert sac._shared_refs == {}
    assert all(not s for s in sac._orphaned)
    assert sac.radix_held_pages(0) == 4          # cache copy untouched
    assert radix.match(prefix).paged_tokens == 4 * ps
    _assert_consistent(sac, radix)


def test_owner_departure_never_frees_pages_shared_by_another_slot():
    """Satellite (release accounting): request A's pages are dedup-
    shared by B; A departs first.  The shared pages must survive until
    B's last reference drops — freeing them would hand B's decode reads
    to the allocator."""
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=32)
    ps = cfg.sac.page_size
    prefix = list(range(4 * ps))
    # A admits and stays LIVE; its insert registers its own pages
    got_a = _admit(sac, radix, 0, prefix)
    assert got_a is not None
    # B dedups against A's live-inserted pages (same device)
    got_b = _admit(sac, radix, 1, prefix, dedup=True)
    assert got_b is not None and sac.dedup_shared_pages == 4
    shared = list(sac._shared_pages[1])
    # A departs retaining NOTHING — but the shared pages must not free
    _finish(sac, radix, 0, got_a[0], 0)
    for p in shared:
        assert not _page_free(sac, 0, p), "shared page freed under B"
    _assert_consistent(sac, radix)
    # B departs: last reference — now they free (directly or as orphans)
    _finish(sac, radix, 1, *got_b)
    assert sac._shared_refs == {}
    assert all(not s for s in sac._orphaned)
    _assert_consistent(sac, radix)


def test_reclaim_under_pressure_orphans_shared_pages():
    """Pool-pressure eviction over a cache copy whose pages are dedup-
    borrowed must orphan them (freed when the borrower departs), not
    hand them to the allocator while a slot still reads them."""
    sac, radix, cfg = _system(n_dev=1, pages_per_dev=12)
    ps = cfg.sac.page_size
    prefix = list(range(4 * ps))
    pins, keep = _admit(sac, radix, 0, prefix)
    _finish(sac, radix, 0, pins, keep)           # 4 cache-held
    got = _admit(sac, radix, 1, prefix, dedup=True)   # borrows all 4
    assert got is not None and len(sac._shared_pages[1]) == 4
    shared = list(sac._shared_pages[1])
    # a big request forces eviction of the cache copy (B pins only its
    # backing path; pool pressure still reclaims unpinned prefixes) —
    # release B's pin first so the copy is evictable
    for p in got[0]:
        radix.release(p)
    big = _admit(sac, radix, 2, list(range(500, 500 + 8 * ps)))
    assert big is not None
    for p in shared:
        assert not _page_free(sac, 0, p), "borrowed page freed early"
    _assert_consistent(sac, radix)
    sac.release(1)                               # borrower departs
    assert sac._shared_refs == {}
    assert all(not s for s in sac._orphaned)
    _assert_consistent(sac, radix)
    _finish(sac, radix, 2, *big)
    _assert_consistent(sac, radix)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_no_stale_pages_under_any_interleaving(data):
    """ISSUE 5 acceptance, extended by PR 6: after ANY interleaving of
    admit (with or without dedup) / finish (with arbitrary retention) /
    evict / headroom-evict / replicate, match_prefix never returns
    pages the allocator considers free, the three accounting views
    agree (including shared-page refcounts and orphans), and the node
    count stays bounded."""
    sac, radix, cfg = _system(n_dev=data.draw(st.integers(1, 3)),
                              pages_per_dev=data.draw(
                                  st.sampled_from([8, 16, 48])))
    ps = cfg.sac.page_size
    live = {}
    nxt = 0
    n_inserts = 0
    for _ in range(30):
        op = data.draw(st.sampled_from(
            ["admit", "admit", "finish", "evict", "headroom",
             "replicate"]))
        if op == "admit":
            # draw from a tiny token alphabet so prefixes collide often
            n_tok = data.draw(st.integers(1, 6)) * ps \
                + data.draw(st.integers(0, ps - 1))
            toks = [data.draw(st.integers(0, 2)) for _ in range(n_tok)]
            got = _admit(sac, radix, nxt, toks,
                         dedup=data.draw(st.booleans()))
            if got is not None:
                live[nxt] = got
                n_inserts += 1
            nxt += 1
        elif op == "replicate":
            n_tok = data.draw(st.integers(1, 4)) * ps
            toks = [data.draw(st.integers(0, 2)) for _ in range(n_tok)]
            m = radix.match(toks)
            if m.hit and sac.n_devices > 1:
                others = [d for d in range(sac.n_devices)
                          if d not in m.copies]
                if others:
                    src = data.draw(st.sampled_from(sorted(m.copies)))
                    sac.replicate_prefix(
                        list(m.pin_tokens), m.copies[src], src,
                        data.draw(st.sampled_from(others)))
        elif op == "finish" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            pins, keep = live.pop(rid)
            # arbitrary retention, INCLUDING wrong values: the system
            # must stay consistent even for keep counts that do not
            # match what the index registered
            k = data.draw(st.sampled_from([0, keep, keep + 2]))
            _finish(sac, radix, rid, pins, k,
                    headroom=data.draw(st.sampled_from([0.0, 0.25])))
        elif op == "evict":
            sac.radix_evict(data.draw(st.integers(1, 3)))
        elif op == "headroom":
            sac.evict_to_headroom(0.5)
        _assert_consistent(sac, radix)
        assert radix.n_nodes() <= 2 * max(n_inserts, 1) + len(live)
    for rid in sorted(live):
        pins, keep = live.pop(rid)
        _finish(sac, radix, rid, pins, keep)
        _assert_consistent(sac, radix)
    # no request is live: every shared ref was returned and every
    # orphaned page freed with its last borrower
    assert sac._shared_refs == {}
    assert all(not s for s in sac._orphaned)
    # drain the cache: the tree must collapse completely (no leaked
    # split nodes, no un-freeable pages)
    while sac.radix_evict(4):
        _assert_consistent(sac, radix)
    radix.evict_lru(64)
    assert radix.n_nodes() == 0
    assert sac.radix_held_pages() == 0


# ---------------------------------------------------------------------------
# engine regressions (real jitted path, reduced configs)
# ---------------------------------------------------------------------------


def _engine(cfg, **kw):
    from repro.serving.engine import Engine
    return Engine(cfg, **kw)


def _shared_trace(cfg, n=5, prefix=24, suffix=8, out=6, seed=3, reuse=1.0):
    return shared_prefix_trace(n, prefix_len=prefix, suffix_len=suffix,
                               output_len=out, reuse_p=reuse, seed=seed,
                               vocab=cfg.vocab)


def test_engine_requeues_when_pool_exhausted():
    """Satellite 1: sac.place returning None must NOT fall back to
    charging device 0 — the request waits (FCFS head) until a finishing
    request frees pages, and every request still completes."""
    cfg = get_config("qwen2-1.5b").reduced()
    eng = _engine(cfg, slots=2, max_ctx=96)
    # shrink the pool to ~one request's footprint so slot 2 must wait
    need = (40 + 6 + cfg.sac.page_size - 1) // cfg.sac.page_size
    eng.sac.placer.capacity_pages = need
    eng.sac.allocator.pages_per_device = need
    reqs = _shared_trace(cfg, n=3, prefix=24, suffix=16, out=6)
    out = eng.run(reqs)
    assert out["n_done"] == 3
    # no phantom booking ever landed on a link that refused the request
    assert eng.stats.traffic.device_anomalies == 0
    # only cache-held prefix pages remain booked, no request bookings
    for d in range(eng.sac.n_devices):
        assert eng.sac.placer.pages_used[d] == eng.sac.radix_held_pages(d)


def test_engine_fails_loudly_when_request_can_never_fit():
    cfg = get_config("qwen2-1.5b").reduced()
    eng = _engine(cfg, slots=1, max_ctx=96)
    eng.sac.placer.capacity_pages = 1
    eng.sac.allocator.pages_per_device = 1
    for r in _shared_trace(cfg, n=1, prefix=24, suffix=16, out=6):
        eng.submit(r)
    with pytest.raises(RuntimeError, match="never be placed"):
        eng.step()


def test_engine_hit_credit_is_page_granular():
    """Satellite 2: identical prompts whose shared prefix is not
    page-aligned must be credited in whole pages only."""
    cfg = get_config("qwen2-1.5b").reduced()
    ps = cfg.sac.page_size
    eng = _engine(cfg, slots=1, max_ctx=96, placement="radix_affinity")
    rng = np.random.default_rng(0)
    base = rng.integers(0, cfg.vocab, size=30).astype(np.int32)
    reqs = []
    for i in range(2):
        tail = rng.integers(0, cfg.vocab, size=3).astype(np.int32)
        prompt = np.concatenate([base, tail])   # 30 shared, 33 total
        reqs.append(Request(i, 0.0, len(prompt), 4, prompt))
    eng.run(reqs)
    expected = (30 // ps) * ps                  # 28 at page_size 4
    assert eng.stats.radix_hit_tokens == expected
    assert eng.stats.radix_hit_tokens % ps == 0


def test_engine_tokens_bit_identical_radix_on_off():
    """The locality loop changes traffic and timing, never tokens."""
    cfg = get_config("qwen2-1.5b").reduced()
    streams = []
    for radix in (True, False):
        eng = _engine(cfg, slots=2, max_ctx=96, seed=2, radix=radix,
                      placement="radix_affinity" if radix else None)
        for r in _shared_trace(cfg, n=2, prefix=24, suffix=8, out=40):
            eng.submit(r)
        for _ in range(12):
            eng.step()
        streams.append([t[:] for t in eng.slot_tokens])
    assert streams[0] == streams[1]


def test_engine_radix_reduces_write_bytes_and_ttft():
    """ISSUE 5 acceptance (engine side): on a shared-prefix trace the
    radix loop cuts prefill write bytes and TTFT at identical decoded
    tokens and identical hit-rate accounting."""
    cfg = get_config("qwen2-1.5b").reduced()
    outs = {}
    for radix in (True, False):
        eng = _engine(cfg, slots=1, max_ctx=96, seed=0, radix=radix,
                      placement="radix_affinity" if radix else None)
        outs[radix] = eng.run(_shared_trace(cfg, n=5))
        outs[radix]["hit_rate"] = eng.stats.hit_rate
    on, off = outs[True], outs[False]
    assert on["engine_tokens"] == off["engine_tokens"]
    assert on["radix_hit_tokens"] > 0 and off["radix_hit_tokens"] == 0
    assert on["bytes_written"] < off["bytes_written"]
    assert on["ttft_mean_s"] < off["ttft_mean_s"]
    assert abs(on["hit_rate"] - off["hit_rate"]) < 0.02


def test_engine_tokens_bit_identical_pr6_features_on_off():
    """Replication, dedup, and radix-aware admission change traffic,
    timing, and pool bytes — never decoded tokens.  Admission may
    permute which slot hosts which request, so the comparison is over
    the multiset of slot token streams."""
    cfg = get_config("qwen2-1.5b").reduced()
    streams = []
    for on in (True, False):
        eng = _engine(cfg, slots=2, max_ctx=96, seed=2,
                      placement="radix_affinity",
                      replicate_prefixes=on, dedup_pages=on,
                      radix_admission=on)
        for r in _shared_trace(cfg, n=3, prefix=24, suffix=8, out=40):
            eng.submit(r)
        for _ in range(12):
            eng.step()
        streams.append(sorted(tuple(t) for t in eng.slot_tokens))
    assert streams[0] == streams[1]


def test_engine_dedup_lifecycle_after_drain():
    """With dedup on, shared prompts borrow cached pages (pool bytes
    per request drop) and the run drains clean: no shared refs, no
    orphans, placer == cache-held, and every request completes."""
    cfg = get_config("qwen2-1.5b").reduced()
    outs = {}
    for on in (True, False):
        eng = _engine(cfg, slots=2, max_ctx=96, seed=1,
                      placement="radix_affinity", dedup_pages=on)
        outs[on] = eng.run(_shared_trace(cfg, n=6, reuse=1.0))
        assert outs[on]["n_done"] == 6
        if on:
            assert eng.sac._shared_refs == {}
            assert all(not s for s in eng.sac._orphaned)
            for d in range(eng.sac.n_devices):
                assert (eng.sac.placer.pages_used[d]
                        == eng.sac.radix_held_pages(d))
    assert outs[True]["dedup_shared_pages"] > 0
    assert outs[False]["dedup_shared_pages"] == 0
    assert (outs[True]["pool_bytes_per_req"]
            < outs[False]["pool_bytes_per_req"])
    assert outs[True]["engine_tokens"] == outs[False]["engine_tokens"]


def test_engine_replicates_under_forced_pressure():
    """Staged pressure: the founder lands while both links are idle;
    the link then heats up, so the next group member's match must
    trigger a copy to the cold link — and decoded tokens must match a
    replication-off run exactly."""
    import dataclasses

    cfg = get_config("qwen2-1.5b").reduced()
    # a huge payback horizon isolates the trigger's pressure direction
    # logic from the reduced config's tiny absolute magnitudes; the
    # long prefix + 1-page suffix keeps the reuse bonus above the
    # full-node copy cost (the copy ships the suffix pages too, so a
    # fat suffix sinks the margin at reduced scale)
    cfg = dataclasses.replace(cfg, sac=dataclasses.replace(
        cfg.sac, replicate_horizon_steps=10 ** 6))
    outs = {}
    for on in (True, False):
        eng = _engine(cfg, slots=2, max_ctx=256, seed=4,
                      placement="radix_affinity", replicate_prefixes=on)
        press = [0.0, 0.0]
        eng.sac.set_pressure_fn(lambda: list(press))
        reqs = _shared_trace(cfg, n=3, prefix=128, suffix=4, out=20)
        eng.submit(reqs[0])
        eng.step()                       # founder placed on an idle link
        dev = next(rp.device for rp in eng.sac.requests.values())
        press[dev] = 1.0                 # the owning link heats up
        for r in reqs[1:]:
            eng.submit(r)
        for _ in range(10):
            eng.step()
        outs[on] = sorted(tuple(t) for t in eng.slot_tokens)
        if on:
            assert eng.sac.replicated_pages > 0
            # the copy landed on the cold link and the cache books it
            assert eng.sac.radix_held_pages(1 - dev) > 0
        else:
            assert eng.sac.replicated_pages == 0
    assert outs[True] == outs[False]


def test_engine_radix_lifecycle_invariants_after_drain():
    """After a full run: no pins leak, every cached page is cache-held,
    and the placer still accounts the held pages."""
    cfg = get_config("qwen2-1.5b").reduced()
    eng = _engine(cfg, slots=2, max_ctx=96, placement="radix_affinity")
    out = eng.run(_shared_trace(cfg, n=6, reuse=0.6))
    assert out["n_done"] == 6
    assert all(n.refs == 0 for n in eng.radix._all_nodes())
    held = {(d, p) for d in range(eng.sac.n_devices)
            for p in eng.sac._radix_pages[d]}
    assert set(eng.radix.cached_pages()) == held
    for d in range(eng.sac.n_devices):
        assert eng.sac.placer.pages_used[d] == eng.sac.radix_held_pages(d)
        for p in eng.sac._radix_pages[d]:
            assert not _page_free(eng.sac, d, p)
