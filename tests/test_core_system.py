"""core/: transfer fabric calibration (Fig 5), metadata seqlock directory,
pool allocator + interleaving, hlo analyzer."""
import numpy as np
import pytest

from repro.core.metadata import PageDirectory, PoolAllocator
from repro.core.pool import interleaved_assignment
from repro.core.sac import SACSystem
from repro.core.transfer import CXL, DRAM, FABRICS, RDMA, fig5_ratios
from repro.configs import get_config


# ---- Fig 5 calibration (paper §3.2) ----

@pytest.mark.parametrize("n", [64, 256, 1024, 2048, 4096])
def test_fig5_cxl_band(n):
    r = fig5_ratios(n)
    assert 1.0 <= r["cxl"] <= 1.70, (n, r)   # paper: 1.04-1.64x


@pytest.mark.parametrize("n", [64, 256, 1024, 2048, 4096])
def test_fig5_rdma_band(n):
    r = fig5_ratios(n)
    assert 3.5 <= r["rdma"] <= 21.0, (n, r)  # paper: 4.0-19.7x


def test_fig5_rdma_reaches_ms():
    assert RDMA.sparse_fetch_time(4096, 1152) > 1e-3  # ms-level (paper)
    assert CXL.sparse_fetch_time(4096, 1152) < 3e-4


def test_rdma_ratio_grows_with_entries():
    r64 = fig5_ratios(64)["rdma"]
    r4096 = fig5_ratios(4096)["rdma"]
    assert r4096 > 2 * r64


def test_bulk_transfer_bandwidth_bound():
    t = RDMA.bulk_transfer_time(1 << 30)
    assert t >= (1 << 30) / RDMA.bandwidth_Bps


# ---- metadata (paper §4.3.1) ----

def test_page_directory_publish_lookup_unpublish():
    d = PageDirectory(capacity=256)
    d.publish(seq_hash=42, page_no=0, device=1, page=7)
    d.publish(seq_hash=42, page_no=1, device=1, page=8)
    assert d.lookup(42, 0) == (1, 7)
    assert d.lookup(42, 1) == (1, 8)
    assert d.lookup(42, 2) is None
    d.unpublish(42, 0)
    assert d.lookup(42, 0) is None
    assert d.lookup(42, 1) == (1, 8)
    # versions even after committed ops (seqlock closed)
    assert all(v % 2 == 0 for v in d.version)


def test_page_directory_counts_line_accesses():
    d = PageDirectory(capacity=64)
    before = d.stats.lines()
    d.publish(1, 0, 0, 0)
    d.lookup(1, 0)
    assert d.stats.lines() > before   # metadata ops cost memory ops, not RPCs


def test_pool_allocator_exhaustion_and_release():
    a = PoolAllocator(n_devices=2, pages_per_device=4)
    p = a.alloc(0, 4)
    assert len(p) == 4 and a.alloc(0, 1) is None
    assert a.free_pages(1) == 4
    a.release(0, p)
    assert a.alloc(0, 2) is not None
    assert 0 <= a.utilization() <= 1


def test_interleaved_assignment():
    assert interleaved_assignment([0, 1, 2, 3], 2) == [0, 1, 0, 1]
    assert interleaved_assignment([0, 1, 2, 3], 2, enabled=False) == [0] * 4


def test_sac_system_place_release_interleaves():
    cfg = get_config("qwen2-1.5b").reduced()
    sys_ = SACSystem(cfg, backend="cxl", n_pool_devices=2,
                     device_bytes=1 << 20)
    r1 = sys_.place(1, 64)
    r2 = sys_.place(2, 64)
    assert {r1.device, r2.device} == {0, 1}
    assert sys_.directory.lookup(1, 0) is not None
    sys_.release(1)
    assert sys_.directory.lookup(1, 0) is None


def test_sac_system_fetch_accounting():
    cfg = get_config("deepseek-v32")
    sys_ = SACSystem(cfg, backend="cxl")
    t = sys_.sparse_fetch_time(2048)
    assert t > 0 and sys_.bytes_fetched == 2048 * sys_.entry_bytes
    t_rdma = SACSystem(cfg, backend="rdma").sparse_fetch_time(2048)
    assert t_rdma > 4 * t                  # the paper's infeasibility gap
