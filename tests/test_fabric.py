"""CXL fabric topology subsystem (PR 7, core/fabric.py).

The invariant this suite guards: **topology changes traffic and timing,
never decoded tokens** — the fabric graph is pure control/accounting
plane.  Sections:

  - FabricTopology structure: presets (flat_star / tree / multi_switch /
    mesh), deterministic routing, LCA device->device routes, bottleneck
    vs leaf projections, from_spec parsing + error cases;
  - the conservation property (hypothesis): the accountant's summed
    per-segment charged seconds equal the charges recomputed along every
    fetch's path — no traffic is lost or double-counted by the graph;
  - flat-star degeneracy: with the default topology the per-SEGMENT
    stats equal the per-device stats element-for-element (the PR 7
    accounting is a strict superset of the historical flat accounting);
  - tree conservation: a trunk's issued seconds are the sum of its
    member leaves' (trunk_scale=1), and leaf segments equal the
    per-device numbers — holds for the engine AND the simulator (the
    per-segment issued-seconds parity contract);
  - QoS: the OverlapQueue's speculative class yields at congested
    segments (only demand stalls; late spec lands in spec_yielded_s);
  - per-path arbiter budgets: devices sharing a saturated trunk share
    one speculation budget (granted_seg), flat star matches the
    topology-free arbiter exactly; DemandTracker departures subtract
    along the full route;
  - engine bit-identity: decoded tokens identical across topologies and
    with warmup_pressure_seed / replica_reads on;
  - simulator: flat-spec runs match the default exactly, a shared trunk
    serializes timing, QoS yield is recorded.
"""
import dataclasses

import pytest

from hypothesis_compat import given, settings, st

from repro.core.fabric import FabricTopology, Segment
from repro.core.traffic import FabricAccountant, OverlapQueue
from repro.core.transfer import (FABRICS, PipelineModel, QOS_DEMAND,
                                 QOS_SPECULATIVE)
from repro.serving.arbiter import ArbiterConfig, BudgetArbiter, DemandTracker


# ---------------------------------------------------------------------------
# structure + routing
# ---------------------------------------------------------------------------


def test_flat_star_structure():
    flat = FabricTopology.flat_star(3)
    assert flat.n_segments == 3
    assert [flat.route(d) for d in range(3)] == [(0,), (1,), (2,)]
    assert not flat.qos_spec_yield
    assert flat.transfer_seconds(1, 2.5) == 2.5      # identity charge


def test_tree_structure_leaves_numbered_first():
    tree = FabricTopology.tree(4, n_switches=2)
    assert tree.n_segments == 6                      # 4 leaves + 2 trunks
    # leaf sid == device id, so leaf projections align index-for-index
    # with per-device arrays
    for d in range(4):
        assert tree.leaf(d) == d
    assert tree.route(0) == (4, 0) and tree.route(1) == (4, 1)
    assert tree.route(2) == (5, 2) and tree.route(3) == (5, 3)
    assert tree.qos_spec_yield


def test_multi_switch_and_mesh_structure():
    ms = FabricTopology.multi_switch(8, 2)
    assert ms.n_segments == 11                       # 8 + 2 trunks + root
    assert ms.route(0) == (10, 8, 0)
    assert ms.route(7) == (10, 9, 7)
    mesh = FabricTopology.mesh(4, n_ports=2)
    # striped: devices 0 and 2 share port 0, 1 and 3 share port 1
    assert mesh.route(0)[0] == mesh.route(2)[0]
    assert mesh.route(1)[0] == mesh.route(3)[0]
    assert mesh.route(0)[0] != mesh.route(1)[0]


def test_route_between_stops_at_lca():
    tree = FabricTopology.tree(4, n_switches=2)
    # same switch: the shared trunk is never crossed
    assert tree.route_between(0, 1) == (0, 1)
    # cross switch: up to the host, down the other trunk
    assert tree.route_between(0, 2) == (0, 4, 5, 2)


def test_route_out_of_range_raises():
    tree = FabricTopology.tree(4, n_switches=2)
    with pytest.raises(IndexError):
        tree.route(4)
    with pytest.raises(IndexError):
        tree.route(-1)


def test_device_view_is_bottleneck_leaf_view_is_endpoint():
    tree = FabricTopology.tree(4, n_switches=2)
    seg = [1.0, 0.0, 0.0, 0.0, 5.0, 0.0]            # leaf0=1, trunk0=5
    assert tree.device_view(seg) == [5.0, 5.0, 0.0, 0.0]
    assert tree.leaf_view(seg) == [1.0, 0.0, 0.0, 0.0]


def test_trunk_scale_slows_segment():
    tree = FabricTopology.tree(2, n_switches=1, trunk_scale=0.5)
    charges = dict(tree.segment_charge(0, 1.0))
    assert charges[0] == 1.0                         # leaf: full rate
    assert charges[2] == 2.0                         # trunk: half rate
    assert tree.transfer_seconds(0, 1.0) == 2.0      # bottleneck
    assert tree.segment_seconds([0.0, 0.0, 32e9], 32e9) == [0.0, 0.0, 2.0]


def test_from_spec_strings_and_errors():
    assert FabricTopology.from_spec(None, 3).name == "flat"
    t = FabricTopology.from_spec("tree:4x2")
    assert t.n_devices == 4 and t.n_segments == 6
    assert FabricTopology.from_spec("tree", 4).n_devices == 4
    assert FabricTopology.from_spec("flat:2").n_segments == 2
    assert FabricTopology.from_spec("multi_switch:8x2").n_segments == 11
    assert FabricTopology.from_spec("mesh:4x2").name == "mesh"
    # pass-through with device-count agreement
    assert FabricTopology.from_spec(t, 4) is t
    with pytest.raises(ValueError):
        FabricTopology.from_spec("warp:4")           # unknown kind
    with pytest.raises(ValueError):
        FabricTopology.from_spec("tree:4x2", 8)      # count mismatch
    with pytest.raises(ValueError):
        FabricTopology.from_spec("tree")             # no count anywhere


# ---------------------------------------------------------------------------
# conservation property: per-segment charges == recomputed path charges
# ---------------------------------------------------------------------------


def _make_topo(kind: str, n: int) -> FabricTopology:
    return {"flat": lambda: FabricTopology.flat_star(n),
            "tree": lambda: FabricTopology.tree(n, 2),
            "multi_switch": lambda: FabricTopology.multi_switch(n, 2),
            "mesh": lambda: FabricTopology.mesh(n, 2)}[kind]()


@given(kind=st.sampled_from(["flat", "tree", "multi_switch", "mesh"]),
       n=st.integers(min_value=2, max_value=6),
       fetches=st.lists(st.tuples(st.integers(min_value=0, max_value=63),
                                  st.integers(min_value=1, max_value=4096)),
                        min_size=1, max_size=24))
@settings(max_examples=60, deadline=None)
def test_segment_charge_conservation(kind, n, fetches):
    """Every fetch charges exactly its path: the accountant's cumulative
    per-segment issued seconds equal the charges recomputed fetch by
    fetch via segment_charge, and the per-device issued seconds equal
    the recomputed bottleneck times."""
    topo = _make_topo(kind, n)
    acct = FabricAccountant(backend="cxl", n_devices=n, topology=topo)
    expect_seg = [0.0] * topo.n_segments
    expect_dev = [0.0] * n
    for dev_raw, entries in fetches:
        dev = dev_raw % n
        acct.sparse_fetch(entries, 1152, device=dev)
        raw = FABRICS["cxl"].sparse_fetch_time(entries, 1152)
        for sid, c in topo.segment_charge(dev, raw):
            expect_seg[sid] += c
        expect_dev[dev] += topo.transfer_seconds(dev, raw)
    assert acct.stats.segment_issued_s == pytest.approx(
        expect_seg, rel=1e-12, abs=1e-15)
    assert acct.stats.device_issued_s == pytest.approx(
        expect_dev, rel=1e-12, abs=1e-15)
    # nothing leaks into the speculative class from demand fetches
    assert acct.stats.segment_prefetch_s == [0.0] * topo.n_segments


# ---------------------------------------------------------------------------
# flat-star degeneracy: per-segment stats == per-device stats exactly
# ---------------------------------------------------------------------------


def test_flat_star_segment_stats_equal_device_stats():
    acct = FabricAccountant(backend="cxl", n_devices=3)
    acct.sparse_fetch(100, 1152, device=0)
    acct.prefetch_fetch(40, 1152, device=1)
    acct.bulk_fetch(5e6, device=2)
    acct.write_back(3e6, device=0)
    acct.add_step_demand(1, 1e6)
    acct.add_step_demand(2, 2e6, qos=QOS_SPECULATIVE)
    seg_backlog = acct.drain_step()
    st_ = acct.stats
    assert st_.segment_issued_s == st_.device_issued_s
    assert st_.segment_prefetch_s == st_.device_prefetch_s
    assert st_.segment_demand_s() == st_.device_demand_s()
    assert st_.segment_demand_bytes == st_.device_demand_bytes
    assert seg_backlog == [0.0, 1e6, 2e6]
    assert st_.critical_demand_bytes == 2e6


def test_flat_spec_matches_default_exactly():
    """topology='flat:N' and the default (None) produce bit-identical
    stats for the same op sequence."""
    outs = []
    for spec in (None, "flat:2", FabricTopology.flat_star(2)):
        acct = FabricAccountant(backend="cxl", n_devices=2, topology=spec)
        acct.enable_overlap(PipelineModel(depth=2, overlap_frac=0.6))
        acct.sparse_fetch(64, 1152, device=0)
        acct.prefetch_fetch(32, 1152, device=1)
        acct.drain_overlap(1e-4)
        outs.append((acct.stats.segment_issued_s,
                     acct.stats.segment_exposed_s,
                     acct.stats.exposed_fabric_s,
                     acct.stats.critical_issued_s,
                     acct.stats.spec_yielded_s))
    assert outs[0] == outs[1] == outs[2]
    assert outs[0][4] == 0.0                 # flat star never QoS-yields


# ---------------------------------------------------------------------------
# tree conservation: trunk == sum of member leaves (the per-segment
# issued-seconds contract shared by engine and simulator)
# ---------------------------------------------------------------------------


def test_tree_trunk_issued_is_sum_of_leaves_accountant():
    tree = FabricTopology.tree(2, n_switches=1)      # segs: d0, d1, trunk
    acct = FabricAccountant(backend="cxl", n_devices=2, topology=tree)
    acct.sparse_fetch(100, 1152, device=0)
    acct.sparse_fetch(60, 1152, device=1)
    acct.prefetch_fetch(30, 1152, device=0)
    st_ = acct.stats
    assert st_.segment_issued_s[2] == pytest.approx(
        st_.segment_issued_s[0] + st_.segment_issued_s[1], rel=1e-12)
    # trunk_scale=1: leaf segments carry the per-device numbers
    assert st_.segment_issued_s[:2] == st_.device_issued_s


# ---------------------------------------------------------------------------
# QoS: speculation yields at congested segments
# ---------------------------------------------------------------------------


def test_overlap_queue_qos_spec_yields_to_demand():
    tree = FabricTopology.tree(2, n_switches=1)      # qos_spec_yield=True
    q = OverlapQueue(tree, PipelineModel(depth=2, overlap_frac=1.0))
    q.issue(0, 0.008, QOS_DEMAND)
    q.issue(0, 0.004, QOS_SPECULATIVE)
    # hide window 0.01: demand (8 ms) fits -> exposed 0; spec gets the
    # 2 ms leftover, the other 2 ms is dropped late (yielded) on BOTH
    # segments of the route
    assert q.drain(0.01) == 0.0
    assert q.spec_yielded_s == pytest.approx(2 * 0.002)


def test_overlap_queue_qos_demand_still_stalls():
    tree = FabricTopology.tree(2, n_switches=1)
    q = OverlapQueue(tree, PipelineModel(depth=2, overlap_frac=1.0))
    q.issue(0, 0.02, QOS_DEMAND)                     # window is 0.01
    q.issue(0, 0.004, QOS_SPECULATIVE)
    assert q.drain(0.01) == pytest.approx(0.01)      # demand tail exposed
    assert q.spec_yielded_s == pytest.approx(2 * 0.004)  # no window left


def test_overlap_queue_without_yield_flag_spec_counts():
    flat = FabricTopology.flat_star(2)               # qos off
    q = OverlapQueue(flat, PipelineModel(depth=2, overlap_frac=1.0))
    q.issue(0, 0.008, QOS_DEMAND)
    q.issue(0, 0.004, QOS_SPECULATIVE)
    assert q.drain(0.01) == pytest.approx(0.002)     # dem+spec - window
    assert q.spec_yielded_s == 0.0


# ---------------------------------------------------------------------------
# per-path arbiter budgets + segment-space demand tracking
# ---------------------------------------------------------------------------


def test_tracker_departure_subtracts_along_route():
    tree = FabricTopology.tree(2, n_switches=1)
    tr = DemandTracker(2, tree)
    tr.set_step([0.4, 0.3, 0.7], {1: 0.4})           # d0, d1, trunk
    assert tr.depart(1, 0) == pytest.approx(0.4)
    assert tr.last_demand_s == pytest.approx([0.0, 0.3, 0.3])


def test_grant_shared_trunk_is_one_budget():
    """Two devices behind one saturated trunk share a single speculation
    budget: the second device's grant sees the first one's spec seconds
    already booked on the trunk (granted_seg)."""
    tree = FabricTopology.tree(2, n_switches=1)
    pipe = PipelineModel(depth=2, overlap_frac=1.0)
    cfg = ArbiterConfig(max_width=64, min_width=0, link_budget_frac=1.0)
    entry_s = 1e-4
    arb_t = BudgetArbiter(cfg, entry_s=entry_s, n_layers=1, pipeline=pipe,
                          topology=tree)
    arb_f = BudgetArbiter(cfg, entry_s=entry_s, n_layers=1, pipeline=pipe)
    t_comp = 0.02                                    # hide window 20 ms
    # leaves idle, trunk 18 ms busy -> 2 ms of shared headroom
    g_t = arb_t.grant(t_comp, [0.0, 0.0, 0.018], {0: [1], 1: [2]})
    g_f = arb_f.grant(t_comp, [0.0, 0.0], {0: [1], 1: [2]})
    assert g_f[1] == g_f[2] == 64                    # flat: both full width
    total_spec_s = (g_t[1] + g_t[2]) * entry_s
    assert total_spec_s <= 0.002 + 1e-12             # one trunk budget
    assert g_t[1] + g_t[2] < g_f[1] + g_f[2]


def test_grant_flat_star_matches_no_topology():
    flat = FabricTopology.flat_star(2)
    pipe = PipelineModel(depth=2, overlap_frac=0.8)
    cfg = ArbiterConfig(max_width=48, min_width=4, link_budget_frac=0.9)
    kw = dict(entry_s=2e-4, n_layers=2, pipeline=pipe)
    a = BudgetArbiter(cfg, topology=flat, **kw)
    b = BudgetArbiter(cfg, **kw)
    demand = [0.003, 0.011]
    dev_reqs = {0: [1, 2], 1: [3]}
    assert a.grant(0.02, demand, dev_reqs) == b.grant(0.02, demand,
                                                      dev_reqs)


def test_arbiter_rejects_out_of_range_device():
    tree = FabricTopology.tree(2, n_switches=1)
    arb = BudgetArbiter(ArbiterConfig(max_width=8),
                        entry_s=1e-4, n_layers=1,
                        pipeline=PipelineModel(depth=2, overlap_frac=1.0),
                        topology=tree)
    with pytest.raises(ValueError):
        arb.grant(0.01, [0.0, 0.0, 0.0], {2: [1]})   # only 2 devices


# ---------------------------------------------------------------------------
# engine: decoded tokens are topology-invariant
# ---------------------------------------------------------------------------


def _engine(cfg, **kw):
    from repro.serving.engine import Engine
    return Engine(cfg, **kw)


def _shared_trace(cfg, n=3, prefix=24, suffix=8, out=6, seed=3):
    from repro.serving.request import shared_prefix_trace
    return shared_prefix_trace(n, prefix_len=prefix, suffix_len=suffix,
                               output_len=out, reuse_p=1.0, seed=seed,
                               vocab=cfg.vocab)


def test_engine_tokens_bit_identical_across_topologies():
    """The fabric graph is control/accounting plane only: flat star, a
    shared-trunk tree, and a cascaded multi_switch fabric decode the
    same tokens."""
    from repro.configs import get_config
    cfg = get_config("qwen2-1.5b").reduced()
    streams = []
    for topo in (None, "tree:2x1", "multi_switch:2x2"):
        eng = _engine(cfg, slots=2, max_ctx=96, seed=2,
                      placement="radix_affinity", topology=topo)
        for r in _shared_trace(cfg, out=10):
            eng.submit(r)
        for _ in range(10):
            eng.step()
        streams.append(sorted(tuple(t) for t in eng.slot_tokens))
        assert eng.sac.traffic.stats.n_segments == \
            FabricTopology.from_spec(topo, 2).n_segments
    assert streams[0] == streams[1] == streams[2]


def test_engine_tokens_bit_identical_fabric_knobs_on_off():
    """warmup_pressure_seed + replica_reads change placement, grants and
    charging — never decoded tokens (multiset comparison: seeding may
    permute slot assignment)."""
    from repro.configs import get_config
    cfg = get_config("qwen2-1.5b").reduced()
    streams = []
    for on in (True, False):
        eng = _engine(cfg, slots=2, max_ctx=96, seed=2,
                      placement="radix_affinity",
                      topology="tree:2x1" if on else None,
                      replicate_prefixes=on,
                      warmup_pressure_seed=on, replica_reads=on)
        for r in _shared_trace(cfg, out=10):
            eng.submit(r)
        for _ in range(10):
            eng.step()
        streams.append(sorted(tuple(t) for t in eng.slot_tokens))
    assert streams[0] == streams[1]


def test_engine_tree_trunk_issued_is_sum_of_leaves():
    """The per-segment issued-seconds contract on the REAL engine: with
    trunk_scale=1 every charge lands once on the leaf and once on the
    trunk, so trunk == leaf0 + leaf1 and leaves == device view."""
    from repro.configs import get_config
    cfg = get_config("qwen2-1.5b").reduced()
    eng = _engine(cfg, slots=2, max_ctx=96, seed=0, topology="tree:2x1")
    for r in _shared_trace(cfg, out=8):
        eng.submit(r)
    for _ in range(8):
        eng.step()
    st_ = eng.sac.traffic.stats
    assert st_.n_segments == 3
    assert sum(st_.segment_issued_s) > 0.0
    assert st_.segment_issued_s[2] == pytest.approx(
        st_.segment_issued_s[0] + st_.segment_issued_s[1], rel=1e-9)
    assert st_.segment_issued_s[:2] == pytest.approx(
        st_.device_issued_s, rel=1e-12)


# ---------------------------------------------------------------------------
# simulator: flat degeneracy, trunk serialization, QoS yield
# ---------------------------------------------------------------------------


def _sim_parts(n_devices=2):
    from repro.serving.request import Request
    from repro.serving.simulator import (ModelProfile, SimConfig,
                                         default_backends, simulate)
    reqs = [Request(request_id=i, arrival_s=0.01 * i, context_len=32768,
                    output_len=24, prefix_len=16384, prefix_group=i % 2)
            for i in range(12)]
    model = ModelProfile("m", n_attn_layers=8, topk=2048, entry_bytes=1152,
                         weights_bytes_per_gpu=2e10)
    backend = dataclasses.replace(default_backends()["cxl"],
                                  n_pool_devices=n_devices)
    return reqs, model, backend, SimConfig, simulate


def test_sim_flat_spec_matches_default_exactly():
    reqs, model, backend, SimConfig, simulate = _sim_parts()
    base = SimConfig(concurrency=8, round1=True, radix_affinity=True,
                     prefetch_width=128, arbiter=True, overlap_frac=0.8)
    a = simulate(reqs, model, backend, base)
    b = simulate(reqs, model, backend,
                 dataclasses.replace(base, topology="flat:2"))
    assert a == b                                    # float-exact


def test_sim_segment_blind_flat_star_is_noop():
    """segment_aware=False only matters on switch topologies: under the
    flat star the control plane is already device == segment."""
    reqs, model, backend, SimConfig, simulate = _sim_parts()
    base = SimConfig(concurrency=8, round1=True, radix_affinity=True,
                     prefetch_width=128, arbiter=True, overlap_frac=0.8)
    a = simulate(reqs, model, backend, base)
    b = simulate(reqs, model, backend,
                 dataclasses.replace(base, segment_aware=False))
    assert a == b


def test_sim_shared_trunk_serializes_timing():
    """A 1-switch tree funnels BOTH devices through one trunk: per-step
    fetch time is the trunk's (summed) drain, so decode is strictly no
    faster than flat — and the trunk's demand bytes equal the leaves'
    total."""
    reqs, model, backend, SimConfig, simulate = _sim_parts()
    base = SimConfig(concurrency=8, round1=True, radix_affinity=True)
    flat = simulate(reqs, model, backend, base)
    tree = simulate(reqs, model, backend,
                    dataclasses.replace(base, topology="tree:2x1"))
    assert tree["tbt_mean_s"] >= flat["tbt_mean_s"]
    assert tree["exposed_fabric_s"] >= flat["exposed_fabric_s"]
    seg = tree["segment_demand_bytes"]
    assert len(seg) == 3
    assert seg[2] == pytest.approx(seg[0] + seg[1], rel=1e-9)
    # decoded-work invariance: same tokens generated, same bytes moved
    assert tree["n_done"] == flat["n_done"]
    assert tree["bytes_fetched"] == pytest.approx(flat["bytes_fetched"])


def test_sim_qos_yield_recorded_under_congestion():
    """On a qos_spec_yield topology a congested trunk drops late
    speculation from exposure: spec_yielded_s > 0 and exposure stays
    demand-driven (<= the blind total-backlog exposure)."""
    reqs, model, backend, SimConfig, simulate = _sim_parts()
    # zero hide window: every speculative segment-second is late, so a
    # qos_spec_yield topology must drop (yield) all of it while the
    # flat star still exposes the full dem+spec backlog
    base = SimConfig(concurrency=12, round1=True, radix_affinity=True,
                     prefetch_width=1024, overlap_frac=0.0)
    flat = simulate(reqs, model, backend, base)
    tree = simulate(reqs, model, backend,
                    dataclasses.replace(base, topology="tree:2x1"))
    assert flat["spec_yielded_s"] == 0.0
    assert tree["spec_yielded_s"] > 0.0
    # demand-only exposure: the tree's per-step exposed tail never
    # includes the yielded speculation
    assert tree["exposed_fabric_s"] < tree["issued_fabric_s"]


def test_sim_replica_reads_and_seeding_run():
    """The PR 7 satellites' simulator twins execute and keep the
    decoded-work invariant (same requests complete, same tokens)."""
    reqs, model, backend, SimConfig, simulate = _sim_parts(n_devices=4)
    base = SimConfig(concurrency=8, round1=True, radix_affinity=True,
                     replicate_prefixes=True, dedup_pages=True,
                     radix_admission=True, topology="tree:4x2")
    aware = dataclasses.replace(base, replica_reads=True,
                                warmup_pressure_seed=True)
    a = simulate(reqs, model, backend, base)
    b = simulate(reqs, model, backend, aware)
    assert a["n_done"] == b["n_done"] == len(reqs)
    assert b["replica_redirects"] >= 0.0
    assert len(b["segment_issued_s"]) == 6
