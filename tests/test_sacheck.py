"""sacheck (tools/sacheck) — the static-analysis suite is itself under
test: every pass must catch its seeded known-bad fixture, must NOT fire
on the matching known-good snippet, suppressions and the baseline must
round-trip, and the real src/ tree must be clean modulo the committed
baseline (with the PR 9 satellites fixed outright, not baselined)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.sacheck.api import baseline_path, check_tree, repo_root  # noqa: E402
from tools.sacheck.config import SacheckConfig  # noqa: E402
from tools.sacheck.core import load_baseline, save_baseline  # noqa: E402
from tools.sacheck.passes import PASSES  # noqa: E402
from tools.sacheck.passes import (accounting_boundary, determinism,  # noqa: E402
                                  jit_purity, twin_coverage, units)


# ---------------------------------------------------------------------------
# fixture-tree plumbing
# ---------------------------------------------------------------------------

TRAFFIC_FIXTURE = """
import dataclasses

@dataclasses.dataclass
class TrafficStats:
    bytes_fetched: float = 0.0
    bytes_written: float = 0.0
    prefetch_bytes: float = 0.0
    spec_yielded_s: float = 0.0

class FabricAccountant:
    def __init__(self):
        self.stats = TrafficStats()
    def record_write_bytes(self, n):
        self.stats.bytes_written += n
"""


def make_tree(tmp_path, files):
    """Write a mini-repo mirroring the real layout; always includes a
    TrafficStats schema so the accounting pass has its boundary."""
    files = dict(files)
    files.setdefault("src/repro/core/traffic.py", TRAFFIC_FIXTURE)
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return tmp_path


def run_one(tmp_path, files, pass_name, config=None, baseline=()):
    root = make_tree(tmp_path, files)
    return check_tree(root, config=config or SacheckConfig(),
                      passes={pass_name: PASSES[pass_name]},
                      baseline=baseline)


def codes(result):
    return sorted(f.code for f in result.new)


# ---------------------------------------------------------------------------
# twin-coverage
# ---------------------------------------------------------------------------

TWIN_SAC = """
import dataclasses

@dataclasses.dataclass(frozen=True)
class SACConfig:
    alpha_s: float = 1.0
    beta_steps: int = 64
"""

TWIN_SIM_FULL = """
import dataclasses

@dataclasses.dataclass
class SimConfig:
    alpha_s: float = 1.0
    beta_steps: int = 64
"""

TWIN_SIM_DRIFTED = """
import dataclasses

@dataclasses.dataclass
class SimConfig:
    alpha_s: float = 1.0
    beta: int = 64
"""

TWIN_SERVE = """
def main(ap):
    ap.add_argument("--alpha-s", type=float)
    ap.add_argument("--beta-steps", type=int)
"""


class TestTwinCoverage:
    def test_known_bad_name_drift_and_missing_flag(self, tmp_path):
        res = run_one(tmp_path, {
            "src/repro/configs/base.py": TWIN_SAC,
            "src/repro/serving/simulator.py": TWIN_SIM_DRIFTED,
            "src/repro/launch/serve.py":
                'def main(ap):\n    ap.add_argument("--alpha-s")\n',
        }, "twin-coverage")
        assert "missing-twin" in codes(res)      # beta_steps vs beta
        assert "missing-flag" in codes(res)      # --beta-steps absent
        assert all(f.path == "src/repro/configs/base.py" for f in res.new)

    def test_known_good_no_false_positive(self, tmp_path):
        res = run_one(tmp_path, {
            "src/repro/configs/base.py": TWIN_SAC,
            "src/repro/serving/simulator.py": TWIN_SIM_FULL,
            "src/repro/launch/serve.py": TWIN_SERVE,
        }, "twin-coverage")
        assert res.new == []

    def test_justified_rename_and_exempt_pass(self, tmp_path):
        cfg = SacheckConfig()
        cfg.twin_renames = {"beta_steps": ("beta", "historical split")}
        cfg.flag_exempt = {"beta_steps": "calibrated constant"}
        res = run_one(tmp_path, {
            "src/repro/configs/base.py": TWIN_SAC,
            "src/repro/serving/simulator.py": TWIN_SIM_DRIFTED,
            "src/repro/launch/serve.py":
                'def main(ap):\n    ap.add_argument("--alpha-s")\n',
        }, "twin-coverage", config=cfg)
        assert res.new == []

    def test_stale_allowlist_entry_flagged(self, tmp_path):
        cfg = SacheckConfig()
        cfg.twin_renames = {"gone_field": (None, "obsolete")}
        res = run_one(tmp_path, {
            "src/repro/configs/base.py": TWIN_SAC,
            "src/repro/serving/simulator.py": TWIN_SIM_FULL,
            "src/repro/launch/serve.py": TWIN_SERVE,
        }, "twin-coverage", config=cfg)
        assert "stale-allowlist" in codes(res)

    # -- shared-policy consumption (PR 10) ------------------------------

    POLICY = ('CONSUMED_KNOBS = ("beta_steps",)\n'
              "class Policy:\n    pass\n")

    def test_consumed_knob_needs_no_twin(self, tmp_path):
        """A knob declared in a policy module's CONSUMED_KNOBS is exempt
        from the same-named-SimConfig-twin rule: both layers run the
        shared object, there is nothing to twin."""
        res = run_one(tmp_path, {
            "src/repro/configs/base.py": TWIN_SAC,
            "src/repro/serving/simulator.py": TWIN_SIM_DRIFTED,
            "src/repro/serving/policy/admission.py": self.POLICY,
            "src/repro/launch/serve.py": TWIN_SERVE,
        }, "twin-coverage")
        assert res.new == []

    def test_consumed_knob_still_requires_flag(self, tmp_path):
        """Consumption exempts the twin, never the serve.py flag —
        operators must still reach the knob."""
        res = run_one(tmp_path, {
            "src/repro/configs/base.py": TWIN_SAC,
            "src/repro/serving/simulator.py": TWIN_SIM_DRIFTED,
            "src/repro/serving/policy/admission.py": self.POLICY,
            "src/repro/launch/serve.py":
                'def main(ap):\n    ap.add_argument("--alpha-s")\n',
        }, "twin-coverage")
        assert codes(res) == ["missing-flag"]

    def test_stale_policy_knob_flagged_at_declaration(self, tmp_path):
        """A CONSUMED_KNOBS entry naming a vanished SACConfig field rots
        exactly like a stale allowlist entry — and is anchored at the
        policy file, where the fix belongs."""
        res = run_one(tmp_path, {
            "src/repro/configs/base.py": TWIN_SAC,
            "src/repro/serving/simulator.py": TWIN_SIM_FULL,
            "src/repro/serving/policy/admission.py":
                'CONSUMED_KNOBS = ("gamma_frac",)\n',
            "src/repro/launch/serve.py": TWIN_SERVE,
        }, "twin-coverage")
        assert "stale-policy-knob" in codes(res)
        (f,) = [f for f in res.new if f.code == "stale-policy-knob"]
        assert f.path == "src/repro/serving/policy/admission.py"

    def test_consumed_knob_obsoletes_allowlist_entry(self, tmp_path):
        """The declaration supersedes a twin_renames justification: keep
        both and twin-coverage says which one to drop."""
        cfg = SacheckConfig()
        cfg.twin_renames = {"beta_steps": (None, "pre-PR 10 residue")}
        res = run_one(tmp_path, {
            "src/repro/configs/base.py": TWIN_SAC,
            "src/repro/serving/simulator.py": TWIN_SIM_DRIFTED,
            "src/repro/serving/policy/admission.py": self.POLICY,
            "src/repro/launch/serve.py": TWIN_SERVE,
        }, "twin-coverage", config=cfg)
        assert "redundant-allowlist" in codes(res)


# ---------------------------------------------------------------------------
# units
# ---------------------------------------------------------------------------


class TestUnits:
    def test_known_bad_mixed_add(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/core/calc.py":
                                 "def f(demand_s, miss_bytes):\n"
                                 "    return demand_s + miss_bytes\n"},
                      "units")
        assert codes(res) == ["unit-mix"]

    def test_known_bad_augassign_and_compare(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/core/calc.py":
                                 "def f(stats, n_bytes, t_s, n_tokens):\n"
                                 "    stats.exposed_fabric_s += n_bytes\n"
                                 "    return t_s < n_tokens\n"},
                      "units")
        assert codes(res) == ["unit-mix", "unit-mix"]

    def test_known_good_conversion_and_same_unit(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/core/calc.py":
                                 "def f(a_s, b_s, n_bytes, bw, x_frac):\n"
                                 "    t = a_s + b_s\n"
                                 "    u = n_bytes / bw\n"
                                 "    v = t + u\n"
                                 "    w = x_frac * a_s\n"
                                 "    return max(t, v) - b_s + w\n"},
                      "units")
        assert res.new == []

    def test_call_result_units(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/core/calc.py":
                                 "def f(model, copy_bytes):\n"
                                 "    return model.prefill_s(4)"
                                 " + copy_bytes\n"},
                      "units")
        assert codes(res) == ["unit-mix"]

    def test_tests_and_tools_out_of_scope(self, tmp_path):
        res = run_one(tmp_path, {"other/calc.py":
                                 "def f(a_s, b_bytes):\n"
                                 "    return a_s + b_bytes\n"},
                      "units")
        assert res.new == []


# ---------------------------------------------------------------------------
# accounting-boundary
# ---------------------------------------------------------------------------


class TestAccountingBoundary:
    def test_known_bad_direct_mutation(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/serving/simulator.py":
                                 "def step(acct, wb):\n"
                                 "    acct.stats.bytes_written += wb\n"
                                 "    acct.stats.prefetch_bytes = 3\n"},
                      "accounting-boundary")
        assert codes(res) == ["direct-mutation", "direct-mutation"]

    def test_accountant_home_is_legal(self, tmp_path):
        # TRAFFIC_FIXTURE itself mutates self.stats.bytes_written inside
        # core/traffic.py — the accountant's own booking is the boundary
        res = run_one(tmp_path, {}, "accounting-boundary")
        assert res.new == []

    def test_non_traffic_stats_fields_ignored(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/serving/engine.py":
                                 "def step(self):\n"
                                 "    self.stats.steps += 1\n"
                                 "    self.stats.radix_hit_tokens += 4\n"},
                      "accounting-boundary")
        assert res.new == []

    def test_api_route_is_legal(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/serving/simulator.py":
                                 "def step(acct, wb):\n"
                                 "    acct.record_write_bytes(wb)\n"},
                      "accounting-boundary")
        assert res.new == []


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------

JIT_BAD = """
import functools
import random
import time

import jax


@functools.partial(jax.jit, static_argnames=("n",))
def step(x, n):
    t0 = time.time()
    r = random.random()
    y = float(x)
    m = int(n)
    return helper(x) + y + r + t0 + m


def helper(x):
    return bool(x)


def unreachable(x):
    return float(x)
"""

JIT_GOOD = """
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k",))
def step(x, k):
    w = int(k)
    return jnp.sum(x) * w


def host_side(x):
    import time
    return time.time(), float(x)
"""


class TestJitPurity:
    def test_known_bad_all_four_classes(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/kernels/k.py": JIT_BAD},
                      "jit-purity")
        got = codes(res)
        assert "time-call" in got
        assert "rng-call" in got
        assert got.count("traced-cast") == 2   # float(x) + helper's bool(x)
        # int(n) is static (static_argnames), unreachable() is not
        # reachable: neither may fire
        lines = {f.line for f in res.new}
        src = JIT_BAD.splitlines()
        assert not any("int(n)" in src[ln - 1] for ln in lines)
        assert not any("unreachable" in src[ln - 1] for ln in lines)

    def test_known_good_no_false_positive(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/kernels/k.py": JIT_GOOD},
                      "jit-purity")
        assert res.new == []

    def test_pallas_call_kernel_body_is_a_root(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/kernels/k.py":
                                 "import random\n"
                                 "from jax.experimental import pallas as pl\n"
                                 "def _kernel(ref):\n"
                                 "    ref[0] = random.random()\n"
                                 "def launch(x):\n"
                                 "    return pl.pallas_call(_kernel)(x)\n"},
                      "jit-purity")
        assert codes(res) == ["rng-call"]

    def test_global_statement_flagged(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/kernels/k.py":
                                 "import jax\n"
                                 "_COUNT = 0\n"
                                 "@jax.jit\n"
                                 "def step(x):\n"
                                 "    global _COUNT\n"
                                 "    _COUNT += 1\n"
                                 "    return x\n"},
                      "jit-purity")
        assert codes(res) == ["global-mutation"]


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


class TestDeterminism:
    def test_known_bad_global_rng(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/serving/gen.py":
                                 "import random\n"
                                 "import numpy as np\n"
                                 "def f():\n"
                                 "    return random.random()"
                                 " + np.random.rand(3)[0]\n"},
                      "determinism")
        assert codes(res) == ["global-rng", "global-rng"]

    def test_known_good_seeded_generators(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/serving/gen.py":
                                 "import random\n"
                                 "import numpy as np\n"
                                 "def f(seed):\n"
                                 "    rng = np.random.default_rng(seed)\n"
                                 "    r = random.Random(seed)\n"
                                 "    return rng.random() + r.random()\n"},
                      "determinism")
        assert res.new == []

    def test_known_bad_set_iteration_in_scope(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/core/acct.py":
                                 "def f(a, b):\n"
                                 "    tot = 0.0\n"
                                 "    for d in set(a) | {b}:\n"
                                 "        tot += d\n"
                                 "    return tot\n"},
                      "determinism")
        assert codes(res) == ["set-iteration"]

    def test_known_good_sorted_set_and_out_of_scope(self, tmp_path):
        res = run_one(tmp_path, {
            "src/repro/core/acct.py":
                "def f(a, b):\n"
                "    return [d for d in sorted(set(a) | {b})]\n",
            "src/repro/models/m.py":
                "def g(a):\n"
                "    for d in set(a):\n"
                "        pass\n"},
            "determinism")
        assert res.new == []


# ---------------------------------------------------------------------------
# suppressions + baseline round-trip
# ---------------------------------------------------------------------------

SUPPRESSED_OK = (
    "import random\n"
    "def f():\n"
    "    # sacheck: disable=determinism -- fixture: seeded upstream\n"
    "    return random.random()\n")

SUPPRESSED_NO_REASON = (
    "import random\n"
    "def f():\n"
    "    return random.random()  # sacheck: disable=determinism\n")


class TestSuppressionAndBaseline:
    def test_reasoned_suppression_suppresses(self, tmp_path):
        res = run_one(tmp_path, {"src/repro/core/g.py": SUPPRESSED_OK},
                      "determinism")
        assert res.new == []
        assert len(res.suppressed) == 1
        assert res.suppressed[0][1].reason == "fixture: seeded upstream"

    def test_reasonless_suppression_does_not_suppress(self, tmp_path):
        res = run_one(tmp_path,
                      {"src/repro/core/g.py": SUPPRESSED_NO_REASON},
                      "determinism")
        got = codes(res)
        assert "global-rng" in got        # the finding survives
        assert "missing-reason" in got    # and the bad disable is reported

    def test_baseline_round_trip(self, tmp_path):
        files = {"src/repro/core/g.py":
                 "import random\ndef f():\n    return random.random()\n"}
        res = run_one(tmp_path, files, "determinism")
        assert len(res.new) == 1
        bl = tmp_path / "baseline.json"
        save_baseline(bl, [f.fingerprint for f in res.new])
        res2 = check_tree(tmp_path, config=SacheckConfig(),
                          passes={"determinism": PASSES["determinism"]},
                          baseline=load_baseline(bl))
        assert res2.ok and len(res2.baselined) == 1
        # fingerprints are line-number independent: prepending a comment
        # line must not turn the baselined finding into a new one
        p = tmp_path / "src/repro/core/g.py"
        p.write_text("# shifted\n" + p.read_text())
        res3 = check_tree(tmp_path, config=SacheckConfig(),
                          passes={"determinism": PASSES["determinism"]},
                          baseline=load_baseline(bl))
        assert res3.ok and len(res3.baselined) == 1

    def test_stale_baseline_entries_reported(self, tmp_path):
        make_tree(tmp_path, {"src/repro/core/g.py": "x = 1\n"})
        res = check_tree(tmp_path, config=SacheckConfig(),
                         passes={"determinism": PASSES["determinism"]},
                         baseline=["determinism|gone.py|global-rng|x"])
        assert res.ok
        assert res.stale_baseline == ["determinism|gone.py|global-rng|x"]


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------


class TestRepoSelfCheck:
    def test_src_clean_modulo_baseline(self):
        root = repo_root()
        baseline = load_baseline(baseline_path(root))
        res = check_tree(root, baseline=baseline)
        assert res.ok, "\n".join(f.render() for f in res.new)

    def test_pr9_satellites_fixed_not_baselined(self):
        """The two simulator accounting-boundary violations and the
        replicate_horizon twin drift must be FIXED (acceptance says
        'not baselined'): neither live findings nor baseline entries may
        mention them."""
        root = repo_root()
        baseline = load_baseline(baseline_path(root))
        for entry in baseline:
            assert not entry.startswith("accounting-boundary|"), entry
            assert "replicate_horizon" not in entry, entry
        res = check_tree(root, baseline=baseline)
        everything = res.new + res.baselined
        assert not [f for f in everything
                    if f.pass_name == "accounting-boundary"]
        assert not [f for f in everything
                    if f.pass_name == "twin-coverage"]

    def test_every_suppression_in_src_has_a_reason(self):
        root = repo_root()
        res = check_tree(root, baseline=load_baseline(baseline_path(root)))
        for f in res.new + res.baselined:
            assert f.code != "missing-reason", f.render()
        for _, sup in res.suppressed:
            assert sup.reason

    def test_cli_clean_and_json_report(self, tmp_path):
        out = tmp_path / "report.json"
        proc = subprocess.run(
            [sys.executable, "-m", "tools.sacheck", "--json", str(out)],
            cwd=REPO, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        report = json.loads(out.read_text())
        assert report["ok"] is True
        assert set(report["passes"]) == set(PASSES)

    def test_cli_fails_on_fixture_violation(self, tmp_path):
        make_tree(tmp_path, {"src/repro/core/g.py":
                             "import random\n"
                             "def f():\n"
                             "    return random.random()\n"})
        proc = subprocess.run(
            [sys.executable, "-m", "tools.sacheck", "--root",
             str(tmp_path), "determinism"],
            cwd=REPO, capture_output=True, text=True)
        assert proc.returncode == 1
        assert "global-rng" in proc.stdout


# ---------------------------------------------------------------------------
# pass registry sanity
# ---------------------------------------------------------------------------


def test_registry_names_match_modules():
    assert PASSES.keys() == {
        twin_coverage.NAME, units.NAME, accounting_boundary.NAME,
        jit_purity.NAME, determinism.NAME}


def test_sim_config_deprecated_alias():
    """PR 9 satellite: SimConfig accepts the pre-rename spelling at
    construction and maps it onto replicate_horizon_steps."""
    sys.path.insert(0, str(REPO / "src"))
    from repro.serving.simulator import SimConfig
    assert SimConfig(replicate_horizon=11).replicate_horizon_steps == 11
    assert SimConfig(replicate_horizon_steps=9).replicate_horizon_steps == 9
    assert SimConfig().replicate_horizon_steps == 64
