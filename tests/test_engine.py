"""Real serving engine end-to-end (reduced configs, CPU)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import Engine
from repro.serving.request import sharegpt_trace


def _trace(cfg, n=4, ctx=40, out=6, seed=3):
    return sharegpt_trace(n, context_len=ctx, output_len=out, seed=seed,
                          ctx_jitter=0.0, vocab=cfg.vocab)


def test_engine_completes_all_requests():
    cfg = get_config("qwen2-1.5b").reduced()
    eng = Engine(cfg, slots=2, max_ctx=96)
    out = eng.run(_trace(cfg, n=5))
    assert out["n_done"] == 5
    assert out["engine_tokens"] == 5 * 6
    assert out["fabric_time_s"] > 0          # fetch+write were charged


def test_engine_more_slots_fewer_steps():
    cfg = get_config("qwen2-1.5b").reduced()
    e1 = Engine(cfg, slots=1, max_ctx=96)
    e4 = Engine(cfg, slots=4, max_ctx=96)
    o1 = e1.run(_trace(cfg, n=4))
    o4 = e4.run(_trace(cfg, n=4))
    assert o4["engine_steps"] < o1["engine_steps"]  # batching works


def test_engine_deterministic_across_backends():
    """Backend changes traffic accounting, never tokens."""
    cfg = get_config("minicpm-2b").reduced()
    outs = {}
    for backend in ("cxl", "rdma"):
        eng = Engine(cfg, slots=2, max_ctx=96, backend=backend, seed=1)
        eng.run(_trace(cfg, n=3))
        outs[backend] = [t[:] for t in eng.slot_tokens]
    # same generated streams (slot_tokens cleared; compare stats instead)
    e1 = Engine(cfg, slots=2, max_ctx=96, backend="cxl", seed=1)
    e2 = Engine(cfg, slots=2, max_ctx=96, backend="rdma", seed=1)
    r1 = e1.run(_trace(cfg, n=3))
    r2 = e2.run(_trace(cfg, n=3))
    assert r1["engine_tokens"] == r2["engine_tokens"]
    assert e1.stats.pool_entries_fetched == e2.stats.pool_entries_fetched


def test_engine_radix_prefix_hits_on_shared_prompt():
    cfg = get_config("qwen2-1.5b").reduced()
    eng = Engine(cfg, slots=1, max_ctx=96)
    reqs = _trace(cfg, n=3, ctx=40)
    shared = reqs[0].prompt_tokens
    for r in reqs:
        r.prompt_tokens = shared.copy()      # identical prompts
    out = eng.run(reqs)
    assert out["radix_hit_tokens"] > 0       # 2nd/3rd hit the radix cache


def test_engine_hybrid_arch():
    cfg = get_config("zamba2-7b").reduced()
    eng = Engine(cfg, slots=2, max_ctx=64)
    out = eng.run(_trace(cfg, n=2, ctx=24, out=4))
    assert out["n_done"] == 2
