"""Beyond-paper optimizations: fp8 pool, grouped MoE, perf-opt plumbing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model

B, S = 2, 32


def test_fp8_pool_decode_close_to_bf16(rng):
    cfg = get_config("qwen2-1.5b").reduced()
    cfg8 = dataclasses.replace(
        cfg, sac=dataclasses.replace(cfg.sac, kv_quant="fp8", topk=64))
    cfgb = dataclasses.replace(
        cfg, sac=dataclasses.replace(cfg.sac, topk=64))
    m8, mb = build_model(cfg8), build_model(cfgb)
    params = m8.init(rng)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    st8, _ = m8.prefill(params, toks)
    stb, _ = mb.prefill(params, toks)
    assert st8["kv_pool"].dtype == jnp.float8_e4m3fn
    assert st8["kv_pool"].nbytes == stb["kv_pool"].nbytes // 2
    _, l8 = m8.decode(params, st8, toks[:, 0])
    _, lb = mb.decode(params, stb, toks[:, 0])
    # quantization noise only: small relative to logit scale
    assert float(jnp.abs(l8 - lb).max()) < 0.5
    assert not jnp.isnan(l8).any()


def test_grouped_moe_matches_global_when_capacity_loose(rng):
    """With generous capacity (no drops), grouped dispatch must equal the
    global dispatch exactly (same expert assignment, same math)."""
    from repro.models import moe
    cfg = get_config("dbrx-132b").reduced()
    p_specs = moe.moe_param_specs(cfg)
    from repro.models.layers import init_params
    p = init_params(p_specs, rng)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.bfloat16)
    out1, aux1 = moe.moe_block(p, x, cfg, cap_factor=8.0, groups=1)
    out4, aux4 = moe.moe_block(p, x, cfg, cap_factor=8.0, groups=4)
    np.testing.assert_allclose(np.asarray(out1, np.float32),
                               np.asarray(out4, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_grouped_moe_nondivisible_groups_fall_back(rng):
    from repro.models import moe
    from repro.models.layers import init_params
    cfg = get_config("mixtral-8x22b").reduced()
    p = init_params(moe.moe_param_specs(cfg), rng)
    x = jax.random.normal(rng, (1, 6, cfg.d_model), jnp.bfloat16)  # T=6
    out, aux = moe.moe_block(p, x, cfg, groups=4)   # 4 ∤ 6 -> falls to 2
    assert out.shape == x.shape and not jnp.isnan(out).any()


def test_opts_plumbing_parse():
    from repro.launch.dryrun import parse_opts
    assert parse_opts("hier_topk=1,pool_closure=1,moe_groups=32") == {
        "hier_topk": 1, "pool_closure": 1, "moe_groups": 32}
    assert parse_opts("kv_quant=fp8") == {"kv_quant": "fp8"}
    assert parse_opts("") == {}


def test_pool_closure_decode_equals_default(rng):
    cfg = get_config("gemma3-12b").reduced()
    m1 = build_model(cfg, mode="sac")
    m2 = build_model(cfg, mode="sac", opts={"pool_closure": 1})
    params = m1.init(rng)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    st1, _ = m1.prefill(params, toks)
    st2, _ = m2.prefill(params, toks)
    t = jnp.array([3, 5], jnp.int32)
    _, l1 = m1.decode(params, st1, t)
    _, l2 = m2.decode(params, st2, t)
    assert float(jnp.abs(l1 - l2).max()) == 0.0
