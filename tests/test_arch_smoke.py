"""Per-architecture smoke tests (assigned requirement): reduced config of
the same family, one forward + one train step on CPU, output shapes +
no NaNs; plus prefill->decode continuity."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, ASSIGNED, get_config
from repro.models.model import build_model
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_loop import make_train_step

B, S = 2, 32


def _inputs(cfg, key):
    if cfg.enc_dec:
        frames = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        toks = jax.random.randint(key, (B, 16), 0, cfg.vocab)
        return {"frames": frames, "tokens": toks, "labels": toks}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_no_nan(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = _inputs(cfg, rng)
    if cfg.enc_dec:
        logits, aux = model.forward(
            params, {"frames": batch["frames"], "tokens": batch["tokens"]})
        assert logits.shape == (B, 16, cfg.vocab)
    else:
        logits, aux = model.forward(params, batch["tokens"])
        assert logits.shape == (B, S, cfg.vocab)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(jnp.asarray(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=10)))
    p2, o2, metrics = step(params, opt, _inputs(cfg, rng))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(o2["step"]) == 1
    # params actually changed
    changed = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_continuity(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    if cfg.enc_dec:
        inp = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16)
    else:
        inp = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    state, _ = model.prefill(params, inp)
    toks = jnp.array([1, 2], jnp.int32)
    for _ in range(3):
        state, logits = model.decode(params, state, toks)
        assert logits.shape == (B, cfg.vocab)
        assert not jnp.isnan(logits).any()
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    key = "dec_len" if cfg.enc_dec else "cache_len"
    assert int(state[key][0]) == (3 if cfg.enc_dec else S + 3)


def test_assigned_pool_complete():
    assert len(ASSIGNED) == 10
    assert len(ARCHS) == 11  # + the paper's own deepseek-v32
