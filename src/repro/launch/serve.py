"""End-to-end serving driver: the real engine on a reduced config (CPU)
or the full config under the production mesh (real hardware).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --requests 8 --ctx 48 --out-len 8 --backend cxl
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--ctx", type=int, default=48)
    ap.add_argument("--out-len", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-ctx", type=int, default=96)
    ap.add_argument("--backend", default="cxl",
                    choices=["cxl", "rdma", "dram", "hbm"])
    ap.add_argument("--mode", default="sac", choices=["sac", "dense"])
    ap.add_argument("--no-buffer", action="store_true",
                    help="disable the HiSparse hot buffer (cold-read "
                         "fabric charging)")
    ap.add_argument("--device-buffer", type=int, default=None,
                    help="hot-buffer entries per layer per slot "
                         "(default: cfg.sac.device_buffer_size)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="pool page tokens (default cfg.sac.page_size); "
                         "radix reuse credit is floored to whole pages")
    ap.add_argument("--prefetch-width", type=int, default=None,
                    help="speculative entries/layer/step beyond top-k "
                         "(default cfg.sac.prefetch_width)")
    ap.add_argument("--warmup-entries", type=int, default=None,
                    help="prefill warm-up seeds per layer per request "
                         "(default cfg.sac.warmup_entries)")
    ap.add_argument("--warmup-radix", type=int, default=None,
                    help="trailing radix-prefix tokens seeded per layer "
                         "at prefill (default cfg.sac.warmup_radix)")
    ap.add_argument("--link-budget-frac", type=float, default=None,
                    help="fraction of the pipeline hide window the "
                         "arbiter lets speculation fill per device "
                         "(default cfg.sac.link_budget_frac)")
    ap.add_argument("--min-prefetch-width", type=int, default=None,
                    help="granted-width floor under saturation "
                         "(default cfg.sac.min_prefetch_width)")
    ap.add_argument("--score-margin", type=float, default=None,
                    help="score-threshold speculation margin; < 0 = "
                         "pure rank window (default cfg.sac.score_margin)")
    ap.add_argument("--radix-headroom-frac", type=float, default=None,
                    help="pool free-page fraction below which request "
                         "finish evicts LRU cached prefixes (default "
                         "cfg.sac.radix_headroom_frac)")
    ap.add_argument("--replicate-horizon-steps", type=int, default=None,
                    help="decode steps over which a prefix replica's "
                         "pressure relief must amortize its copy cost "
                         "(default cfg.sac.replicate_horizon_steps)")
    ap.add_argument("--prefetch", action="store_true",
                    help="enable the fetch pipeline (speculative "
                         "prefetch + prefill warm-up + overlap queues; "
                         "serving/prefetch.py)")
    ap.add_argument("--arbiter", action="store_true",
                    help="enable cross-request prefetch budget "
                         "arbitration (serving/arbiter.py); implies "
                         "--prefetch — the arbiter governs speculation")
    ap.add_argument("--layer-sizing", default=None,
                    choices=["uniform", "windowed"],
                    help="hot-tier slot apportioning across layers "
                         "(LayerSizer; default cfg.sac.layer_sizing)")
    ap.add_argument("--placement", default=None,
                    choices=["round_robin", "first_fit", "least_loaded",
                             "pressure_aware", "radix_affinity"],
                    help="pool placement policy (core/placement.py); "
                         "pressure_aware lands new requests on the "
                         "least-pressured fabric link, radix_affinity "
                         "additionally weighs prefix locality (a cached "
                         "prompt prefix's device) against that pressure")
    ap.add_argument("--no-radix", action="store_true",
                    help="disable the radix prefix cache entirely "
                         "(serving/radix.py; the A/B baseline for "
                         "prefix-locality wins)")
    ap.add_argument("--replicate-prefixes", action="store_true",
                    help="hot-prefix replication (PR 6): copy a matched "
                         "prefix's pages to the least-pressured pool "
                         "device when corrected pressure on the owning "
                         "link covers the one-time copy cost, so "
                         "placement can split a hot prefix's load "
                         "across links (requires the radix cache)")
    ap.add_argument("--dedup-pages", action="store_true",
                    help="refcounted page dedup (PR 6): a same-device "
                         "prefix match shares the cached pages with the "
                         "new slot instead of booking private copies "
                         "(decode never mutates prefix pages)")
    ap.add_argument("--radix-admission", action="store_true",
                    help="radix-aware admission (PR 6): admit the "
                         "waiting request with the longest cached-"
                         "prefix match first (FCFS tie-break) instead "
                         "of strict FCFS")
    ap.add_argument("--admission", default=None,
                    choices=["fcfs", "radix", "edf"],
                    help="admission policy (PR 10, serving/policy/"
                         "admission.py): fcfs = submission order, "
                         "radix = longest cached-prefix match first, "
                         "edf = earliest TTFT deadline (arrival_s + "
                         "--slo-ttft) first with optional load "
                         "shedding; default = radix when "
                         "--radix-admission is set, else fcfs")
    ap.add_argument("--shed-queue-depth", type=int, default=None,
                    help="EDF load shedding (PR 10): drop the arrived "
                         "backlog beyond this many earliest-deadline "
                         "waiting requests — shed requests never "
                         "decode (default cfg.sac.shed_queue_depth; "
                         "0 = off)")
    ap.add_argument("--topology", default=None,
                    help="CXL fabric topology spec (PR 7, core/"
                         "fabric.py): e.g. 'tree:4x2' (4 devices "
                         "behind 2 switches), 'multi_switch:8x2', "
                         "'mesh:4x2'; default = flat star (one host "
                         "port per device — the pre-PR 7 accounting). "
                         "Traffic is charged per link SEGMENT and "
                         "placement/grants read bottleneck-segment "
                         "pressure along each path")
    ap.add_argument("--warmup-pressure-seed", action="store_true",
                    help="seed the placement pressure feed from BOOKED "
                         "prefill-write demand before the first decode "
                         "step (PR 7: wave-1 admissions stop herding "
                         "onto a hot prefix's owner)")
    ap.add_argument("--replica-reads", action="store_true",
                    help="replica-aware reads (PR 7): re-pick the "
                         "least-pressured copy of a cached prefix "
                         "every step instead of freezing the choice "
                         "at placement (requires the radix cache)")
    ap.add_argument("--resize-epsilon", type=float, default=None,
                    help="resize hysteresis: skip the online LayerSizer "
                         "re-apportioning when no layer's per-interval "
                         "miss rate moved more than this (default "
                         "cfg.sac.resize_epsilon)")
    ap.add_argument("--precision-weighted", action="store_true",
                    help="split each device's arbiter grant budget by "
                         "measured per-request prefetch precision "
                         "(implies --arbiter)")
    ap.add_argument("--resize-interval", type=int, default=0,
                    help="decode steps between online LayerSizer "
                         "re-apportionings of the hot tier from "
                         "measured per-layer miss rates (0 = off)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="shared-prefix workload: requests share their "
                         "first N prompt tokens with probability "
                         "--reuse-p (the radix prefix cache's regime; "
                         "0 = independent ShareGPT-style prompts)")
    ap.add_argument("--reuse-p", type=float, default=0.7,
                    help="prefix-group reuse probability for "
                         "--shared-prefix traces")
    # --- PR 8: continuous batching + disaggregated prefill ---
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate (req/s); 0 = "
                         "closed-loop, every request arrives at t=0. "
                         "Admission into freed slots is gated on the "
                         "virtual clock vs each request's arrival_s")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill (PR 8): splice each prompt in "
                         "over ceil(ctx/chunk) bounded chunks "
                         "interleaved with decode steps instead of "
                         "stalling the batch on the whole prompt "
                         "(0 = monolithic; decoded tokens are "
                         "bit-identical either way)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill (PR 8): prefill runs on "
                         "separate lanes sharing the virtual clock, "
                         "writes KV to the pool device over the fabric, "
                         "and the decode loop adopts the slot via a "
                         "handoff record")
    ap.add_argument("--prefill-lanes", type=int, default=None,
                    help="concurrent prefill lanes of the disaggregated "
                         "prefill engine (default "
                         "cfg.sac.prefill_lanes)")
    ap.add_argument("--diurnal", action="store_true",
                    help="use the diurnal_trace workload generator "
                         "(diurnal arrival rates around --arrival-rate, "
                         "bursts, heavy-tailed contexts, multi-tenant "
                         "prefix groups; requires --shared-prefix and "
                         "a finite --arrival-rate)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="diurnal_trace tenant count (prefix reuse "
                         "never crosses tenants)")
    ap.add_argument("--burst-p", type=float, default=0.0,
                    help="diurnal_trace per-arrival burst probability")
    ap.add_argument("--ctx-tail-alpha", type=float, default=0.0,
                    help="diurnal_trace Pareto tail index for "
                         "heavy-tailed context lengths (0 = off)")
    ap.add_argument("--slo-ttft", type=float, default=0.0,
                    help="arrival-anchored TTFT SLO target in seconds "
                         "(reported as slo_ttft_attainment; 0 = off)")
    ap.add_argument("--slo-tbt", type=float, default=0.0,
                    help="per-request mean TBT SLO target in seconds "
                         "(reported as slo_tbt_attainment; 0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import dataclasses

    import numpy as np

    from repro.configs import get_config
    from repro.serving.engine import Engine
    from repro.serving.request import (diurnal_trace, shared_prefix_trace,
                                       sharegpt_trace)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.precision_weighted and not args.arbiter:
        print("--precision-weighted implies --arbiter: enabling the "
              "budget arbiter")
        args.arbiter = True
    if args.arbiter and not args.prefetch:
        # the arbiter governs speculative prefetch; without the pipeline
        # it would be a silent no-op
        print("--arbiter implies --prefetch: enabling the fetch pipeline")
        args.prefetch = True
    overrides = {}
    # sparse SACConfig overrides: None = keep the config default (the
    # flag<->field map is enforced by sacheck's twin-coverage pass)
    for field in ("page_size", "prefetch_width", "warmup_entries",
                  "warmup_radix", "link_budget_frac",
                  "min_prefetch_width", "score_margin",
                  "radix_headroom_frac", "replicate_horizon_steps",
                  "resize_epsilon", "admission", "shed_queue_depth"):
        val = getattr(args, field)
        if val is not None:
            overrides[field] = val
    if args.slo_ttft > 0:
        # the EDF admission deadline and the summarize() attainment
        # target are the same knob — one SLO, consumed once through
        # the shared admission policy
        overrides["slo_ttft_s"] = args.slo_ttft
    if args.precision_weighted or args.resize_interval:
        overrides.update(precision_weighted=args.precision_weighted,
                         resize_interval=args.resize_interval)
    if overrides:
        cfg = dataclasses.replace(
            cfg, sac=dataclasses.replace(cfg.sac, **overrides))
    if cfg.enc_dec:
        raise SystemExit("serve driver targets decoder-only archs; "
                         "whisper decode is exercised in tests")
    if ((args.replicate_prefixes or args.dedup_pages
         or args.radix_admission or args.replica_reads)
            and args.no_radix):
        raise SystemExit("--replicate-prefixes/--dedup-pages/"
                         "--radix-admission/--replica-reads need the "
                         "radix cache (drop --no-radix)")
    eng = Engine(cfg, slots=args.slots, max_ctx=args.max_ctx,
                 backend=args.backend, mode=args.mode, seed=args.seed,
                 track_buffer=not args.no_buffer,
                 device_buffer=args.device_buffer,
                 prefetch=args.prefetch,
                 arbiter=args.arbiter or None,
                 layer_sizing=args.layer_sizing,
                 placement=args.placement,
                 radix=not args.no_radix,
                 replicate_prefixes=args.replicate_prefixes or None,
                 dedup_pages=args.dedup_pages or None,
                 radix_admission=args.radix_admission or None,
                 topology=args.topology,
                 warmup_pressure_seed=args.warmup_pressure_seed or None,
                 replica_reads=args.replica_reads or None,
                 prefill_chunk_tokens=args.prefill_chunk,
                 disagg=args.disagg or None,
                 prefill_lanes=args.prefill_lanes)
    rate = args.arrival_rate if args.arrival_rate > 0 else float("inf")
    if args.diurnal:
        if not args.shared_prefix or not np.isfinite(rate):
            raise SystemExit("--diurnal needs --shared-prefix and a "
                             "finite --arrival-rate")
        if args.shared_prefix >= args.ctx:
            raise SystemExit("--shared-prefix must be below --ctx")
        reqs = diurnal_trace(
            args.requests, prefix_len=args.shared_prefix,
            suffix_len=args.ctx - args.shared_prefix,
            output_len=args.out_len, base_rate=args.arrival_rate,
            reuse_p=args.reuse_p, n_tenants=args.tenants,
            burst_p=args.burst_p, ctx_tail_alpha=args.ctx_tail_alpha,
            seed=args.seed, vocab=cfg.vocab)
    elif args.shared_prefix:
        if args.shared_prefix >= args.ctx:
            raise SystemExit("--shared-prefix must be below --ctx")
        reqs = shared_prefix_trace(
            args.requests, prefix_len=args.shared_prefix,
            suffix_len=args.ctx - args.shared_prefix,
            output_len=args.out_len, reuse_p=args.reuse_p,
            seed=args.seed, arrival_rate=rate, vocab=cfg.vocab)
    else:
        reqs = sharegpt_trace(args.requests, context_len=args.ctx,
                              output_len=args.out_len, seed=args.seed,
                              ctx_jitter=0.0, arrival_rate=rate,
                              vocab=cfg.vocab)
    out = eng.run(reqs, slo_ttft_s=args.slo_ttft, slo_tbt_s=args.slo_tbt)
    out["buffer_hit_rate"] = eng.stats.hit_rate
    print(json.dumps({k: (round(v, 5) if isinstance(v, float) else v)
                      for k, v in out.items()}, indent=1))


if __name__ == "__main__":
    main()
