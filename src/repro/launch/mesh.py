"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:                    # older jax: meshes are Auto-only
    AxisType = None


def _make(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-mesh)."""
    return _make(tuple(shape), tuple(axes))
