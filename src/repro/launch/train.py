"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 300 --batch 8 --seq 128 [--reduced] [--ckpt-dir ckpts] \
        [--resume]

On this CPU container use --reduced (tiny same-family config).  On real
hardware the same driver runs the full config under the production mesh
(--mesh single|multi).  Fault tolerance: atomic checkpoints every
--ckpt-every steps (params, opt state, data cursor); --resume restarts
from the newest consistent snapshot, resharding onto whatever devices
exist (distributed/elastic.py).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="wsd",
                    choices=["wsd", "cosine", "const"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.models.model import build_model
    from repro.training import checkpoint as ckpt
    from repro.training.data import batch_iterator
    from repro.training.optimizer import OptConfig, init_opt_state
    from repro.training.train_loop import make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params)
    opt_cfg = OptConfig(lr=args.lr, schedule=args.schedule,
                        warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    start_step = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir):
        (state, start_step, extras) = ckpt.restore(
            args.ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed from step {start_step}")

    batches = batch_iterator(cfg, shape, seed=args.seed,
                             start_step=start_step)
    step_fn = jax.jit(make_train_step(model, opt_cfg, args.grad_accum),
                      donate_argnums=(0, 1))

    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq} steps={args.steps}")
    t0 = time.time()
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"  step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i-start_step+1):.2f}s/step)",
                  flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1,
                      {"params": params, "opt": opt_state},
                      extras={"data_step": i + 1, "arch": cfg.name})
            ckpt.prune(args.ckpt_dir, keep=3)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps,
                  {"params": params, "opt": opt_state},
                  extras={"data_step": args.steps, "arch": cfg.name})
    print("[train] done")


if __name__ == "__main__":
    main()
