import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms.

MUST be run as its own process (the device-count flag above is set before
any jax import and locks on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape decode_32k --mesh single [--mode sac] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # sweep (subprocesses)

Per cell this prints ``compiled.memory_analysis()`` (proves the program
fits per-chip HBM) and ``compiled.cost_analysis()``, and writes a JSON
record with trip-count-corrected HLO metrics (distributed/hlo_analysis)
and the three roofline terms:

    compute_s    = HLO_dot_FLOPs / 197e12        (per chip, bf16 peak)
    memory_s     = HLO_bytes / 819e9             (per chip HBM)
    collective_s = collective_bytes / 50e9       (per chip ICI link)
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

PEAK_FLOPS = 197e12     # TPU v5e bf16
HBM_BW = 819e9
ICI_BW = 50e9


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def np_prod_axes(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    p = 1
    for a in axes:
        p *= sizes.get(a, 1)
    return p


def batch_axes_for(mesh, batch: int):
    """Longest prefix of (pod, data) whose product divides batch."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in sizes and batch % (prod * sizes[a]) == 0:
            axes.append(a)
            prod *= sizes[a]
    return tuple(axes)


def _rec_pspec(shape, batch: int, model_size: int):
    """Heuristic spec for recurrent-state leaves: shard the batch axis,
    plus the first later axis divisible by the model-axis size."""
    spec = [None] * len(shape)
    b_ax = next((i for i, d in enumerate(shape) if d == batch), None)
    if b_ax is not None:
        spec[b_ax] = "__B__"
        for j in range(b_ax + 1, len(shape)):
            if shape[j] % model_size == 0 and shape[j] >= model_size:
                spec[j] = "model"
                break
    return spec


def serve_state_shardings(state_shapes, mesh, batch: int):
    baxes = batch_axes_for(mesh, batch)
    b_entry = baxes if baxes else None
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]

    def one(path_key, leaf):
        shape = leaf.shape
        if path_key in ("kv_pool", "idx_pool"):
            return NamedSharding(mesh, P(None, b_entry, "model", None))
        if path_key == "self_kv":
            return NamedSharding(mesh, P(None, b_entry, None, None))
        if path_key in ("cache_len", "dec_len"):
            return NamedSharding(mesh, P(b_entry))
        spec = _rec_pspec(shape, batch, model_size)
        spec = [b_entry if s == "__B__" else s for s in spec]
        return NamedSharding(mesh, P(*spec))

    out = {}
    for key, sub in state_shapes.items():
        if key in ("kv_pool", "idx_pool", "self_kv", "cache_len", "dec_len"):
            out[key] = one(key, sub)
        else:  # rec_* pytrees
            out[key] = jax.tree.map(lambda l: one("rec", l), sub)
    return out


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------


def parse_opts(env: Optional[str] = None) -> Dict:
    """REPRO_OPTS="hier_topk=1,pool_closure=1,moe_groups=32" -> dict."""
    s = env if env is not None else os.environ.get("REPRO_OPTS", "")
    out: Dict = {}
    for kv in s.split(","):
        if "=" not in kv:
            continue
        k, v = kv.split("=", 1)
        out[k.strip()] = int(v) if v.strip().isdigit() else v.strip()
    return out


def build_cell(arch: str, shape_name: str, mesh, mode: str = "sac",
               grad_accum: int = 8, opts: Optional[Dict] = None):
    """Returns (step_fn, in_shardings, in_specs, meta) for one cell."""
    from repro.configs import get_config, SHAPES_BY_NAME
    from repro.core.pool import make_pooled_fetch, local_fetch
    from repro.core.topk import make_hierarchical_topk
    from repro.distributed import sharding as shd
    from repro.models.model import (build_model, cell_is_supported,
                                    input_specs)
    from repro.training.optimizer import OptConfig, init_opt_state
    from repro.training.train_loop import make_train_step

    import dataclasses as _dc
    opts = dict(parse_opts(), **(opts or {}))
    grad_accum = int(opts.get("grad_accum", grad_accum))
    cfg = get_config(arch)
    if opts.get("kv_quant"):
        cfg = _dc.replace(cfg, sac=_dc.replace(cfg.sac,
                                               kv_quant=opts["kv_quant"]))
    shape = SHAPES_BY_NAME[shape_name]
    skip = cell_is_supported(cfg, shape, mode)
    if skip:
        return None, None, None, {"skip": skip}

    baxes = batch_axes_for(mesh, shape.global_batch)
    rules = shd.TRAIN_RULES if shape.kind == "train" else shd.SERVE_RULES
    if shape.kind != "train" and not baxes:
        # batch unshardable (e.g. long_500k B=1): the data axis is idle, so
        # row-sharding weights over it is free capacity/bandwidth — keep it
        # (the D-unsharded serve rule only pays off when batch owns `data`)
        rules = dict(rules, D=("data",))

    if shape.kind == "decode" and cfg.has_attention:
        fetch = make_pooled_fetch(mesh, batch_axes=baxes)
    else:
        fetch = local_fetch
    topk_fn = None
    if opts.get("hier_topk") and shape.kind == "decode" and cfg.sac.enabled:
        topk_fn = make_hierarchical_topk(mesh, cfg.sac.topk,
                                         batch_axes=baxes)
    if opts.get("moe_groups") == "auto":
        opts["moe_groups"] = int(np_prod_axes(mesh, baxes))
    model = build_model(cfg, fetch_fn=fetch, mode=mode, topk_fn=topk_fn,
                        opts=opts)

    meta = {"arch": arch, "shape": shape_name, "mode": model.mode,
            "kind": shape.kind, "opts": {k: v for k, v in opts.items()},
            "batch": shape.global_batch, "seq": shape.seq_len}

    with shd.use_rules(rules, mesh):
        p_shard = shd.params_shardings(model.specs, mesh, rules=rules)
        b_entry = baxes if baxes else None

        if shape.kind == "train":
            if cfg.enc_dec:
                ga = min(grad_accum, shape.global_batch)
            else:
                ga = grad_accum if shape.global_batch % grad_accum == 0 else 1
            step = make_train_step(model, OptConfig(), ga)
            opt_shard = {"m": jax.tree.map(lambda s: s, p_shard),
                         "v": jax.tree.map(lambda s: s, p_shard),
                         "step": NamedSharding(mesh, P())}
            batch_specs = input_specs(cfg, shape)
            bshard = {k: NamedSharding(
                mesh, P(b_entry, "model" if v.ndim == 3 else None)
                if v.ndim <= 2 else P(b_entry, "model", None))
                for k, v in batch_specs.items()}
            in_sh = (p_shard, opt_shard, bshard)
            p_spec = model.param_shapes()
            opt_spec = jax.eval_shape(init_opt_state, p_spec)
            in_spec = (p_spec, opt_spec, batch_specs)
            meta["grad_accum"] = ga
            return step, in_sh, in_spec, meta

        if shape.kind == "prefill":
            def step(params, batch):
                if cfg.enc_dec:
                    return model.prefill(params, batch["frames"])
                return model.prefill(params, batch["tokens"])
            batch_specs = input_specs(cfg, shape)
            bshard = {k: NamedSharding(
                mesh, P(b_entry, "model", None) if v.ndim == 3
                else P(b_entry, None))
                for k, v in batch_specs.items()}
            return step, (p_shard, bshard), \
                (model.param_shapes(), batch_specs), meta

        # decode
        def step(params, state, tokens):
            return model.decode(params, state, tokens)
        specs = input_specs(cfg, shape, model=model)
        st_shard = serve_state_shardings(specs["state"], mesh,
                                         shape.global_batch)
        tok_shard = NamedSharding(mesh, P(b_entry))
        return step, (p_shard, st_shard, tok_shard), \
            (model.param_shapes(), specs["state"], specs["tokens"]), meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, mode: str,
             out_dir: Optional[str] = None, verbose: bool = True) -> Dict:
    from repro.launch.mesh import make_production_mesh
    from repro.distributed.hlo_analysis import hlo_metrics
    from repro.distributed import sharding as shd
    from repro.configs import get_config, SHAPES_BY_NAME

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step, in_sh, in_spec, meta = build_cell(arch, shape_name, mesh, mode)
    meta["mesh"] = "multi" if multi_pod else "single"
    meta["n_devices"] = mesh.devices.size
    if step is None:
        meta["status"] = "skipped"
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name}: {meta['skip']}")
        return meta

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    rules = shd.TRAIN_RULES if shape.kind == "train" else shd.SERVE_RULES
    with shd.use_rules(rules, mesh):
        with mesh:
            donate = (1,) if meta["kind"] == "decode" else ()
            lowered = jax.jit(step, in_shardings=in_sh,
                              donate_argnums=donate).lower(*in_spec)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = hlo_metrics(compiled.as_text())

    chips = mesh.devices.size
    compute_s = hlo["flops"] / PEAK_FLOPS
    memory_s = hlo["bytes"] / HBM_BW
    collective_s = hlo["collective_bytes"] / ICI_BW
    model_flops = _model_flops(cfg, shape)
    per_chip_model = model_flops / chips

    rec = dict(meta)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        mem_per_device={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None) or
            getattr(mem, "temp_size_in_bytes", 0),
        },
        xla_cost={k: cost.get(k) for k in ("flops", "bytes accessed")},
        hlo_flops=hlo["flops"], hlo_bytes=hlo["bytes"],
        collective_bytes=hlo["collective_bytes"],
        collective_breakdown=hlo["collective_breakdown"],
        collective_counts=hlo["collective_counts"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=max(("compute", compute_s), ("memory", memory_s),
                     ("collective", collective_s), key=lambda kv: kv[1])[0],
        model_flops=model_flops,
        useful_flops_ratio=(per_chip_model / hlo["flops"]
                            if hlo["flops"] else None),
    )
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} [{rec['mesh']}][{rec['mode']}]"
              f" OK lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print(f"  memory_analysis: args={rec['mem_per_device']['argument_bytes']}"
              f" temp={rec['mem_per_device']['temp_bytes']}")
        print(f"  cost_analysis: flops={cost.get('flops')}"
              f" bytes={cost.get('bytes accessed')}")
        print(f"  roofline: compute={compute_s*1e3:.2f}ms"
              f" memory={memory_s*1e3:.2f}ms"
              f" collective={collective_s*1e3:.2f}ms"
              f" dominant={rec['dominant']}"
              f" useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = os.environ.get("REPRO_TAG", "")
        tag = f"__{tag}" if tag else ""
        name = f"{arch}__{shape_name}__{rec['mesh']}__{rec['mode']}{tag}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS convention: 6*N*D train (N_active for MoE), 2*N_active
    per generated token for decode, 2*N_active*tokens prefill (+ dense-
    attention quadratic term for attention archs on train/prefill)."""
    n_act = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        base = 6 * n_act * B * S
        if cfg.has_attention:
            base += 6 * cfg.n_attn_layers * B * S * S * cfg.hd \
                * cfg.n_heads * 0.5
        return base
    if shape.kind == "prefill":
        base = 2 * n_act * B * S
        if cfg.has_attention:
            base += 2 * cfg.n_attn_layers * B * S * S * cfg.hd \
                * cfg.n_heads * 2 * 0.5
        return base
    # decode: one token per request
    base = 2 * n_act * B
    if cfg.has_attention and cfg.sac.enabled:
        k = cfg.sac.topk
        dims = (cfg.kv_lora_rank + cfg.qk_rope_dim) if cfg.mla \
            else 2 * cfg.n_kv_heads * cfg.hd
        base += 2 * cfg.n_attn_layers * B * (k * dims + S * cfg.sac.d_idx)
    return base


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------

CELLS_ENV = "REPRO_DRYRUN_CELLS"


def sweep(args):
    """Run every cell in its own subprocess (fresh device-count flag,
    crash isolation); aggregate JSONs land in --out."""
    from repro.configs import ASSIGNED, SHAPES

    archs = args.archs.split(",") if args.archs else ASSIGNED
    shapes = args.shapes.split(",") if args.shapes else [s.name for s in SHAPES]
    meshes = args.meshes.split(",") if args.meshes else ["single", "multi"]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                out = os.path.join(args.out)
                marker = os.path.join(
                    out, f"{arch}__{shape}__{mesh_kind}__{args.mode}.json")
                if args.resume and os.path.exists(marker):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", mesh_kind, "--mode", args.mode,
                       "--out", out]
                print(">>", " ".join(cmd), flush=True)
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures.append((arch, shape, mesh_kind))
                    print(f"!! FAILED {arch} {shape} {mesh_kind}", flush=True)
    print(f"sweep done; {len(failures)} failures: {failures}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--mode", choices=["sac", "dense"], default="sac")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", help="comma list for --all")
    ap.add_argument("--shapes", help="comma list for --all")
    ap.add_argument("--meshes", help="comma list for --all")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    if args.all:
        failures = sweep(args)
        sys.exit(1 if failures else 0)
    rec = run_cell(args.arch, args.shape, multi_pod=args.mesh == "multi",
                   mode=args.mode, out_dir=args.out)
    sys.exit(0 if rec.get("status") in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
