"""Disaggregated KV-cache pool (the paper's CXL pool, mapped to TPU).

The pool is a logical array ``[B, S, d]`` per layer whose sequence axis is
sharded across the ``model`` mesh axis — the pod's aggregate HBM plays the
role of the CXL memory pool, and ICI plays the role of the CXL fabric
(DESIGN.md §2).  The **read path** is a fine-grained gather of the per-layer
top-k entries:

  - each pool shard gathers the indices that fall inside its range
    (clamped + masked ``take_along_axis`` — on real TPU this is the Pallas
    scalar-prefetch DMA gather, ``kernels/gather_kv.py``),
  - a single ``psum`` over the ``model`` axis assembles the full ``[B,k,d]``
    result on every TP rank (which is what TP attention needs anyway).

Per step this moves exactly ``k * entry_bytes`` per request over the
fabric — the paper's "fetch only the top-k on demand" — instead of the
full-prefix transfer an RDMA-style full-prefetch system performs.

The **write path** scatters each request's newly decoded entry to the shard
that owns its position (a masked in-place update, no collective: the new
entry is produced TP-replicated by the layer, every shard keeps its slice).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                   # jax >= 0.5
    _shard_map = jax.shard_map
except AttributeError:                 # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

FetchFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


# ---------------------------------------------------------------------------
# read path
# ---------------------------------------------------------------------------


def local_fetch(pool_layer: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Single-shard gather. pool_layer: [B, S, d]; idx: [B, k] -> [B, k, d]."""
    return jnp.take_along_axis(pool_layer, idx[..., None], axis=1)


def _pooled_fetch_local(pool, idx, *, axis: str):
    """shard_map body: masked local gather + psum over the pool axis.

    The optimization barrier pins the gather -> mask -> psum order: the
    CPU backend's bf16 all-reduce is wrapped in converts that the XLA
    simplifier otherwise commutes through the gather and hoists out of
    the layer scan — materializing an f32 copy of the ENTIRE pool
    (§Perf iteration C3).  On TPU the psum is native bf16 and the
    barrier is a no-op.
    """
    S_local = pool.shape[1]
    rank = jax.lax.axis_index(axis)
    local = idx - rank * S_local
    in_bounds = (local >= 0) & (local < S_local)
    local_c = jnp.clip(local, 0, S_local - 1)
    vals = jnp.take_along_axis(pool, local_c[..., None], axis=1)
    vals = jnp.where(in_bounds[..., None], vals, 0)
    vals = jax.lax.optimization_barrier(vals)
    return jax.lax.psum(vals, axis)


def make_pooled_fetch(mesh: Mesh, *, batch_axes=("pod", "data"),
                      pool_axis: str = "model") -> FetchFn:
    """Build the pooled-HBM fetch: [B@batch_axes, S@pool_axis, d] x [B, k]
    -> [B, k, d] replicated over pool_axis (ready for TP attention)."""
    batch = tuple(a for a in batch_axes if a in mesh.axis_names)
    spec_pool = P(batch, pool_axis, None)
    spec_idx = P(batch, None)
    spec_out = P(batch, None, None)
    body = functools.partial(_pooled_fetch_local, axis=pool_axis)
    return _shard_map(body, mesh=mesh,
                      in_specs=(spec_pool, spec_idx),
                      out_specs=spec_out)


def make_fetch_fn(mesh: Optional[Mesh], backend: str = "local",
                  **kw) -> FetchFn:
    """Resolve the fetch callback for a backend name.

    ``local``      — single-shard take_along_axis (tests, host_dram engine).
    ``pooled_hbm`` — shard_map collective gather over the pool axis.
    """
    if backend == "pooled_hbm":
        if mesh is None:
            raise ValueError("pooled_hbm backend requires a mesh")
        return make_pooled_fetch(mesh, **kw)
    if backend in ("local", "host_dram"):
        return local_fetch
    raise ValueError(f"unknown pool backend {backend!r}")


# ---------------------------------------------------------------------------
# write path
# ---------------------------------------------------------------------------


def pool_write(pool: jnp.ndarray, new_entries: jnp.ndarray,
               pos: jnp.ndarray) -> jnp.ndarray:
    """Write one new entry per (layer, request) at per-request positions.

    pool: [L, B, S, d]; new_entries: [L, B, d]; pos: [B] -> updated pool.

    Implemented as a masked select rather than lax.scatter (§Perf
    iteration C2): elementwise select keeps the S axis sharded with zero
    collectives (each pool shard blends only its own rows), preserves the
    pool dtype (XLA:CPU lowers bf16 scatter through full f32 pool copies),
    and aliases the donated pool buffer.
    """
    S = pool.shape[2]
    pos_c = jnp.clip(pos, 0, S - 1)
    mask = (jnp.arange(S, dtype=jnp.int32)[None, :]
            == pos_c[:, None])                       # [B, S]
    return jnp.where(mask[None, :, :, None],
                     new_entries.astype(pool.dtype)[:, :, None, :], pool)


def pool_write_prefill(pool: jnp.ndarray, entries: jnp.ndarray,
                       offset: int = 0) -> jnp.ndarray:
    """Bulk layer-wise write of prefill entries (the paper's GPU write path).

    pool: [L, B, S, d]; entries: [L, B, T, d] -> pool with [offset:offset+T)
    filled.  A contiguous dynamic-update-slice: each pool shard receives its
    slice of the new entries (reshard on entry, no host staging).
    """
    return jax.lax.dynamic_update_slice(
        pool, entries.astype(pool.dtype), (0, 0, offset, 0))


# ---------------------------------------------------------------------------
# device interleaving (paper §4.3.3) — lives in the shared placement
# substrate; re-exported here for back-compat.
# ---------------------------------------------------------------------------

from repro.core.placement import interleaved_assignment  # noqa: E402,F401
