"""Shared fabric-traffic accounting substrate.

One stats schema — :class:`TrafficStats` — for every serving layer:

  - ``SACSystem`` (core/sac.py) charges real engine fetches/writes here;
  - ``Engine`` (serving/engine.py) exposes the same object as
    ``EngineStats.traffic`` (buffer hits/misses are *measured* from the
    in-graph HiSparse buffer, core/hisparse.py);
  - ``simulate()`` (serving/simulator.py) accumulates its analytic
    per-device step demand through the same accountant.

The point of sharing the schema is paper §5.5: SAC's wins hinge on
*miss-only* fabric traffic, so the engine's measured hits/misses and the
simulator's analytic hit model must be comparable numbers — the parity
test (tests/test_engine_buffer.py) grounds one against the other.

Since PR 7 the charging unit is the **link segment** of a
:class:`~repro.core.fabric.FabricTopology`: every transfer is routed
host->device and books occupancy (``seconds / bandwidth_scale +
latency_s``) on EACH segment of its path, with the end-to-end time being
the bottleneck segment's occupancy.  Per-device counters are kept as
views (demand bytes / issued seconds of that device's transfers), and
under the default flat-star topology — one dedicated segment per device,
``sid == device`` — every per-segment number degenerates exactly to the
historical flat per-device accounting (tests/test_fabric.py pins this
bit-for-bit).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Union

from repro.core.fabric import FabricTopology
from repro.core.transfer import (FABRICS, QOS_DEMAND, QOS_SPECULATIVE,
                                 FabricModel, PipelineModel)


@dataclasses.dataclass
class TrafficStats:
    """Cumulative fabric-traffic counters (one schema for all layers).

    ``fabric_time_s`` is the *issued* total: every second the fabric
    links were busy.  ``exposed_fabric_s`` is the part that was NOT
    hidden behind compute by the fetch pipeline and therefore landed on
    the step critical path (serving/prefetch.py; without an overlap model
    the two are equal).  Invariant: ``issued >= exposed >= 0``.
    """

    n_devices: int = 1
    n_segments: int = 0              # fabric link segments (== n_devices
                                     # under the default flat star)
    bytes_fetched: float = 0.0       # entries/pages pulled over the fabric
    bytes_written: float = 0.0       # prefill / decode write-back traffic
    entries_fetched: float = 0.0     # discrete entries pulled over the fabric
    buffer_hits: float = 0.0         # HiSparse hot-tier hits (no fabric)
    buffer_misses: float = 0.0       # hot-tier misses (crossed the fabric)
    fabric_time_s: float = 0.0       # seconds issued on the fabric
    exposed_fabric_s: float = 0.0    # issued seconds not hidden by compute
    prefetched_entries: float = 0.0  # speculative/warm-up entries inserted
    prefetch_useful: float = 0.0     # prefetched entries later demand-hit
    prefetch_bytes: float = 0.0      # fabric bytes spent on prefetch
    spec_yielded_s: float = 0.0      # speculative segment-seconds dropped
                                    # at congested segments by the QoS
                                    # yield rule (topologies built with
                                    # qos_spec_yield; core/fabric.py)
    critical_demand_bytes: float = 0.0   # sum over steps of the MAX per-
                                    # SEGMENT demand bytes — the step
                                    # fetch critical path.  Unlike end-to-
                                    # end exposed seconds this is
                                    # independent of the hide-window
                                    # volume (how many steps the run
                                    # took), so it is the fair link-
                                    # hotspot envelope metric
                                    # (benchmarks/locality_gate.py).
                                    # Flat star: segments == devices, so
                                    # this is the pre-PR 7 per-device max
    critical_issued_s: float = 0.0  # engine twin: sum over steps of the
                                    # max per-SEGMENT issued seconds (the
                                    # overlap queues' critical link)
    device_demand_bytes: List[float] = dataclasses.field(
        default_factory=list)       # cumulative fetch demand per device
    device_issued_s: List[float] = dataclasses.field(
        default_factory=list)       # cumulative issued transfer seconds
                                    # per device (end-to-end bottleneck
                                    # time of that device's transfers)
    device_prefetch_s: List[float] = dataclasses.field(
        default_factory=list)       # issued seconds spent on prefetch, per
                                    # device (subset of device_issued_s) —
                                    # the arbiter's per-link pressure split
    segment_demand_bytes: List[float] = dataclasses.field(
        default_factory=list)       # cumulative fetch bytes crossing each
                                    # fabric segment (a byte is counted on
                                    # EVERY segment of its path)
    segment_issued_s: List[float] = dataclasses.field(
        default_factory=list)       # cumulative occupancy seconds per
                                    # segment (seconds/bandwidth_scale +
                                    # latency per transfer)
    segment_exposed_s: List[float] = dataclasses.field(
        default_factory=list)       # per-segment unhidden tails (subset
                                    # of segment_issued_s)
    segment_prefetch_s: List[float] = dataclasses.field(
        default_factory=list)       # speculative share of segment_issued_s
    device_anomalies: int = 0       # out-of-range device ids seen at the
                                    # accounting boundary (clamped once and
                                    # counted instead of silently aliased)
    request_pf: Dict[Hashable, List[float]] = dataclasses.field(
        default_factory=dict)       # per-request [inserted, useful]
                                    # prefetch attribution — the arbiter's
                                    # precision-weighting signal
    request_demand_s: Dict[Hashable, float] = dataclasses.field(
        default_factory=dict)       # per-request issued DEMAND seconds
                                    # (misses + writes, never prefetch) —
                                    # lets the pressure feed subtract a
                                    # finishing request's own share from
                                    # its link immediately instead of
                                    # waiting for the EMA to decay it

    def __post_init__(self):
        if self.n_segments <= 0:
            self.n_segments = self.n_devices
        if not self.device_demand_bytes:
            self.device_demand_bytes = [0.0] * self.n_devices
        if not self.device_issued_s:
            self.device_issued_s = [0.0] * self.n_devices
        if not self.device_prefetch_s:
            self.device_prefetch_s = [0.0] * self.n_devices
        if not self.segment_demand_bytes:
            self.segment_demand_bytes = [0.0] * self.n_segments
        if not self.segment_issued_s:
            self.segment_issued_s = [0.0] * self.n_segments
        if not self.segment_exposed_s:
            self.segment_exposed_s = [0.0] * self.n_segments
        if not self.segment_prefetch_s:
            self.segment_prefetch_s = [0.0] * self.n_segments

    def device_demand_s(self) -> List[float]:
        """Per-device issued seconds attributable to *demand* traffic
        (total issued minus the speculative share) — the link-pressure
        signal flat-topology consumers read."""
        return [t - p for t, p in zip(self.device_issued_s,
                                      self.device_prefetch_s)]

    def segment_demand_s(self) -> List[float]:
        """Per-SEGMENT issued seconds attributable to demand traffic —
        the pressure signal topology-aware consumers (DemandTracker,
        Placer) read; under the flat star it equals
        :meth:`device_demand_s` element-for-element."""
        return [t - p for t, p in zip(self.segment_issued_s,
                                      self.segment_prefetch_s)]

    @property
    def hit_rate(self) -> float:
        tot = self.buffer_hits + self.buffer_misses
        return self.buffer_hits / tot if tot else 0.0

    @property
    def total_bytes(self) -> float:
        return self.bytes_fetched + self.bytes_written

    @property
    def issued_fabric_s(self) -> float:
        return self.fabric_time_s

    @property
    def prefetch_wasted(self) -> float:
        """Prefetched entries never demand-hit (evicted unused, or still
        resident unused).  ``prefetched == useful + wasted`` always."""
        return self.prefetched_entries - self.prefetch_useful

    @property
    def prefetch_precision(self) -> float:
        return (self.prefetch_useful / self.prefetched_entries
                if self.prefetched_entries else 0.0)

    def request_precision(self, key: Hashable, prior: float = 1.0,
                          pseudo: float = 8.0) -> float:
        """One request's measured prefetch precision (useful / inserted),
        Laplace-smoothed toward an optimistic ``prior`` with ``pseudo``
        virtual entries.  The smoothing matters: a fresh request's first
        inserts have not had a chance to be demand-hit yet, and a raw
        0/N estimate would starve it before the signal exists (a
        feedback loop — starved requests never accumulate the inserts
        that would redeem them).  Heavily-wasteful speculators still
        converge to ~0."""
        ins, use = self.request_pf.get(key, (0.0, 0.0))
        return (use + pseudo * prior) / (ins + pseudo)

    def drop_request(self, key: Hashable) -> None:
        """Forget a finished request's prefetch and demand attribution
        (the key — an engine slot or a request id — is about to be
        reused)."""
        self.request_pf.pop(key, None)
        self.request_demand_s.pop(key, None)


class OverlapQueue:
    """Per-segment double-buffered fetch queues (issued vs exposed split).

    Fetch seconds are *issued* along a device's fabric path as the step
    discovers its misses (and prefetch candidates); at step end ``drain``
    hides as much as the :class:`~repro.core.transfer.PipelineModel`
    window allows and returns the step's *exposed* stall — the slowest
    segment's unhidden tail, since the step cannot advance past its
    critical-path link.

    QoS: each segment keeps separate demand and speculative backlogs.
    On a topology with ``qos_spec_yield``, demand drains first; the
    speculative backlog is serviced only from the segment's leftover hide
    window, and the remainder is *yielded* (dropped from this step's
    exposure — speculated entries are stale by the next step — and
    accumulated in ``spec_yielded_s``).  Without the yield flag (and
    under the default flat star) both classes share the window exactly as
    one queue did pre-PR 7.
    """

    def __init__(self, topology: Union[int, FabricTopology],
                 pipeline: PipelineModel):
        if not isinstance(topology, FabricTopology):
            topology = FabricTopology.flat_star(max(int(topology), 1))
        self.topology = topology
        self.pipeline = pipeline
        n = topology.n_segments
        self._pending_dem = [0.0] * n
        self._pending_spec = [0.0] * n
        self.spec_yielded_s = 0.0            # cumulative yielded seconds
        self.last_yielded_s = 0.0            # yielded by the last drain
        self.last_exposed = [0.0] * n        # per-segment exposed tails
                                             # of the last drain

    def issue(self, device: int, seconds: float,
              qos: int = QOS_DEMAND) -> None:
        # an aliased id would charge the WRONG link's hide window;
        # callers (FabricAccountant) validate at the accounting boundary,
        # so an IndexError from route() here is a programming error
        charges = self.topology.segment_charge(device, seconds)
        if seconds <= 0:
            return
        pend = (self._pending_spec if qos == QOS_SPECULATIVE
                else self._pending_dem)
        for sid, c in charges:
            pend[sid] += c

    @property
    def pending_s(self) -> float:
        return sum(self._pending_dem) + sum(self._pending_spec)

    @property
    def peak_pending_s(self) -> float:
        """This step's critical-path segment: the max per-segment queue."""
        return max((d + s for d, s in zip(self._pending_dem,
                                          self._pending_spec)),
                   default=0.0)

    def drain(self, compute_s: float) -> float:
        """End-of-step: return exposed seconds, clear the queues."""
        window = self.pipeline.hide_window_s(compute_s)
        yielded = 0.0
        exposed = [0.0] * len(self._pending_dem)
        for sid, (dem, spec) in enumerate(zip(self._pending_dem,
                                              self._pending_spec)):
            if self.topology.qos_spec_yield:
                # demand owns the segment; speculation gets the leftover
                # window or is dropped (never exposed, never deferred)
                exposed[sid] = self.pipeline.exposed_time(dem, compute_s)
                leftover = max(0.0, window - dem)
                yielded += max(0.0, spec - min(spec, leftover))
            else:
                exposed[sid] = self.pipeline.exposed_time(dem + spec,
                                                          compute_s)
        self.last_exposed = exposed
        self.last_yielded_s = yielded
        self.spec_yielded_s += yielded
        self._pending_dem = [0.0] * len(self._pending_dem)
        self._pending_spec = [0.0] * len(self._pending_spec)
        return max(exposed, default=0.0)


class FabricAccountant:
    """Charges fabric operations against a :class:`FabricModel` and keeps
    one :class:`TrafficStats` for every consumer.

    Two usage styles:

      - **timed ops** (real engine): ``sparse_fetch`` / ``bulk_fetch`` /
        ``write_back`` return seconds from the calibrated fabric model and
        accumulate bytes + time;
      - **per-step demand** (simulator): ``add_step_demand`` accumulates a
        decode step's per-device byte demand; ``drain_step`` returns the
        per-SEGMENT backlog (the slowest segment is the step's fetch
        critical path) and folds it into the cumulative stats;
        ``charge_seconds`` books the time the caller computed from that
        demand.

    Routing: every op names its endpoint ``device``; the accountant routes
    it through ``self.topology`` and books per-segment occupancy
    (``Segment.charge``) on each path segment.  The *returned* transfer
    time is the path bottleneck's occupancy — identical to the raw model
    time under the default flat star.

    Overlap: without ``enable_overlap``, every charged second is also
    exposed (``charge_exposed`` is called by the timed ops).  With an
    :class:`OverlapQueue` enabled, timed ops *issue* into the per-segment
    queues instead and the caller drains once per step with its compute
    window (``drain_overlap``) — only the unhidden tail lands in
    ``exposed_fabric_s``.
    """

    def __init__(self, fabric: Optional[FabricModel] = None, *,
                 backend: Optional[str] = None, n_devices: int = 1,
                 topology: Union[str, FabricTopology, None] = None):
        if fabric is None and backend is not None:
            fabric = FABRICS[backend]
        self.fabric = fabric
        if isinstance(topology, FabricTopology):
            n_devices = topology.n_devices
        else:
            topology = FabricTopology.from_spec(topology, n_devices)
        self.topology: FabricTopology = topology
        self.stats = TrafficStats(n_devices=n_devices,
                                  n_segments=topology.n_segments)
        self._seg_step_dem = [0.0] * topology.n_segments
        self._seg_step_spec = [0.0] * topology.n_segments
        self._dev_step = [0.0] * n_devices
        self.step_spec_bytes: List[float] = [0.0] * topology.n_segments
        self.overlap: Optional[OverlapQueue] = None

    # -- overlap (fetch pipeline) ------------------------------------------
    def enable_overlap(self, pipeline: PipelineModel) -> OverlapQueue:
        self.overlap = OverlapQueue(self.topology, pipeline)
        return self.overlap

    def charge_exposed(self, seconds: float) -> None:
        self.stats.exposed_fabric_s += max(seconds, 0.0)

    def drain_overlap(self, compute_s: float) -> float:
        """Drain the per-segment queues against this step's compute window
        and book the exposed tail.  No-op (0.0) when overlap is off —
        timed ops then charge exposed at issue time."""
        if self.overlap is None:
            return 0.0
        self.stats.critical_issued_s += self.overlap.peak_pending_s
        exposed = self.overlap.drain(compute_s)
        for sid, e in enumerate(self.overlap.last_exposed):
            self.stats.segment_exposed_s[sid] += e
        self.stats.spec_yielded_s += self.overlap.last_yielded_s
        self.charge_exposed(exposed)
        return exposed

    def _book_time(self, seconds: float, device: int,
                   qos: int = QOS_DEMAND) -> None:
        """Issued seconds (raw device-link time — the queue re-routes):
        queue behind compute if overlap is on, else expose immediately
        (the serial seed semantics)."""
        if self.overlap is not None:
            self.overlap.issue(device, seconds, qos)
        else:
            self.charge_exposed(self.topology.transfer_seconds(device,
                                                               seconds))
            for sid, c in self.topology.segment_charge(device, seconds):
                self.stats.segment_exposed_s[sid] += c

    def _charge_path(self, device: int, seconds: float,
                     qos: int = QOS_DEMAND) -> float:
        """Book per-segment issued occupancy for one transfer and return
        the end-to-end (bottleneck-segment) transfer time."""
        if seconds <= 0:
            return 0.0
        worst = 0.0
        for sid, c in self.topology.segment_charge(device, seconds):
            self.stats.segment_issued_s[sid] += c
            if qos == QOS_SPECULATIVE:
                self.stats.segment_prefetch_s[sid] += c
            worst = max(worst, c)
        return worst

    @property
    def n_devices(self) -> int:
        return self.stats.n_devices

    @property
    def n_segments(self) -> int:
        return self.stats.n_segments

    def _resolve_device(self, device: int) -> int:
        """Validate a device id at the accounting boundary.

        A silently aliased id (the pre-PR 4 ``dev % n`` convention) would
        charge the WRONG link's budget and feed the arbiter/placer a
        corrupted pressure signal.  Out-of-range ids are clamped ONCE
        here — every downstream counter then indexes directly — and the
        anomaly is counted in ``TrafficStats.device_anomalies`` so tests
        and dashboards can see it happened.
        """
        if 0 <= device < self.n_devices:
            return device
        self.stats.device_anomalies += 1
        return min(max(device, 0), self.n_devices - 1)

    def _attribute_demand(self, key: Optional[Hashable], t: float) -> None:
        """Book issued DEMAND seconds against one request (never called
        on the prefetch path — speculation is not the request's demand
        share and must not be subtracted from its link at departure)."""
        if key is not None and t > 0:
            self.stats.request_demand_s[key] = \
                self.stats.request_demand_s.get(key, 0.0) + t

    # -- timed ops (engine / SACSystem) ------------------------------------
    def sparse_fetch(self, n_entries: int, entry_bytes: int, *,
                     device: int = 0, contention: float = 1.0,
                     key: Optional[Hashable] = None,
                     qos: int = QOS_DEMAND) -> float:
        """Fine-grained fetch of ``n_entries`` discrete entries.

        ``key`` attributes the issued seconds to one request
        (``TrafficStats.request_demand_s``) — the per-request demand
        share the pressure feed subtracts when that request departs.
        """
        if n_entries <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        device = self._resolve_device(device)
        t = self.fabric.sparse_fetch_time(n_entries, entry_bytes,
                                          contention=contention)
        n_bytes = n_entries * entry_bytes
        self.stats.bytes_fetched += n_bytes
        self.stats.entries_fetched += n_entries
        self.stats.device_demand_bytes[device] += n_bytes
        for sid in self.topology.route(device):
            self.stats.segment_demand_bytes[sid] += n_bytes
        tt = self._charge_path(device, t, qos)
        self.stats.fabric_time_s += tt
        self.stats.device_issued_s[device] += tt
        if qos != QOS_SPECULATIVE:
            self._attribute_demand(key, tt)
        self._book_time(t, device, qos)
        return tt

    def prefetch_fetch(self, n_entries: int, entry_bytes: int, *,
                       device: int = 0, contention: float = 1.0) -> float:
        """Speculative/warm-up fetch of ``n_entries`` entries: same fabric
        cost and accounting as a demand fetch, additionally attributed to
        prefetch traffic (and issued as ``QOS_SPECULATIVE``, so it yields
        at congested segments on QoS topologies)."""
        device = self._resolve_device(device)
        t = self.sparse_fetch(n_entries, entry_bytes, device=device,
                              contention=contention, qos=QOS_SPECULATIVE)
        if n_entries > 0:
            self.stats.prefetch_bytes += n_entries * entry_bytes
            self.stats.device_prefetch_s[device] += t
        return t

    def bulk_fetch(self, n_bytes: float, *, device: int = 0,
                   contention: float = 1.0,
                   key: Optional[Hashable] = None) -> float:
        """Streaming fetch of a contiguous region (full-prefetch path)."""
        if n_bytes <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        device = self._resolve_device(device)
        t = self.fabric.bulk_transfer_time(n_bytes, contention=contention)
        self.stats.bytes_fetched += n_bytes
        self.stats.device_demand_bytes[device] += n_bytes
        for sid in self.topology.route(device):
            self.stats.segment_demand_bytes[sid] += n_bytes
        tt = self._charge_path(device, t)
        self.stats.fabric_time_s += tt
        self.stats.device_issued_s[device] += tt
        self._attribute_demand(key, tt)
        self._book_time(t, device)
        return tt

    def write_back(self, n_bytes: float, *, device: int = 0,
                   contention: float = 1.0,
                   key: Optional[Hashable] = None) -> float:
        """Pool write (prefill bulk write / decode write-back).

        ``device`` matters for the arbiter's per-link demand signal: a
        prefill write lands on the request's pool device, not device 0.
        """
        if n_bytes <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        device = self._resolve_device(device)
        t = self.fabric.bulk_transfer_time(n_bytes, contention=contention)
        self.stats.bytes_written += n_bytes
        tt = self._charge_path(device, t)
        self.stats.fabric_time_s += tt
        self.stats.device_issued_s[device] += tt
        self._attribute_demand(key, tt)
        self._book_time(t, device)
        return tt

    # -- hot-buffer accounting --------------------------------------------
    def record_hits(self, hits: float, misses: float) -> None:
        """Record HiSparse hot-tier outcomes (measured or analytic)."""
        self.stats.buffer_hits += hits
        self.stats.buffer_misses += misses

    def record_prefetch(self, inserted: float, useful: float, *,
                        key: Optional[Hashable] = None) -> None:
        """Record prefetch outcomes (measured in-graph by the HiSparse
        ``pf_*`` counters, or analytic in the simulator).  ``key``
        additionally attributes the outcome to one request (engine slot
        or request id) — the per-request precision the arbiter's
        precision-weighted grants consume."""
        self.stats.prefetched_entries += inserted
        self.stats.prefetch_useful += useful
        if key is not None:
            pf = self.stats.request_pf.setdefault(key, [0.0, 0.0])
            pf[0] += inserted
            pf[1] += useful

    def record_prefetch_bytes(self, n_bytes: float) -> None:
        """Attribute already-issued fabric bytes to prefetch traffic.

        The simulator's analytic speculation issues its demand through
        ``add_step_demand(..., qos=QOS_SPECULATIVE)`` (bytes + timing are
        booked there); this records the prefetch-bytes attribution the
        precision metrics read.  The engine path gets the same
        attribution inside :meth:`prefetch_fetch`."""
        self.stats.prefetch_bytes += n_bytes

    def record_spec_yield(self, seconds: float) -> None:
        """Book speculative seconds dropped by the QoS yield rule.

        The engine's :class:`OverlapQueue` drains book this through
        :meth:`drain_overlap`; the simulator's analytic drain computes
        the yielded share itself and records it here."""
        self.stats.spec_yielded_s += seconds

    def record_write_bytes(self, n_bytes: float) -> None:
        """Book pool-write bytes whose TIMING the caller models itself
        (the simulator's trunk-serialized prefill writes and chunked
        prefill tails charge seconds via :meth:`charge_seconds` after
        computing the drain analytically).  The engine's timed path is
        :meth:`write_back`, which books bytes AND time."""
        self.stats.bytes_written += n_bytes

    def record_copy_bytes(self, n_bytes: float) -> None:
        """Book a replica copy: ``n_bytes`` read from the owning device
        and written to the target (hot-prefix replication, PR 6).  The
        caller charges the transfer seconds on both links."""
        self.stats.bytes_fetched += n_bytes
        self.stats.bytes_written += n_bytes

    # -- per-step demand (simulator) ---------------------------------------
    def add_step_demand(self, device: int, n_bytes: float,
                        qos: int = QOS_DEMAND) -> None:
        """Accumulate one request's step byte demand on every segment of
        its device's path (plus the per-device view)."""
        device = self._resolve_device(device)
        self._dev_step[device] += n_bytes
        seg = (self._seg_step_spec if qos == QOS_SPECULATIVE
               else self._seg_step_dem)
        for sid in self.topology.route(device):
            seg[sid] += n_bytes

    def drain_step(self) -> List[float]:
        """Fold the current step's demand into the stats and return the
        per-SEGMENT byte backlog (demand + speculative; the speculative
        split is left in ``step_spec_bytes`` for QoS-aware timing)."""
        total = [d + s for d, s in zip(self._seg_step_dem,
                                       self._seg_step_spec)]
        self.step_spec_bytes = list(self._seg_step_spec)
        for d, n in enumerate(self._dev_step):
            self.stats.device_demand_bytes[d] += n
        self.stats.bytes_fetched += sum(self._dev_step)
        for sid, n in enumerate(total):
            self.stats.segment_demand_bytes[sid] += n
        if total:
            self.stats.critical_demand_bytes += max(total)
        self._seg_step_dem = [0.0] * self.n_segments
        self._seg_step_spec = [0.0] * self.n_segments
        self._dev_step = [0.0] * self.n_devices
        return total

    def charge_seconds(self, seconds: float) -> None:
        self.stats.fabric_time_s += seconds

    def charge_segment_seconds(self, seg_seconds: List[float],
                               spec_seconds: Optional[List[float]] = None
                               ) -> None:
        """Simulator twin of the per-segment issued booking: fold one
        step's analytic per-segment drain times (and optionally the
        speculative share) into the cumulative per-segment stats."""
        for sid, t in enumerate(seg_seconds):
            self.stats.segment_issued_s[sid] += t
        if spec_seconds is not None:
            for sid, t in enumerate(spec_seconds):
                self.stats.segment_prefetch_s[sid] += t
        if seg_seconds:
            self.stats.critical_issued_s += max(seg_seconds)
