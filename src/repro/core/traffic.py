"""Shared fabric-traffic accounting substrate.

One stats schema — :class:`TrafficStats` — for every serving layer:

  - ``SACSystem`` (core/sac.py) charges real engine fetches/writes here;
  - ``Engine`` (serving/engine.py) exposes the same object as
    ``EngineStats.traffic`` (buffer hits/misses are *measured* from the
    in-graph HiSparse buffer, core/hisparse.py);
  - ``simulate()`` (serving/simulator.py) accumulates its analytic
    per-device step demand through the same accountant.

The point of sharing the schema is paper §5.5: SAC's wins hinge on
*miss-only* fabric traffic, so the engine's measured hits/misses and the
simulator's analytic hit model must be comparable numbers — the parity
test (tests/test_engine_buffer.py) grounds one against the other.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.transfer import FABRICS, FabricModel


@dataclasses.dataclass
class TrafficStats:
    """Cumulative fabric-traffic counters (one schema for all layers)."""

    n_devices: int = 1
    bytes_fetched: float = 0.0       # entries/pages pulled over the fabric
    bytes_written: float = 0.0       # prefill / decode write-back traffic
    buffer_hits: float = 0.0         # HiSparse hot-tier hits (no fabric)
    buffer_misses: float = 0.0       # hot-tier misses (crossed the fabric)
    fabric_time_s: float = 0.0       # seconds charged to the fabric
    device_demand_bytes: List[float] = dataclasses.field(
        default_factory=list)       # cumulative fetch demand per device

    def __post_init__(self):
        if not self.device_demand_bytes:
            self.device_demand_bytes = [0.0] * self.n_devices

    @property
    def hit_rate(self) -> float:
        tot = self.buffer_hits + self.buffer_misses
        return self.buffer_hits / tot if tot else 0.0

    @property
    def total_bytes(self) -> float:
        return self.bytes_fetched + self.bytes_written


class FabricAccountant:
    """Charges fabric operations against a :class:`FabricModel` and keeps
    one :class:`TrafficStats` for every consumer.

    Two usage styles:

      - **timed ops** (real engine): ``sparse_fetch`` / ``bulk_fetch`` /
        ``write_back`` return seconds from the calibrated fabric model and
        accumulate bytes + time;
      - **per-step demand** (simulator): ``add_step_demand`` accumulates a
        decode step's per-device byte demand; ``drain_step`` returns it
        (the slowest device is the step's fetch critical path) and folds
        it into the cumulative stats; ``charge_seconds`` books the time
        the caller computed from that demand.
    """

    def __init__(self, fabric: Optional[FabricModel] = None, *,
                 backend: Optional[str] = None, n_devices: int = 1):
        if fabric is None and backend is not None:
            fabric = FABRICS[backend]
        self.fabric = fabric
        self.stats = TrafficStats(n_devices=n_devices)
        self._step_demand = [0.0] * n_devices

    @property
    def n_devices(self) -> int:
        return self.stats.n_devices

    # -- timed ops (engine / SACSystem) ------------------------------------
    def sparse_fetch(self, n_entries: int, entry_bytes: int, *,
                     device: int = 0, contention: float = 1.0) -> float:
        """Fine-grained fetch of ``n_entries`` discrete entries."""
        if n_entries <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        t = self.fabric.sparse_fetch_time(n_entries, entry_bytes,
                                          contention=contention)
        n_bytes = n_entries * entry_bytes
        self.stats.bytes_fetched += n_bytes
        self.stats.device_demand_bytes[device % self.n_devices] += n_bytes
        self.stats.fabric_time_s += t
        return t

    def bulk_fetch(self, n_bytes: float, *, device: int = 0,
                   contention: float = 1.0) -> float:
        """Streaming fetch of a contiguous region (full-prefetch path)."""
        if n_bytes <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        t = self.fabric.bulk_transfer_time(n_bytes, contention=contention)
        self.stats.bytes_fetched += n_bytes
        self.stats.device_demand_bytes[device % self.n_devices] += n_bytes
        self.stats.fabric_time_s += t
        return t

    def write_back(self, n_bytes: float, *, contention: float = 1.0
                   ) -> float:
        """Pool write (prefill bulk write / decode write-back)."""
        if n_bytes <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        t = self.fabric.bulk_transfer_time(n_bytes, contention=contention)
        self.stats.bytes_written += n_bytes
        self.stats.fabric_time_s += t
        return t

    # -- hot-buffer accounting --------------------------------------------
    def record_hits(self, hits: float, misses: float) -> None:
        """Record HiSparse hot-tier outcomes (measured or analytic)."""
        self.stats.buffer_hits += hits
        self.stats.buffer_misses += misses

    # -- per-step demand (simulator) ---------------------------------------
    def add_step_demand(self, device: int, n_bytes: float) -> None:
        self._step_demand[device % self.n_devices] += n_bytes

    def drain_step(self) -> List[float]:
        """Fold the current step's demand into the stats and return it."""
        demand = self._step_demand
        for d, n in enumerate(demand):
            self.stats.device_demand_bytes[d] += n
        self.stats.bytes_fetched += sum(demand)
        self._step_demand = [0.0] * self.n_devices
        return demand

    def charge_seconds(self, seconds: float) -> None:
        self.stats.fabric_time_s += seconds
