"""Shared fabric-traffic accounting substrate.

One stats schema — :class:`TrafficStats` — for every serving layer:

  - ``SACSystem`` (core/sac.py) charges real engine fetches/writes here;
  - ``Engine`` (serving/engine.py) exposes the same object as
    ``EngineStats.traffic`` (buffer hits/misses are *measured* from the
    in-graph HiSparse buffer, core/hisparse.py);
  - ``simulate()`` (serving/simulator.py) accumulates its analytic
    per-device step demand through the same accountant.

The point of sharing the schema is paper §5.5: SAC's wins hinge on
*miss-only* fabric traffic, so the engine's measured hits/misses and the
simulator's analytic hit model must be comparable numbers — the parity
test (tests/test_engine_buffer.py) grounds one against the other.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional

from repro.core.transfer import FABRICS, FabricModel, PipelineModel


@dataclasses.dataclass
class TrafficStats:
    """Cumulative fabric-traffic counters (one schema for all layers).

    ``fabric_time_s`` is the *issued* total: every second the fabric
    links were busy.  ``exposed_fabric_s`` is the part that was NOT
    hidden behind compute by the fetch pipeline and therefore landed on
    the step critical path (serving/prefetch.py; without an overlap model
    the two are equal).  Invariant: ``issued >= exposed >= 0``.
    """

    n_devices: int = 1
    bytes_fetched: float = 0.0       # entries/pages pulled over the fabric
    bytes_written: float = 0.0       # prefill / decode write-back traffic
    entries_fetched: float = 0.0     # discrete entries pulled over the fabric
    buffer_hits: float = 0.0         # HiSparse hot-tier hits (no fabric)
    buffer_misses: float = 0.0       # hot-tier misses (crossed the fabric)
    fabric_time_s: float = 0.0       # seconds issued on the fabric
    exposed_fabric_s: float = 0.0    # issued seconds not hidden by compute
    prefetched_entries: float = 0.0  # speculative/warm-up entries inserted
    prefetch_useful: float = 0.0     # prefetched entries later demand-hit
    prefetch_bytes: float = 0.0      # fabric bytes spent on prefetch
    critical_demand_bytes: float = 0.0   # sum over steps of the MAX per-
                                    # device demand bytes — the step fetch
                                    # critical path.  Unlike end-to-end
                                    # exposed seconds this is independent
                                    # of the hide-window volume (how many
                                    # steps the run took), so it is the
                                    # fair link-hotspot envelope metric
                                    # (benchmarks/locality_gate.py)
    critical_issued_s: float = 0.0  # engine twin: sum over steps of the
                                    # max per-device ISSUED seconds (the
                                    # overlap queues' critical link)
    device_demand_bytes: List[float] = dataclasses.field(
        default_factory=list)       # cumulative fetch demand per device
    device_issued_s: List[float] = dataclasses.field(
        default_factory=list)       # cumulative issued seconds per device
    device_prefetch_s: List[float] = dataclasses.field(
        default_factory=list)       # issued seconds spent on prefetch, per
                                    # device (subset of device_issued_s) —
                                    # the arbiter's per-link pressure split
    device_anomalies: int = 0       # out-of-range device ids seen at the
                                    # accounting boundary (clamped once and
                                    # counted instead of silently aliased)
    request_pf: Dict[Hashable, List[float]] = dataclasses.field(
        default_factory=dict)       # per-request [inserted, useful]
                                    # prefetch attribution — the arbiter's
                                    # precision-weighting signal
    request_demand_s: Dict[Hashable, float] = dataclasses.field(
        default_factory=dict)       # per-request issued DEMAND seconds
                                    # (misses + writes, never prefetch) —
                                    # lets the pressure feed subtract a
                                    # finishing request's own share from
                                    # its link immediately instead of
                                    # waiting for the EMA to decay it

    def __post_init__(self):
        if not self.device_demand_bytes:
            self.device_demand_bytes = [0.0] * self.n_devices
        if not self.device_issued_s:
            self.device_issued_s = [0.0] * self.n_devices
        if not self.device_prefetch_s:
            self.device_prefetch_s = [0.0] * self.n_devices

    def device_demand_s(self) -> List[float]:
        """Per-device issued seconds attributable to *demand* traffic
        (total issued minus the speculative share) — the link-pressure
        signal the budget arbiter (serving/arbiter.py) reads."""
        return [t - p for t, p in zip(self.device_issued_s,
                                      self.device_prefetch_s)]

    @property
    def hit_rate(self) -> float:
        tot = self.buffer_hits + self.buffer_misses
        return self.buffer_hits / tot if tot else 0.0

    @property
    def total_bytes(self) -> float:
        return self.bytes_fetched + self.bytes_written

    @property
    def issued_fabric_s(self) -> float:
        return self.fabric_time_s

    @property
    def prefetch_wasted(self) -> float:
        """Prefetched entries never demand-hit (evicted unused, or still
        resident unused).  ``prefetched == useful + wasted`` always."""
        return self.prefetched_entries - self.prefetch_useful

    @property
    def prefetch_precision(self) -> float:
        return (self.prefetch_useful / self.prefetched_entries
                if self.prefetched_entries else 0.0)

    def request_precision(self, key: Hashable, prior: float = 1.0,
                          pseudo: float = 8.0) -> float:
        """One request's measured prefetch precision (useful / inserted),
        Laplace-smoothed toward an optimistic ``prior`` with ``pseudo``
        virtual entries.  The smoothing matters: a fresh request's first
        inserts have not had a chance to be demand-hit yet, and a raw
        0/N estimate would starve it before the signal exists (a
        feedback loop — starved requests never accumulate the inserts
        that would redeem them).  Heavily-wasteful speculators still
        converge to ~0."""
        ins, use = self.request_pf.get(key, (0.0, 0.0))
        return (use + pseudo * prior) / (ins + pseudo)

    def drop_request(self, key: Hashable) -> None:
        """Forget a finished request's prefetch and demand attribution
        (the key — an engine slot or a request id — is about to be
        reused)."""
        self.request_pf.pop(key, None)
        self.request_demand_s.pop(key, None)


class OverlapQueue:
    """Per-device double-buffered fetch queues (issued vs exposed split).

    Fetch seconds are *issued* per device as the step discovers its
    misses (and prefetch candidates); at step end ``drain`` hides as much
    as the :class:`~repro.core.transfer.PipelineModel` window allows and
    returns the step's *exposed* stall — the slowest device's unhidden
    tail, since the step cannot advance past its critical-path link.
    """

    def __init__(self, n_devices: int, pipeline: PipelineModel):
        self.pipeline = pipeline
        self._pending = [0.0] * max(n_devices, 1)

    def issue(self, device: int, seconds: float) -> None:
        if not 0 <= device < len(self._pending):
            # an aliased id would charge the WRONG link's hide window;
            # callers (FabricAccountant) validate at the accounting
            # boundary, so reaching here is a programming error
            raise IndexError(
                f"device {device} out of range [0, {len(self._pending)})")
        if seconds > 0:
            self._pending[device] += seconds

    @property
    def pending_s(self) -> float:
        return sum(self._pending)

    @property
    def peak_pending_s(self) -> float:
        """This step's critical-path link: the max per-device queue."""
        return max(self._pending, default=0.0)

    def drain(self, compute_s: float) -> float:
        """End-of-step: return exposed seconds, clear the queues."""
        exposed = max((self.pipeline.exposed_time(p, compute_s)
                       for p in self._pending), default=0.0)
        self._pending = [0.0] * len(self._pending)
        return exposed


class FabricAccountant:
    """Charges fabric operations against a :class:`FabricModel` and keeps
    one :class:`TrafficStats` for every consumer.

    Two usage styles:

      - **timed ops** (real engine): ``sparse_fetch`` / ``bulk_fetch`` /
        ``write_back`` return seconds from the calibrated fabric model and
        accumulate bytes + time;
      - **per-step demand** (simulator): ``add_step_demand`` accumulates a
        decode step's per-device byte demand; ``drain_step`` returns it
        (the slowest device is the step's fetch critical path) and folds
        it into the cumulative stats; ``charge_seconds`` books the time
        the caller computed from that demand.

    Overlap: without ``enable_overlap``, every charged second is also
    exposed (``charge_exposed`` is called by the timed ops).  With an
    :class:`OverlapQueue` enabled, timed ops *issue* into the per-device
    queues instead and the caller drains once per step with its compute
    window (``drain_overlap``) — only the unhidden tail lands in
    ``exposed_fabric_s``.
    """

    def __init__(self, fabric: Optional[FabricModel] = None, *,
                 backend: Optional[str] = None, n_devices: int = 1):
        if fabric is None and backend is not None:
            fabric = FABRICS[backend]
        self.fabric = fabric
        self.stats = TrafficStats(n_devices=n_devices)
        self._step_demand = [0.0] * n_devices
        self.overlap: Optional[OverlapQueue] = None

    # -- overlap (fetch pipeline) ------------------------------------------
    def enable_overlap(self, pipeline: PipelineModel) -> OverlapQueue:
        self.overlap = OverlapQueue(self.n_devices, pipeline)
        return self.overlap

    def charge_exposed(self, seconds: float) -> None:
        self.stats.exposed_fabric_s += max(seconds, 0.0)

    def drain_overlap(self, compute_s: float) -> float:
        """Drain the per-device queues against this step's compute window
        and book the exposed tail.  No-op (0.0) when overlap is off —
        timed ops then charge exposed at issue time."""
        if self.overlap is None:
            return 0.0
        self.stats.critical_issued_s += self.overlap.peak_pending_s
        exposed = self.overlap.drain(compute_s)
        self.charge_exposed(exposed)
        return exposed

    def _book_time(self, seconds: float, device: int) -> None:
        """Issued seconds: queue behind compute if overlap is on, else
        expose immediately (the serial seed semantics)."""
        if self.overlap is not None:
            self.overlap.issue(device, seconds)
        else:
            self.charge_exposed(seconds)

    @property
    def n_devices(self) -> int:
        return self.stats.n_devices

    def _resolve_device(self, device: int) -> int:
        """Validate a device id at the accounting boundary.

        A silently aliased id (the pre-PR 4 ``dev % n`` convention) would
        charge the WRONG link's budget and feed the arbiter/placer a
        corrupted pressure signal.  Out-of-range ids are clamped ONCE
        here — every downstream counter then indexes directly — and the
        anomaly is counted in ``TrafficStats.device_anomalies`` so tests
        and dashboards can see it happened.
        """
        if 0 <= device < self.n_devices:
            return device
        self.stats.device_anomalies += 1
        return min(max(device, 0), self.n_devices - 1)

    def _attribute_demand(self, key: Optional[Hashable], t: float) -> None:
        """Book issued DEMAND seconds against one request (never called
        on the prefetch path — speculation is not the request's demand
        share and must not be subtracted from its link at departure)."""
        if key is not None and t > 0:
            self.stats.request_demand_s[key] = \
                self.stats.request_demand_s.get(key, 0.0) + t

    # -- timed ops (engine / SACSystem) ------------------------------------
    def sparse_fetch(self, n_entries: int, entry_bytes: int, *,
                     device: int = 0, contention: float = 1.0,
                     key: Optional[Hashable] = None) -> float:
        """Fine-grained fetch of ``n_entries`` discrete entries.

        ``key`` attributes the issued seconds to one request
        (``TrafficStats.request_demand_s``) — the per-request demand
        share the pressure feed subtracts when that request departs.
        """
        if n_entries <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        device = self._resolve_device(device)
        t = self.fabric.sparse_fetch_time(n_entries, entry_bytes,
                                          contention=contention)
        n_bytes = n_entries * entry_bytes
        self.stats.bytes_fetched += n_bytes
        self.stats.entries_fetched += n_entries
        self.stats.device_demand_bytes[device] += n_bytes
        self.stats.fabric_time_s += t
        self.stats.device_issued_s[device] += t
        self._attribute_demand(key, t)
        self._book_time(t, device)
        return t

    def prefetch_fetch(self, n_entries: int, entry_bytes: int, *,
                       device: int = 0, contention: float = 1.0) -> float:
        """Speculative/warm-up fetch of ``n_entries`` entries: same fabric
        cost and accounting as a demand fetch, additionally attributed to
        prefetch traffic so the wasted share is measurable."""
        device = self._resolve_device(device)
        t = self.sparse_fetch(n_entries, entry_bytes, device=device,
                              contention=contention)
        if n_entries > 0:
            self.stats.prefetch_bytes += n_entries * entry_bytes
            self.stats.device_prefetch_s[device] += t
        return t

    def bulk_fetch(self, n_bytes: float, *, device: int = 0,
                   contention: float = 1.0,
                   key: Optional[Hashable] = None) -> float:
        """Streaming fetch of a contiguous region (full-prefetch path)."""
        if n_bytes <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        device = self._resolve_device(device)
        t = self.fabric.bulk_transfer_time(n_bytes, contention=contention)
        self.stats.bytes_fetched += n_bytes
        self.stats.device_demand_bytes[device] += n_bytes
        self.stats.fabric_time_s += t
        self.stats.device_issued_s[device] += t
        self._attribute_demand(key, t)
        self._book_time(t, device)
        return t

    def write_back(self, n_bytes: float, *, device: int = 0,
                   contention: float = 1.0,
                   key: Optional[Hashable] = None) -> float:
        """Pool write (prefill bulk write / decode write-back).

        ``device`` matters for the arbiter's per-link demand signal: a
        prefill write lands on the request's pool device, not device 0.
        """
        if n_bytes <= 0:
            return 0.0
        assert self.fabric is not None, "timed ops need a fabric model"
        device = self._resolve_device(device)
        t = self.fabric.bulk_transfer_time(n_bytes, contention=contention)
        self.stats.bytes_written += n_bytes
        self.stats.fabric_time_s += t
        self.stats.device_issued_s[device] += t
        self._attribute_demand(key, t)
        self._book_time(t, device)
        return t

    # -- hot-buffer accounting --------------------------------------------
    def record_hits(self, hits: float, misses: float) -> None:
        """Record HiSparse hot-tier outcomes (measured or analytic)."""
        self.stats.buffer_hits += hits
        self.stats.buffer_misses += misses

    def record_prefetch(self, inserted: float, useful: float, *,
                        key: Optional[Hashable] = None) -> None:
        """Record prefetch outcomes (measured in-graph by the HiSparse
        ``pf_*`` counters, or analytic in the simulator).  ``key``
        additionally attributes the outcome to one request (engine slot
        or request id) — the per-request precision the arbiter's
        precision-weighted grants consume."""
        self.stats.prefetched_entries += inserted
        self.stats.prefetch_useful += useful
        if key is not None:
            pf = self.stats.request_pf.setdefault(key, [0.0, 0.0])
            pf[0] += inserted
            pf[1] += useful

    # -- per-step demand (simulator) ---------------------------------------
    def add_step_demand(self, device: int, n_bytes: float) -> None:
        self._step_demand[self._resolve_device(device)] += n_bytes

    def drain_step(self) -> List[float]:
        """Fold the current step's demand into the stats and return it."""
        demand = self._step_demand
        for d, n in enumerate(demand):
            self.stats.device_demand_bytes[d] += n
        self.stats.bytes_fetched += sum(demand)
        if demand:
            self.stats.critical_demand_bytes += max(demand)
        self._step_demand = [0.0] * self.n_devices
        return demand

    def charge_seconds(self, seconds: float) -> None:
        self.stats.fabric_time_s += seconds
