"""CXL switch-fabric topology: routing, per-segment capacity, QoS.

The paper's pool is not a bundle of independent point-to-point links —
it is a *switch fabric* (§A.2: CXL Type-3 devices behind an XConn
switch) where congestion lives at shared switch ports.  Two devices
behind one saturated upstream port are not independent, and placement /
arbitration decisions made on per-endpoint numbers are blind to that.

:class:`FabricTopology` models the fabric as a graph of directed **link
segments** between the host, switches, and memory devices:

  - ``route(device)`` returns the deterministic host->device path as a
    tuple of segment ids (the LAST segment is always the device's leaf
    link, so per-device stats project out of per-segment stats);
  - every :class:`Segment` carries a ``bandwidth_scale`` (capacity as a
    multiple of one device link) and an additive ``latency_s``;
  - a transfer that takes ``t`` seconds at the device-link rate occupies
    each segment on its path for ``t / bandwidth_scale + latency_s``
    seconds (``segment_charge``) — the *link-segment seconds* the shared
    accountant (core/traffic.py) books, and the unit every control loop
    (arbiter grants, pressure-aware placement) reasons in.

Two QoS classes split that traffic (``QOS_DEMAND`` / ``QOS_SPECULATIVE``,
core/transfer.py): demand fetches (top-k misses, prefill writes) own the
segment; speculative prefetch *yields* at congested segments — on a
topology built with ``qos_spec_yield=True``, a segment's speculative
backlog is only serviced from the hide window left over after its demand
backlog, and the remainder is dropped from the step's exposure and
counted in ``TrafficStats.spec_yielded_s`` (the speculated entries go
stale by the next step, so deferring them has no value).  Demand is
never delayed by speculation at a shared port.

Presets (all deterministic, no external graph library):

  - ``flat_star(n)``      — one dedicated host port per device; paths are
    single leaf segments with ``sid == device``, so every per-segment
    number degenerates EXACTLY to the flat per-device accounting the
    repo used before PR 7.  This is the default everywhere
    (``SACConfig.topology is None``) and is bit-identical by
    construction (tests/test_fabric.py).
  - ``tree(n, s)``        — ``s`` switches, devices grouped contiguously;
    each path crosses a shared host->switch trunk then the leaf.
  - ``multi_switch(n, s)``— cascaded: one shared host uplink feeding
    ``s`` switch trunks (two shared levels).
  - ``mesh(n, p)``        — ``p`` host ports with devices striped across
    them (``device % p``) — the interleaved dual-homing layout.

``from_spec`` parses the string forms used by configs and the CLI:
``"flat:4"``, ``"tree:4x2"``, ``"multi_switch:8x2"``, ``"mesh:4x2"``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

from repro.core.transfer import QOS_DEMAND, QOS_SPECULATIVE  # noqa: F401
                                    # (re-exported: fabric is the natural
                                    # import site for QoS-aware consumers)


@dataclasses.dataclass(frozen=True)
class Segment:
    """One directed link segment of the fabric graph."""

    sid: int
    name: str
    bandwidth_scale: float = 1.0   # capacity as a multiple of one device
                                   # link (trunks shared by many leaves
                                   # with scale 1.0 are the congestion)
    latency_s: float = 0.0         # additive per-transfer propagation

    def charge(self, seconds: float) -> float:
        """Segment occupancy for a transfer of ``seconds`` at the
        device-link rate."""
        if seconds <= 0.0:
            return 0.0
        return seconds / max(self.bandwidth_scale, 1e-12) + self.latency_s


class FabricTopology:
    """Deterministic switch-fabric graph with host->device routing.

    ``device_paths[d]`` is the host->device segment-id path; its last
    element is the device's *leaf* segment.  By convention every preset
    numbers leaves first (``sid == device id``) so the leaf projection of
    per-segment arrays lines up index-for-index with the historical
    per-device arrays.
    """

    def __init__(self, n_devices: int, segments: Sequence[Segment],
                 device_paths: Sequence[Sequence[int]], *,
                 name: str = "custom", qos_spec_yield: bool = False):
        assert n_devices >= 1 and len(device_paths) == n_devices
        self.name = name
        self.n_devices = int(n_devices)
        self.segments: Tuple[Segment, ...] = tuple(segments)
        assert all(s.sid == i for i, s in enumerate(self.segments)), \
            "segment ids must be dense and ordered"
        self.qos_spec_yield = bool(qos_spec_yield)
        paths = []
        for d, p in enumerate(device_paths):
            p = tuple(int(s) for s in p)
            assert p, f"device {d} has an empty path"
            assert all(0 <= s < len(self.segments) for s in p), (d, p)
            paths.append(p)
        self._paths: Tuple[Tuple[int, ...], ...] = tuple(paths)
        counts: dict = {}
        for p in self._paths:
            for s in p:
                counts[s] = counts.get(s, 0) + 1
        # trunks: segments on >= 2 device paths — where concurrent
        # transfers to DIFFERENT devices contend.  Flat star: empty by
        # construction, so trunk-only serialization degenerates to the
        # independent-lane model the repo used before PR 7.
        self.shared_segments: frozenset = frozenset(
            s for s, c in counts.items() if c >= 2)

    # -- structure ---------------------------------------------------------
    @property
    def n_segments(self) -> int:
        return len(self.segments)

    def route(self, device: int) -> Tuple[int, ...]:
        """Deterministic host->device path (segment ids; last = leaf)."""
        if not 0 <= device < self.n_devices:
            raise IndexError(
                f"device {device} out of range [0, {self.n_devices})")
        return self._paths[device]

    def route_between(self, src: int, dst: int) -> Tuple[int, ...]:
        """Device->device path (replica copies): up from ``src`` to the
        lowest common ancestor, down to ``dst`` — the shared upper
        segments (common path prefix) are never crossed."""
        a, b = self.route(src), self.route(dst)
        common = 0
        for x, y in zip(a, b):
            if x != y:
                break
            common += 1
        return tuple(reversed(a[common:])) + b[common:]

    def leaf(self, device: int) -> int:
        """The device's last-hop segment id (== ``device`` in presets)."""
        return self.route(device)[-1]

    # -- per-segment <-> per-device views ----------------------------------
    def device_view(self, seg_values: Sequence[float]) -> List[float]:
        """Project per-segment values to per-device BOTTLENECK values:
        ``out[d] = max over segments on route(d)``.  This is the pressure
        the placement policies and replica selection consume — a device
        behind a saturated trunk reads the trunk's pressure, not its idle
        leaf's."""
        vals = list(seg_values) + [0.0] * self.n_segments
        return [max(vals[s] for s in self._paths[d])
                for d in range(self.n_devices)]

    def leaf_view(self, seg_values: Sequence[float]) -> List[float]:
        """Project per-segment values to per-device LEAF values — the
        endpoint-only view (exactly the pre-fabric flat accounting; the
        segment-blind baseline of benchmarks/fabric_sweep.py)."""
        vals = list(seg_values) + [0.0] * self.n_segments
        return [vals[p[-1]] for p in self._paths]

    def segment_charge(self, device: int, seconds: float
                       ) -> List[Tuple[int, float]]:
        """Per-segment occupancy of a host<->device transfer that takes
        ``seconds`` at the device-link rate: ``(sid, charge)`` per
        segment on the path."""
        return [(s, self.segments[s].charge(seconds))
                for s in self.route(device)]

    def transfer_seconds(self, device: int, seconds: float) -> float:
        """End-to-end transfer time along the path: the bottleneck
        segment's occupancy (cut-through switching — the transfer moves
        at the slowest segment's rate, latencies additive through
        ``Segment.charge``).  Flat star: exactly ``seconds``."""
        if seconds <= 0.0:
            return 0.0
        return max(c for _, c in self.segment_charge(device, seconds))

    def segment_seconds(self, seg_bytes: Sequence[float], bw_Bps: float
                        ) -> List[float]:
        """Per-segment drain time of a step's byte backlog at a base
        device-link bandwidth (the simulator's analytic fetch model)."""
        return [b / (max(bw_Bps, 1e-9) * max(s.bandwidth_scale, 1e-12))
                for b, s in zip(seg_bytes, self.segments)]

    def describe(self) -> str:
        lanes = ", ".join(
            f"dev{d}<-[{':'.join(str(s) for s in p)}]"
            for d, p in enumerate(self._paths))
        return (f"{self.name}(n={self.n_devices}, "
                f"segments={self.n_segments}, qos_yield="
                f"{self.qos_spec_yield}) {lanes}")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FabricTopology<{self.describe()}>"

    # -- presets -----------------------------------------------------------
    @classmethod
    def flat_star(cls, n_devices: int, *,
                  qos_spec_yield: bool = False) -> "FabricTopology":
        """The degenerate topology: every device on its own host port.
        One leaf segment per device, ``sid == device`` — per-segment
        accounting IS the historical per-device accounting."""
        segs = [Segment(d, f"host->dev{d}") for d in range(n_devices)]
        return cls(n_devices, segs, [(d,) for d in range(n_devices)],
                   name="flat", qos_spec_yield=qos_spec_yield)

    @classmethod
    def tree(cls, n_devices: int, n_switches: int = 2, *,
             trunk_scale: float = 1.0,
             qos_spec_yield: bool = True) -> "FabricTopology":
        """``n_switches`` switches on dedicated host ports; devices
        grouped contiguously (device d behind switch d // ceil(n/s)).
        Each trunk has ``trunk_scale`` device-links of capacity — at the
        default 1.0 a switch's devices genuinely share one link's worth
        of upstream bandwidth (PCIe x8 uplink, paper §A.2)."""
        s = max(min(int(n_switches), n_devices), 1)
        per = -(-n_devices // s)                       # ceil division
        segs = [Segment(d, f"sw{d // per}->dev{d}")
                for d in range(n_devices)]
        segs += [Segment(n_devices + i, f"host->sw{i}",
                         bandwidth_scale=trunk_scale) for i in range(s)]
        paths = [(n_devices + d // per, d) for d in range(n_devices)]
        return cls(n_devices, segs, paths, name="tree",
                   qos_spec_yield=qos_spec_yield)

    @classmethod
    def multi_switch(cls, n_devices: int, n_switches: int = 2, *,
                     trunk_scale: float = 1.0, uplink_scale: float = 2.0,
                     qos_spec_yield: bool = True) -> "FabricTopology":
        """Cascaded fabric: one shared host uplink feeds ``n_switches``
        switch trunks which feed contiguous device groups.  The uplink
        (default 2x one device link) is the pod-level shared port every
        transfer crosses."""
        s = max(min(int(n_switches), n_devices), 1)
        per = -(-n_devices // s)
        segs = [Segment(d, f"sw{d // per}->dev{d}")
                for d in range(n_devices)]
        segs += [Segment(n_devices + i, f"up->sw{i}",
                         bandwidth_scale=trunk_scale) for i in range(s)]
        root = n_devices + s
        segs.append(Segment(root, "host->up", bandwidth_scale=uplink_scale))
        paths = [(root, n_devices + d // per, d) for d in range(n_devices)]
        return cls(n_devices, segs, paths, name="multi_switch",
                   qos_spec_yield=qos_spec_yield)

    @classmethod
    def mesh(cls, n_devices: int, n_ports: int = 2, *,
             port_scale: float = 1.0,
             qos_spec_yield: bool = True) -> "FabricTopology":
        """Striped dual-homing: ``n_ports`` host ports with device d
        hanging off port ``d % n_ports`` — the interleaved counterpart
        of ``tree``'s contiguous grouping (adjacent devices never share
        an upstream port)."""
        p = max(min(int(n_ports), n_devices), 1)
        segs = [Segment(d, f"port{d % p}->dev{d}")
                for d in range(n_devices)]
        segs += [Segment(n_devices + i, f"host->port{i}",
                         bandwidth_scale=port_scale) for i in range(p)]
        paths = [(n_devices + d % p, d) for d in range(n_devices)]
        return cls(n_devices, segs, paths, name="mesh",
                   qos_spec_yield=qos_spec_yield)

    # -- spec parsing ------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: Union[str, "FabricTopology", None],
                  n_devices: Optional[int] = None) -> "FabricTopology":
        """Resolve a topology spec:

          - ``None``            -> flat star over ``n_devices``;
          - a FabricTopology    -> passed through (``n_devices`` must
            agree when given);
          - ``"flat[:N]"``, ``"tree[:NxS]"``, ``"multi_switch[:NxS]"``,
            ``"mesh[:NxP]"`` -> the preset (``N`` defaults to
            ``n_devices``; ``S``/``P`` defaults to 2).
        """
        if isinstance(spec, FabricTopology):
            assert n_devices is None or spec.n_devices == n_devices, \
                (spec.n_devices, n_devices)
            return spec
        if spec is None:
            assert n_devices is not None, \
                "a None topology spec needs n_devices"
            return cls.flat_star(n_devices)
        parts = str(spec).strip().split(":")
        kind = parts[0]
        n, arg = n_devices, 2
        if len(parts) > 1 and parts[1]:
            dims = parts[1].lower().split("x")
            n = int(dims[0])
            if len(dims) > 1:
                arg = int(dims[1])
        if n is None:
            raise ValueError(
                f"topology spec {spec!r} names no device count and none "
                "was supplied")
        if n_devices is not None and n != n_devices:
            raise ValueError(
                f"topology spec {spec!r} names {n} devices but the "
                f"serving layer has {n_devices}")
        makers = {"flat": lambda: cls.flat_star(n),
                  "star": lambda: cls.flat_star(n),
                  "tree": lambda: cls.tree(n, arg),
                  "multi_switch": lambda: cls.multi_switch(n, arg),
                  "mesh": lambda: cls.mesh(n, arg)}
        if kind not in makers:
            raise ValueError(f"unknown topology kind {kind!r} "
                             f"(have {sorted(makers)})")
        return makers[kind]()
