"""SAC core: the paper's contribution as composable JAX modules.

- pool:      disaggregated KV pool (shard_map fetch collective, write-back)
- hisparse:  functional hierarchical device buffer (miss-id / LRU / PT)
- topk:      lightning-indexer top-k (plain + hierarchical distributed)
- sac:       per-layer decode assembly + host-level pool system
- metadata:  seqlock page directory + pool allocator
- transfer:  calibrated fabric cost models (CXL / RDMA / DRAM / ICI / HBM)
"""
