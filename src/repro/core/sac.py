"""SAC facade — the paper's contribution as a composable JAX module.

Two halves:

1. **In-graph** (`sparse_attend`, `dense_attend`): the per-layer decode
   attention assembly used inside compiled ``serve_step``s —
   indexer scoring → masked top-k → pool fetch (injected callback: local
   gather or the pooled-HBM shard_map collective) → sparse attention
   (absorbed-MLA or GQA).  This is the paper's Figure 6 decode path.

2. **Host-level** (`SACSystem`): pool bookkeeping for the serving engine
   and simulator — page allocation across pool devices via the shared
   placement substrate (core/placement.py, paper §4.3.3), metadata
   publishing (paper §4.3.1), and fabric-cost accounting via the shared
   traffic substrate (core/traffic.py, paper Fig 5 models).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hisparse
from repro.core.fabric import FabricTopology
from repro.core.metadata import PageDirectory, PoolAllocator
from repro.core.placement import (Placer, pages_for_tokens,
                                  policy_for_interleave)
from repro.core.pool import FetchFn, local_fetch
from repro.core.traffic import FabricAccountant
from repro.core.transfer import FABRICS, FabricModel
from repro.models import dsa


# ---------------------------------------------------------------------------
# in-graph decode attention (used by models/transformer.py)
# ---------------------------------------------------------------------------


def sparse_attend(p_attn: Dict, p_idx: Dict, x: jnp.ndarray, cfg: ModelConfig,
                  kv_pool_l: jnp.ndarray, idx_pool_l: jnp.ndarray,
                  cache_len: jnp.ndarray, positions: jnp.ndarray,
                  own_entry: jnp.ndarray,
                  fetch_fn: FetchFn = local_fetch,
                  topk_fn: Optional[Callable] = None,
                  window: int = 0,
                  buf_state: Optional[hisparse.BufferState] = None,
                  prefetch_width: int = 0,
                  prefetch_fn: Optional[Callable] = None,
                  score_margin: float = -1.0,
                  pf_budget: Optional[jnp.ndarray] = None):
    """One layer of SAC decode attention.  x: [B, D] -> [B, D].

    kv_pool_l: [B, S, d_entry] (this layer's pool slice, S possibly sharded
    over the pool axis); idx_pool_l: [B, S, d_idx]; own_entry: [B, d_entry]
    (the current token's KV entry, appended so the token attends to itself
    before the write-back lands).  ``window`` > 0 restricts the candidate
    set to the trailing window (SWA layers: top-k within the window).

    With ``buf_state`` (this layer's HiSparse hot tier, core/hisparse.py)
    the top-k read goes through ``hisparse.read_through`` — values are
    bit-identical, but residency is measured so the host can charge only
    *misses* to the fabric (paper §5.5).  Returns the plain output when
    ``buf_state`` is None, else ``(out, new_buf_state, hits, misses)``.

    ``prefetch_width`` > 0 (buffered path only) additionally warm-inserts
    the next step's speculated entrants — ``prefetch_fn(scores,
    cache_len) -> (idx [B, w], valid)``, default ranks [k, k+w) of this
    step's indexer scores (dsa.speculate_next_topk) — into the hot tier
    after the demand swap-in.  ``score_margin >= 0`` switches the default
    speculation from the rank window to score-threshold selection (tail
    entries within the margin of the k-th demand score, dsa._spec_tail);
    ``pf_budget`` ([B] int32, from the fabric budget arbiter,
    serving/arbiter.py) caps how many speculation lanes each request may
    actually issue this step.  Prefetch only ever touches the buffer (the
    pool stays authoritative), so decoded tokens are bit-identical with
    prefetch on or off and under any margin/budget; the ``pf_*`` counters
    inside the returned buffer state measure inserted/useful speculation
    for the host's wasted-traffic accounting (serving/prefetch.py).
    """
    scores = dsa.indexer_scores(p_idx, x, idx_pool_l, cfg)
    if window:
        # candidate set = (cache_len - window, cache_len]: size-`window`
        # trailing window including the (appended) current token.
        pos = jnp.arange(scores.shape[-1], dtype=jnp.int32)
        in_win = pos[None, :] > (cache_len[:, None] - window)
        scores = jnp.where(in_win, scores, dsa.NEG_INF)
    speculate = buf_state is not None and prefetch_width > 0
    p_idx_ = p_valid = None
    if topk_fn is not None:
        idx, valid = topk_fn(scores, cache_len)
    elif speculate and prefetch_fn is None:
        # fused selection: one top_k(k+w) yields the (bit-identical)
        # demand set AND the speculation tail
        idx, valid, p_idx_, p_valid = dsa.topk_select_with_tail(
            scores, cache_len, cfg.sac.topk, prefetch_width, score_margin)
    else:
        idx, valid = dsa.topk_select(scores, cache_len, cfg.sac.topk)
    fetched = fetch_fn(kv_pool_l, idx)
    if buf_state is not None:
        fetched, buf_state, hits, misses = hisparse.read_through(
            buf_state, idx, fetched, valid)
        if speculate:
            if p_idx_ is None:
                p_idx_, p_valid = (
                    prefetch_fn(scores, cache_len) if prefetch_fn is not None
                    else dsa.speculate_next_topk(scores, cache_len,
                                                 cfg.sac.topk,
                                                 prefetch_width,
                                                 score_margin))
            if pf_budget is not None:
                # arbiter-granted cap: only the first budget[b] lanes may
                # issue (lanes are best-first) — traffic shaping only
                p_valid = dsa.budget_mask(p_valid, pf_budget)
            p_vals = fetch_fn(kv_pool_l, jnp.clip(
                p_idx_, 0, kv_pool_l.shape[1] - 1))
            buf_state, _ = hisparse.warm_insert(buf_state, p_idx_, p_vals,
                                                p_valid)
    fetched = jnp.concatenate(
        [fetched, own_entry[:, None, :].astype(fetched.dtype)], axis=1)
    valid = jnp.concatenate(
        [valid, jnp.ones((valid.shape[0], 1), bool)], axis=1)
    if cfg.mla:
        out = dsa.mla_absorbed_decode(p_attn, x, cfg, fetched, valid,
                                      positions)
    else:
        out = dsa.gqa_sparse_decode(p_attn, x, cfg, fetched, valid,
                                    positions)
    if buf_state is not None:
        return out, buf_state, hits, misses
    return out


def window_attend(p_attn: Dict, x: jnp.ndarray, cfg: ModelConfig,
                  kv_pool_l: jnp.ndarray, cache_len: jnp.ndarray,
                  positions: jnp.ndarray, own_entry: jnp.ndarray,
                  window: int, fetch_fn: FetchFn = local_fetch) -> jnp.ndarray:
    """Sliding-window decode: fetch the trailing ``window-1`` entries
    (contiguous indices through the same fetch path) + the own entry."""
    B = x.shape[0]
    w = window - 1
    idx = cache_len[:, None] - w + jnp.arange(w, dtype=jnp.int32)[None, :]
    valid = idx >= 0
    idx = jnp.clip(idx, 0, kv_pool_l.shape[1] - 1)
    fetched = fetch_fn(kv_pool_l, idx)
    fetched = jnp.concatenate(
        [fetched, own_entry[:, None, :].astype(fetched.dtype)], axis=1)
    valid = jnp.concatenate([valid, jnp.ones((B, 1), bool)], axis=1)
    if cfg.mla:
        return dsa.mla_absorbed_decode(p_attn, x, cfg, fetched, valid,
                                       positions)
    return dsa.gqa_sparse_decode(p_attn, x, cfg, fetched, valid, positions)


def dense_attend(p_attn: Dict, x: jnp.ndarray, cfg: ModelConfig,
                 kv_pool_l: jnp.ndarray, cache_len: jnp.ndarray,
                 positions: jnp.ndarray, own_entry: jnp.ndarray
                 ) -> jnp.ndarray:
    """Dense decode over the full pool slice (full-prefetch baseline)."""
    B, S, _ = kv_pool_l.shape
    pool = jnp.concatenate(
        [kv_pool_l, own_entry[:, None, :].astype(kv_pool_l.dtype)], axis=1)
    valid = jnp.concatenate(
        [jnp.arange(S, dtype=jnp.int32)[None, :] < cache_len[:, None],
         jnp.ones((B, 1), bool)], axis=1)
    if cfg.mla:
        return dsa.mla_absorbed_decode(p_attn, x, cfg, pool, valid, positions)
    return dsa.gqa_sparse_decode(p_attn, x, cfg, pool, valid, positions)


# ---------------------------------------------------------------------------
# host-level pool system (serving engine / simulator substrate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestPages:
    request_id: int
    device: int
    pages: list
    n_tokens: int


class SACSystem:
    """Disaggregated KV-cache system state for one serving cluster.

    ``backend`` picks the fabric cost model: "cxl" (SAC), "rdma"
    (full-prefetch baseline), "dram"/"hbm" (non-disaggregated baselines).

    Placement goes through the shared :class:`~repro.core.placement.Placer`
    (one implementation for engine, scheduler, and simulator); traffic is
    charged to the shared :class:`~repro.core.traffic.FabricAccountant`
    whose ``TrafficStats`` the engine exposes directly.

    With a radix index attached (``attach_radix``, serving/radix.py) the
    system also owns the cached-prefix page lifecycle: ``release`` can
    retain a finished request's prefix pages under radix ownership
    (still booked against the device's byte/page budgets via
    ``Placer.adjust``), ``radix_evict`` returns evicted prefixes' pages
    to the allocator, ``place`` evicts LRU prefixes when the pool is
    exhausted, and every page ``release`` actually frees is purged from
    the index — the allocator and the index can never disagree about a
    page (the PR 5 stale-page property, tests/test_radix.py).
    """

    def __init__(self, cfg: ModelConfig, *, backend: str = "cxl",
                 n_pool_devices: int = 2, device_bytes: int = 256 << 30,
                 interleave: bool = True, placement: Optional[str] = None,
                 pressure_fn=None, seq_capacity: int = 1 << 17,
                 topology=None):
        self.cfg = cfg
        self.backend = backend
        self.fabric: FabricModel = FABRICS[backend]
        self.interleave = interleave
        # fabric switch topology (core/fabric.py): accepts None (flat
        # star — the exact pre-PR 7 per-device accounting), a spec
        # string ("tree:4x2", ...), or a FabricTopology.  One object is
        # shared by the accountant (per-segment charging), the placer
        # (bottleneck-pressure projection), and — via the engine — the
        # demand tracker and budget arbiter.
        self.topology = FabricTopology.from_spec(topology, n_pool_devices)
        self.n_devices = n_pool_devices
        self.entry_bytes = cfg.kv_bytes_per_token_layer + 2 * cfg.sac.d_idx
        self.page_tokens = cfg.sac.page_size
        self.page_bytes = (self.entry_bytes * self.page_tokens
                           * max(cfg.n_attn_layers, 1))
        pages_per_device = max(device_bytes // max(self.page_bytes, 1), 1)
        self.allocator = PoolAllocator(n_pool_devices, pages_per_device)
        self.placer = Placer(
            n_pool_devices,
            policy=placement or policy_for_interleave(interleave),
            capacity_bytes=float(device_bytes),
            capacity_pages=pages_per_device,
            pressure_fn=pressure_fn,
            topology=self.topology)
        self.traffic = FabricAccountant(self.fabric,
                                        n_devices=n_pool_devices,
                                        topology=self.topology)
        self.directory = PageDirectory()
        self.requests: Dict[int, RequestPages] = {}
        # radix prefix cache ownership: the index (attach_radix) plus the
        # per-device set of page ids the CACHE owns — retained at request
        # finish, returned to the allocator only when the index evicts or
        # invalidates them.  Pages backing LIVE requests never enter this
        # set (their booking still owns them).
        self.radix = None
        self._radix_pages = [set() for _ in range(n_pool_devices)]
        self.radix_evicted_pages = 0     # cumulative cache pages returned
                                         # to the allocator (place-time
                                         # pressure + headroom evictions)
        # PR 6 page dedup: requests whose leading pages are refcount-
        # shared with a cached prefix (request_id -> that shared page
        # list), per-page sharer refcounts, and the orphan set — shared
        # pages whose owning copy left (owner departed un-retained, or
        # the cache evicted under a sharer) stay allocated + booked
        # until the LAST sharer departs, then return to the pool here
        self._shared_pages: Dict[int, list] = {}
        self._shared_refs: Dict[Tuple[int, int], int] = {}
        self._orphaned = [set() for _ in range(n_pool_devices)]
        self.replicated_pages = 0        # cumulative replica pages copied
        self.dedup_shared_pages = 0      # cumulative pages refcount-shared
                                         # instead of privately held
        self.booked_pages_cum = 0        # cumulative request pages booked
                                         # net of dedup (the pool-bytes-
                                         # per-request numerator)

    # -- placement ---------------------------------------------------------
    def set_pressure_fn(self, fn) -> None:
        """Attach the live per-device link-pressure feed the
        ``pressure_aware`` placement policy reads (core/placement.py).
        Both serving layers wire the shared
        :class:`repro.serving.policy.PressureFeed` in here — tracker
        demand plus the warm-up seed while its window is open — so the
        engine's and the simulator's placers consume one feed class."""
        self.placer.set_pressure_fn(fn)

    def note_pressure_update(self) -> None:
        """Tell the placer the pressure feed was re-measured (once per
        engine step) so its in-flight correction resets."""
        self.placer.note_pressure_update()

    def attach_radix(self, radix) -> None:
        """Hand the system the radix prefix index whose page lifecycle it
        owns (duck-typed ``RadixIndex``; the engine builds one, the
        lifecycle tests drive the pair directly)."""
        self.radix = radix

    def place(self, request_id: int, n_tokens: int, *,
              affinity=None, affinity_s: float = 0.0
              ) -> Optional[RequestPages]:
        """Allocate pool pages for a request on one device (paper stores a
        request's KV within a single device; the shared placer interleaves
        requests across devices).

        ``affinity``/``affinity_s`` thread a radix-matched prefix's
        device — or, with replicas, every device holding a copy — and
        the seconds reuse there saves to the placement policy.  Under
        pool page pressure, unpinned LRU cached prefixes are evicted
        until the request fits or nothing is evictable.
        """
        n_pages = pages_for_tokens(n_tokens, self.page_tokens)
        n_bytes = n_pages * self.page_bytes
        while True:
            dev = self.placer.place(request_id, n_pages=n_pages,
                                    n_bytes=n_bytes, affinity=affinity,
                                    affinity_s=affinity_s)
            if dev is not None:
                break
            if self.radix is None or not self._evict_for_fit(
                    n_bytes, n_pages):
                return None      # genuinely full: nothing left to evict
        pages = self.allocator.alloc(dev, n_pages)
        assert pages is not None, \
            "placer and allocator page budgets diverged"
        rp = RequestPages(request_id, dev, pages, n_tokens)
        self.requests[request_id] = rp
        for pno, page in enumerate(pages):
            self.directory.publish(request_id, pno, dev, page)
        self.booked_pages_cum += n_pages
        return rp

    # -- hot-prefix replication / page dedup (PR 6) ------------------------
    def replica_copy_cost_s(self, n_pages: int) -> float:
        """One-time fabric cost of copying ``n_pages`` to another pool
        device (read leg + write leg run on different links; a symmetric
        fabric makes them equal, so charge one bulk transfer)."""
        return self.fabric.bulk_transfer_time(n_pages * self.page_bytes)

    def replicate_prefix(self, tokens, pages, src_device: int,
                         dst_device: int) -> int:
        """Copy a cached prefix's pages onto ``dst_device`` (hot-prefix
        replication): allocate fresh pages there, register them as a
        replica on the backing radix node, book them against the
        device's budgets, and charge the one-time copy traffic — a bulk
        read on the owning link plus a bulk write on the target link.
        The copy is charged UNkeyed: it belongs to the cache, not to any
        request, so no departure ever subtracts it from the pressure
        signal.  Returns pages replicated (0 when the target doesn't
        fit, the node already has a copy there, or no node matches)."""
        if (self.radix is None or src_device == dst_device
                or not 0 <= dst_device < self.n_devices):
            return 0
        n_pages = len(pages)
        n_bytes = n_pages * self.page_bytes
        if n_pages == 0 or not self.placer.fits(dst_device, n_bytes=n_bytes,
                                                n_pages=n_pages):
            return 0
        new_pages = self.allocator.alloc(dst_device, n_pages)
        if new_pages is None:
            return 0
        took = self.radix.add_replica(tokens, dst_device, new_pages)
        if not took:
            self.allocator.release(dst_device, new_pages)
            return 0
        self.placer.adjust(dst_device, n_bytes=n_bytes, n_pages=n_pages)
        self._radix_pages[dst_device].update(new_pages)
        self.traffic.bulk_fetch(n_bytes, device=src_device)
        self.traffic.write_back(n_bytes, device=dst_device)
        self.replicated_pages += took
        return took

    def dedup_match(self, request_id: int, shared_pages) -> int:
        """Refcount-share a matched prefix's cached pages with a live
        request (page dedup): the request's freshly allocated private
        copies of the matched prefix return straight to the pool, its
        booking shrinks by the same amount, and its directory entries
        re-point at the cached pages.  Decode never mutates prefix
        pages, so no copy-on-write path is needed; the caller keeps the
        backing radix path pinned for the request's lifetime, which is
        what keeps the shared pages resident.  Returns pages shared."""
        rp = self.requests.get(request_id)
        if rp is None or request_id in self._shared_pages:
            return 0
        n = min(len(shared_pages), len(rp.pages))
        if n <= 0:
            return 0
        shared = list(shared_pages)[:n]
        self.allocator.release(rp.device, rp.pages[:n])
        self.placer.shrink(request_id, n_bytes=n * self.page_bytes,
                           n_pages=n)
        rp.pages = shared + rp.pages[n:]
        for pno, page in enumerate(shared):
            self.directory.publish(request_id, pno, rp.device, page)
        self._shared_pages[request_id] = shared
        for p in shared:
            k = (rp.device, p)
            self._shared_refs[k] = self._shared_refs.get(k, 0) + 1
        self.dedup_shared_pages += n
        self.booked_pages_cum -= n
        return n

    def release(self, request_id: int, *, keep_pages: int = 0) -> int:
        """Free a finished request's pool pages.

        ``keep_pages`` > 0 retains the request's first that-many pages
        (the radix-registered prefix) under cache ownership instead of
        freeing them: the allocator keeps them allocated, the device's
        byte/page budgets keep charging them (``Placer.adjust``), and
        they return to the pool only through ``radix_evict``.  Every
        page actually freed is purged from the attached index in the
        same motion — the index can never advertise a freed page.
        Returns the number of pages retained (0 on unknown requests).

        Shared pages (PR 6 dedup) never free here under another live
        sharer: pages this request BORROWED only drop a refcount (the
        last sharer out frees an orphaned page); pages this request OWNS
        that others still share turn sticky — excluded from invalidation
        and from the freed list, they stay allocated + booked as cache
        pages (if the index still references them) or orphans (freed at
        the last sharer's departure).  No double-free, no leak.
        """
        rp = self.requests.pop(request_id, None)
        if rp is None:
            return 0
        self.placer.release(request_id)
        dev = rp.device
        # drop this request's borrowed-page refcounts first; an orphan
        # whose last sharer just left finally returns to the pool
        borrowed = set(self._shared_pages.pop(request_id, []))
        for p in borrowed:
            k = (dev, p)
            left = self._shared_refs.get(k, 0) - 1
            if left > 0:
                self._shared_refs[k] = left
                continue
            self._shared_refs.pop(k, None)
            if p in self._orphaned[dev]:
                self._orphaned[dev].discard(p)
                self.allocator.release(dev, [p])
                self.placer.adjust(dev, n_bytes=-self.page_bytes,
                                   n_pages=-1)
        # pages OTHER live requests still share out of this one's
        # allocation are sticky: this departure must not free them
        sticky = {p for p in rp.pages
                  if p not in borrowed and (dev, p) in self._shared_refs}
        keep = max(0, min(int(keep_pages), len(rp.pages)))
        kept: list = []
        if self.radix is not None:
            # purge the freed tail FIRST: any node referencing one of
            # those pages loses its whole payload (a partially-freed
            # prefix is unreadable), which may un-register pages inside
            # the keep range too — retention is node-granular, so only
            # pages a surviving node still references are retained
            tail = [p for p in rp.pages[keep:]
                    if p not in borrowed and p not in sticky]
            if tail:
                self.radix.invalidate_pages(dev, tail)
            kept = [p for p in rp.pages[:keep]
                    if p not in borrowed and self.radix.owns(dev, p)]
        kept_set = set(kept)
        for p in sticky - kept_set:
            if self.radix is not None and self.radix.owns(dev, p):
                kept.append(p)      # sharer's pin keeps the node alive
            else:
                self._orphaned[dev].add(p)
                self.placer.adjust(dev, n_bytes=self.page_bytes, n_pages=1)
        kept_set = set(kept)
        freed = [p for p in rp.pages
                 if p not in kept_set and p not in borrowed
                 and p not in self._orphaned[dev]]
        if kept:
            self.placer.adjust(dev, n_bytes=len(kept) * self.page_bytes,
                               n_pages=len(kept))
            self._radix_pages[dev].update(kept)
        if freed:
            self.allocator.release(dev, freed)
        for pno in range(len(rp.pages)):
            self.directory.unpublish(request_id, pno)
        return len(kept)

    # -- radix page lifecycle ----------------------------------------------
    def _reclaim(self, evicted) -> int:
        """Return evicted prefixes' CACHE-OWNED pages to the allocator.
        Pages still backing a live request — possible when a caller
        inserted without retaining — are dropped from the index but
        stay allocated (the request's own release frees them)."""
        n_freed = 0
        for dev, pages in evicted:
            if not 0 <= dev < self.n_devices:
                continue
            owned = [p for p in pages if p in self._radix_pages[dev]]
            if not owned:
                continue
            self._radix_pages[dev].difference_update(owned)
            # a cache page a live request still refcount-shares must not
            # return to the pool under the sharer's feet: it is orphaned
            # (still allocated + booked) until the last sharer departs
            free_now = [p for p in owned
                        if (dev, p) not in self._shared_refs]
            self._orphaned[dev].update(
                p for p in owned if (dev, p) in self._shared_refs)
            if free_now:
                self.allocator.release(dev, free_now)
                self.placer.adjust(
                    dev, n_bytes=-len(free_now) * self.page_bytes,
                    n_pages=-len(free_now))
            n_freed += len(free_now)
        self.radix_evicted_pages += n_freed
        return n_freed

    def radix_evict(self, n_leaves: int = 1,
                    device: Optional[int] = None) -> int:
        """Evict up to ``n_leaves`` unpinned LRU cached prefixes
        (optionally restricted to one device) and reclaim their
        cache-owned pages.  Returns pages freed — note a 0 can also
        mean the victims' pages were live-request-backed; loops that
        need a 'nothing left to evict' signal must check the index
        (``evict_lru`` returning empty), as ``_evict_for_fit`` and
        ``evict_to_headroom`` do."""
        if self.radix is None:
            return 0
        return self._reclaim(self.radix.evict_lru(n_leaves, device=device))

    def _evictable_pages(self, device: int) -> int:
        """Cache-owned pages on ``device`` whose backing node is
        unpinned — what eviction can actually reclaim.  Pinned copies
        (a live request is reusing them) and live-request-backed pages
        must not count toward 'freeing the cache would fit it', or the
        feasibility guard drains unpinned prefixes for nothing."""
        held = self._radix_pages[device]
        if not held or self.radix is None:
            return 0
        return sum(1 for (d, p), node in self.radix.cached_pages().items()
                   if d == device and node.refs == 0 and p in held)

    def _evict_for_fit(self, n_bytes: float, n_pages: int) -> bool:
        """Placement-pressure eviction: free cached prefixes ONLY on a
        device whose EVICTABLE cache pages would actually make the
        request fit — a global LRU walk would drain healthy devices'
        caches without unblocking anything.  Evicts until that device
        fits the request (the caller retries placement); returns False
        when no device can be helped."""
        for dev in range(self.n_devices):
            evictable = self._evictable_pages(dev)
            if not evictable:
                continue
            if not (self.placer.pages_used[dev] - evictable + n_pages
                    <= self.placer.capacity_pages
                    and self.placer.bytes_used[dev]
                    - evictable * self.page_bytes + n_bytes
                    <= self.placer.capacity_bytes):
                continue        # even a fully-drained cache won't fit it
            reclaimed = 0
            while (self.placer.pages_used[dev] + n_pages
                   > self.placer.capacity_pages
                   or self.placer.bytes_used[dev] + n_bytes
                   > self.placer.capacity_bytes):
                evicted = self.radix.evict_lru(4, device=dev)
                if not evicted:
                    break       # remaining copies are pinned
                reclaimed += self._reclaim(evicted)
            if reclaimed:
                return True
        return False

    def radix_held_pages(self, device: Optional[int] = None) -> int:
        """Pages currently owned by the prefix cache (one device or all)."""
        if device is not None:
            return len(self._radix_pages[device])
        return sum(len(s) for s in self._radix_pages)

    def evict_to_headroom(self, frac: float) -> int:
        """Evict LRU cached prefixes until every device keeps at least
        ``frac`` of its pages free (finish-time pool pressure relief) —
        victims come from the PRESSURED device only.  Returns total
        pages freed; stops when nothing there is evictable."""
        if self.radix is None or frac <= 0:
            return 0
        total = 0
        for dev in range(self.n_devices):
            while (self.allocator.free_pages(dev)
                   < frac * self.allocator.pages_per_device
                   and self._radix_pages[dev]):
                # batched victims: one tree walk reclaims several
                # prefixes, instead of a full rescan per node
                evicted = self.radix.evict_lru(4, device=dev)
                if not evicted:
                    break
                total += self._reclaim(evicted)
        return total

    def note_departure(self, device: int, seconds: float) -> None:
        """Forward a finished request's measured demand share to the
        placer's pressure-keyed policies (core/placement.py)."""
        if 0 <= device < self.n_devices:
            self.placer.note_departure(device, seconds)

    # -- fabric accounting (delegates to the shared accountant) ------------
    @property
    def bytes_fetched(self) -> float:
        return self.traffic.stats.bytes_fetched

    @property
    def bytes_written(self) -> float:
        return self.traffic.stats.bytes_written

    def sparse_fetch_time(self, n_entries: int, *, device: int = 0,
                          contention: float = 1.0, key=None) -> float:
        return self.traffic.sparse_fetch(n_entries, self.entry_bytes,
                                         device=device,
                                         contention=contention, key=key)

    def prefetch_fetch_time(self, n_entries: int, *, device: int = 0,
                            contention: float = 1.0) -> float:
        """Speculative/warm-up entry fetch (fetch pipeline): same wire cost
        as a demand fetch, attributed to prefetch traffic."""
        return self.traffic.prefetch_fetch(n_entries, self.entry_bytes,
                                           device=device,
                                           contention=contention)

    def full_prefetch_time(self, n_tokens: int, *, device: int = 0,
                           contention: float = 1.0) -> float:
        n_bytes = n_tokens * self.entry_bytes * max(self.cfg.n_attn_layers, 1)
        return self.traffic.bulk_fetch(n_bytes, device=device,
                                       contention=contention)

    def write_back_time(self, n_tokens: int, *, device: int = 0,
                        contention: float = 1.0, key=None) -> float:
        n_bytes = n_tokens * self.entry_bytes * max(self.cfg.n_attn_layers, 1)
        return self.traffic.write_back(n_bytes, device=device,
                                       contention=contention, key=key)

    def device_of(self, request_id: int) -> int:
        rp = self.requests.get(request_id)
        return rp.device if rp else 0
