"""Pool-resident metadata: page directory with seqlock-versioned entries.

The paper (§4.3.1) replaces RPC-based metadata services with a shared
CXL memory region accessed via load/store.  We model that region as a set
of flat numpy arrays (the "pool namespace") plus an access-accounting hook
so the serving simulator can charge every metadata load/store to the
fabric cost model — the point being that lookups cost *memory ops*, not
RPCs.

Entries follow single-writer seqlock semantics: a writer bumps the version
to odd (claim), mutates, bumps to even (commit); readers retry on odd or
changed versions.  ``MetadataRegion.stats`` counts the cache-line touches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

LINE = 64  # CXL cache-line granularity


@dataclasses.dataclass
class AccessStats:
    loads: int = 0
    stores: int = 0

    def lines(self) -> int:
        return self.loads + self.stores


class PageDirectory:
    """Maps (seq_hash, page_no) -> (device_id, page_id) in pool memory.

    Open-addressed hash table living in the shared region; every probe is
    one cache-line load, every publish is two stores (claim+commit bracket
    folded into the line count).
    """

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = capacity
        self.keys = np.full(capacity, -1, np.int64)        # packed key
        self.vals = np.full((capacity, 2), -1, np.int32)   # (device, page)
        self.version = np.zeros(capacity, np.int64)        # seqlock
        self.stats = AccessStats()

    @staticmethod
    def _pack(seq_hash: int, page_no: int) -> int:
        return ((seq_hash & 0xFFFFFFFF) << 24) | (page_no & 0xFFFFFF)

    def _probe(self, key: int):
        h = (key * 0x9E3779B97F4A7C15) % self.capacity
        for i in range(self.capacity):
            slot = (h + i) % self.capacity
            self.stats.loads += 1
            if self.keys[slot] == key or self.keys[slot] == -1:
                return slot
        raise RuntimeError("page directory full")

    def publish(self, seq_hash: int, page_no: int, device: int, page: int):
        key = self._pack(seq_hash, page_no)
        slot = self._probe(key)
        # seqlock write bracket: version odd -> mutate -> even
        self.version[slot] += 1
        self.stats.stores += 1
        self.keys[slot] = key
        self.vals[slot] = (device, page)
        self.version[slot] += 1
        self.stats.stores += 1

    def lookup(self, seq_hash: int, page_no: int
               ) -> Optional[Tuple[int, int]]:
        key = self._pack(seq_hash, page_no)
        for _ in range(8):  # seqlock retry loop
            slot = self._probe(key)
            v0 = int(self.version[slot])
            self.stats.loads += 1
            if v0 % 2 == 1:
                continue
            if self.keys[slot] != key:
                return None
            dev, page = (int(self.vals[slot][0]), int(self.vals[slot][1]))
            self.stats.loads += 1
            if int(self.version[slot]) == v0:
                return dev, page
        return None

    def unpublish(self, seq_hash: int, page_no: int):
        key = self._pack(seq_hash, page_no)
        slot = self._probe(key)
        if self.keys[slot] == key:
            self.version[slot] += 1
            self.keys[slot] = -1
            self.vals[slot] = (-1, -1)
            self.version[slot] += 1
            self.stats.stores += 3


class PoolAllocator:
    """Per-device page allocator for the pool (O(1) ops, O(live) memory).

    One allocator per CXL device; the scheduler's interleaving decides
    *which* device a request's pages go to (core/pool.py).  Never-used
    pages are represented by a high-water mark (a 2 TB pool at 16-token
    pages is hundreds of millions of pages — materializing a free list
    would cost GBs of host memory); released pages go to a returned
    stack that is drained first.
    """

    def __init__(self, n_devices: int, pages_per_device: int):
        self.n_devices = n_devices
        self.pages_per_device = pages_per_device
        self._next = [0] * n_devices             # high-water mark
        self._returned = [[] for _ in range(n_devices)]

    def alloc(self, device: int, n: int):
        if self.free_pages(device) < n:
            return None
        ret = self._returned[device]
        take = min(len(ret), n)
        pages = [ret.pop() for _ in range(take)]
        fresh = n - take
        hw = self._next[device]
        pages.extend(range(hw, hw + fresh))
        self._next[device] = hw + fresh
        return pages

    def release(self, device: int, pages):
        self._returned[device].extend(pages)

    def free_pages(self, device: int) -> int:
        return (self.pages_per_device - self._next[device]
                + len(self._returned[device]))

    def utilization(self) -> float:
        total = self.n_devices * self.pages_per_device
        used = sum(self._next[d] - len(self._returned[d])
                   for d in range(self.n_devices))
        return used / total
