"""Shared request->pool-device placement substrate (paper §4.3.3).

This is the ONE implementation of placement used by every serving layer:

  - ``SACSystem.place`` (core/sac.py) — page-granular pool bookkeeping for
    the real engine;
  - ``Scheduler`` (serving/scheduler.py) — byte-granular admission control;
  - ``simulate()`` (serving/simulator.py) — consumes placement through the
    Scheduler it embeds.

A :class:`Placer` tracks per-device occupancy in BOTH bytes and pages and
answers "which device should this request's KV live on" under a pluggable
:class:`PlacementPolicy`:

  - ``round_robin`` — the paper's CXL-device interleaving: consecutive
    requests land on different devices so concurrent fetches spread over
    fabric links (skipping full devices), bounding per-device imbalance;
  - ``first_fit``  — lowest-index device with room (interleaving OFF — the
    ablation baseline of paper Fig 13);
  - ``least_loaded`` — smallest booked-bytes device first (beyond-paper:
    balances *capacity* rather than request count, useful under highly
    skewed context lengths).

The paper stores one request's KV entirely within a single device; the
placer decides *which* device, the caller owns the page/byte payloads.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


class PlacementPolicy:
    """Candidate-device ordering strategy.  Stateless except for what the
    subclass declares (round-robin keeps a pointer)."""

    name = "base"

    def order(self, placer: "Placer") -> List[int]:
        raise NotImplementedError

    def on_commit(self, placer: "Placer", device: int) -> None:
        """Called after a successful placement on ``device``."""


class RoundRobinPolicy(PlacementPolicy):
    """Interleave requests across devices (paper §4.3.3)."""

    name = "round_robin"

    def __init__(self):
        self._rr = 0

    def order(self, placer: "Placer") -> List[int]:
        n = placer.n_devices
        return [(self._rr + i) % n for i in range(n)]

    def on_commit(self, placer: "Placer", device: int) -> None:
        self._rr = (device + 1) % placer.n_devices


class FirstFitPolicy(PlacementPolicy):
    """Lowest index with room (interleaving disabled, Fig 13 baseline)."""

    name = "first_fit"

    def order(self, placer: "Placer") -> List[int]:
        return list(range(placer.n_devices))


class LeastLoadedPolicy(PlacementPolicy):
    """Smallest booked-bytes device first (ties break toward pages, then
    index, so the ordering is deterministic)."""

    name = "least_loaded"

    def order(self, placer: "Placer") -> List[int]:
        return sorted(range(placer.n_devices),
                      key=lambda d: (placer.bytes_used[d],
                                     placer.pages_used[d], d))


POLICIES = {
    "round_robin": RoundRobinPolicy,
    "first_fit": FirstFitPolicy,
    "least_loaded": LeastLoadedPolicy,
}


def make_policy(policy: str) -> PlacementPolicy:
    if policy not in POLICIES:
        raise ValueError(f"unknown placement policy {policy!r} "
                         f"(have {sorted(POLICIES)})")
    return POLICIES[policy]()


def policy_for_interleave(interleave: bool) -> str:
    """Map the paper's interleave on/off knob to a policy name."""
    return "round_robin" if interleave else "first_fit"


# ---------------------------------------------------------------------------
# the placer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Booking:
    device: int
    n_bytes: float
    n_pages: int


class Placer:
    """Capacity-aware request->device placement with byte AND page budgets.

    ``place`` walks devices in policy order and books the first that fits
    both budgets; ``release`` undoes a booking.  All serving layers share
    this class so their placement decisions agree by construction.
    """

    def __init__(self, n_devices: int, *, policy: str = "round_robin",
                 capacity_bytes: float = float("inf"),
                 capacity_pages: Optional[int] = None):
        assert n_devices >= 1
        self.n_devices = n_devices
        self.policy = make_policy(policy)
        self.capacity_bytes = capacity_bytes
        self.capacity_pages = (capacity_pages if capacity_pages is not None
                               else (1 << 62))
        self.bytes_used: List[float] = [0.0] * n_devices
        self.pages_used: List[int] = [0] * n_devices
        self.counts: List[int] = [0] * n_devices      # active requests
        self._bookings: Dict[int, _Booking] = {}

    # -- placement ---------------------------------------------------------
    def fits(self, device: int, n_bytes: float = 0.0, n_pages: int = 0
             ) -> bool:
        return (self.bytes_used[device] + n_bytes <= self.capacity_bytes
                and self.pages_used[device] + n_pages <= self.capacity_pages)

    def place(self, request_id: int, *, n_bytes: float = 0.0,
              n_pages: int = 0) -> Optional[int]:
        """Book ``request_id`` on the first policy-ordered device with
        room; returns the device or None if every device is full."""
        assert request_id not in self._bookings, \
            f"request {request_id} already placed"
        for dev in self.policy.order(self):
            if self.fits(dev, n_bytes, n_pages):
                self.bytes_used[dev] += n_bytes
                self.pages_used[dev] += n_pages
                self.counts[dev] += 1
                self._bookings[request_id] = _Booking(dev, n_bytes, n_pages)
                self.policy.on_commit(self, dev)
                return dev
        return None

    def release(self, request_id: int) -> Optional[int]:
        """Undo a booking; returns the device it lived on (None if unknown)."""
        bk = self._bookings.pop(request_id, None)
        if bk is None:
            return None
        self.bytes_used[bk.device] -= bk.n_bytes
        self.pages_used[bk.device] -= bk.n_pages
        self.counts[bk.device] -= 1
        return bk.device

    def device_of(self, request_id: int) -> Optional[int]:
        bk = self._bookings.get(request_id)
        return bk.device if bk else None

    # -- introspection -----------------------------------------------------
    def device_loads(self) -> List[int]:
        """Active request count per device."""
        return list(self.counts)

    def max_imbalance(self) -> int:
        loads = self.device_loads()
        return max(loads) - min(loads) if loads else 0


# ---------------------------------------------------------------------------
# convenience (paper Fig 13 ablation helper)
# ---------------------------------------------------------------------------


def pages_for_tokens(n_tokens: int, page_size: int) -> int:
    return max(1, math.ceil(max(n_tokens, 0) / max(page_size, 1)))


def interleaved_assignment(request_ids: Sequence[int], n_devices: int,
                           enabled: bool = True) -> List[int]:
    """Round-robin request -> pool-device assignment (capacity-free).

    With interleaving on, consecutive requests land on different pool
    devices so concurrent fetches spread across fabric links; off, all
    requests hit device 0 (the ablation baseline of paper Fig 13).

    Assignment is by ARRIVAL ORDER (the shared round-robin policy), not
    keyed on the ids — a pre-substrate version used ``rid % n_devices``,
    which coincides for sequential ids but not for arbitrary ones.
    """
    placer = Placer(n_devices, policy=policy_for_interleave(enabled))
    return [placer.place(i) for i, _ in enumerate(request_ids)]
