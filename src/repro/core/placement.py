"""Shared request->pool-device placement substrate (paper §4.3.3).

This is the ONE implementation of placement used by every serving layer:

  - ``SACSystem.place`` (core/sac.py) — page-granular pool bookkeeping for
    the real engine;
  - ``Scheduler`` (serving/scheduler.py) — byte-granular admission control;
  - ``simulate()`` (serving/simulator.py) — consumes placement through the
    Scheduler it embeds.

A :class:`Placer` tracks per-device occupancy in BOTH bytes and pages and
answers "which device should this request's KV live on" under a pluggable
:class:`PlacementPolicy`:

  - ``round_robin`` — the paper's CXL-device interleaving: consecutive
    requests land on different devices so concurrent fetches spread over
    fabric links (skipping full devices), bounding per-device imbalance;
  - ``first_fit``  — lowest-index device with room (interleaving OFF — the
    ablation baseline of paper Fig 13);
  - ``least_loaded`` — smallest booked-bytes device first (beyond-paper:
    balances *capacity* rather than request count, useful under highly
    skewed context lengths);
  - ``pressure_aware`` — least *link-pressured* device first (the PR 4
    closed loop): the placer consumes a live per-device pressure feed
    (``TrafficStats.device_demand_s()`` step deltas, supplied by the
    engine or simulator through ``set_pressure_fn``) and lands new
    requests on the device whose fabric link has the most headroom,
    breaking pressure ties by booked bytes (the least-loaded key).
    Without a feed it degrades exactly to ``least_loaded``.
  - ``radix_affinity`` — pressure-aware *plus* prefix locality (the
    PR 5 closed loop): a request whose prompt prefix is cached on some
    device (serving/radix.py) passes that device as an ``affinity``
    hint together with the fabric/compute seconds reuse would save
    (skipped re-prefill + skipped pool write of the matched pages).
    The hint device wins whenever its corrected pressure is within the
    saved seconds of the best link — locality-first tiering, but a
    slammed link still repels the request.  Capacity ALWAYS wins: the
    hint only reorders candidates, never overrides the byte/page fit.

The paper stores one request's KV entirely within a single device; the
placer decides *which* device, the caller owns the page/byte payloads.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


class PlacementPolicy:
    """Candidate-device ordering strategy.  Stateless except for what the
    subclass declares (round-robin keeps a pointer)."""

    name = "base"

    def order(self, placer: "Placer") -> List[int]:
        raise NotImplementedError

    def on_commit(self, placer: "Placer", device: int) -> None:
        """Called after a successful placement on ``device``."""

    def on_departure(self, placer: "Placer", device: int,
                     seconds: float) -> None:
        """Called when a request finishes: its own measured demand share
        (``seconds``) just left ``device``'s link.  Pressure-keyed
        policies subtract it immediately instead of waiting for the EMA
        to decay (no-op for pressure-blind policies)."""


class RoundRobinPolicy(PlacementPolicy):
    """Interleave requests across devices (paper §4.3.3)."""

    name = "round_robin"

    def __init__(self):
        self._rr = 0

    def order(self, placer: "Placer") -> List[int]:
        n = placer.n_devices
        return [(self._rr + i) % n for i in range(n)]

    def on_commit(self, placer: "Placer", device: int) -> None:
        self._rr = (device + 1) % placer.n_devices


class FirstFitPolicy(PlacementPolicy):
    """Lowest index with room (interleaving disabled, Fig 13 baseline)."""

    name = "first_fit"

    def order(self, placer: "Placer") -> List[int]:
        return list(range(placer.n_devices))


class LeastLoadedPolicy(PlacementPolicy):
    """Smallest booked-bytes device first (ties break toward pages, then
    index, so the ordering is deterministic)."""

    name = "least_loaded"

    def order(self, placer: "Placer") -> List[int]:
        return sorted(range(placer.n_devices),
                      key=lambda d: (placer.bytes_used[d],
                                     placer.pages_used[d], d))


class PressureAwarePolicy(PlacementPolicy):
    """Least link-pressured device first (serving/arbiter.py feedback
    loop): the primary key is the placer's live per-device pressure feed
    (demand fabric seconds observed last step), so a new request lands on
    the link with the most headroom even when byte loads are balanced.
    Ties fall back to the least-loaded ordering (bytes, pages, index) —
    with no feed attached every pressure is 0.0 and the policy IS
    least_loaded.

    The feed is a per-STEP measurement, so several requests admitted in
    one scheduling gap would all see the same stale snapshot and herd
    onto the same device.  The policy therefore keeps an in-flight
    correction: each booking committed since the snapshot last changed
    adds one average request's worth of pressure to its device, exactly
    like the least-loaded key updates bytes per booking."""

    name = "pressure_aware"
    ema_beta = 0.7      # snapshot smoothing: one step's demand delta is
                        # noisy (cold bursts, warm-up); the decision key
                        # is an EMA over successive snapshots

    def __init__(self):
        self._snapshot = None          # (epoch, values) of the last reset
        self._ema: List[float] = []
        self._placed_since: List[int] = []
        # EMA of departed requests' measured per-step shares: the
        # in-flight correction's per-request estimate when the live
        # signal cannot provide one (right after a synchronized finish
        # wave the feed is near zero and sum(ema)/active collapses —
        # without this floor an admission burst would herd)
        self._dep_share = 0.0

    def _corrected(self, placer: "Placer") -> List[float]:
        pressure = placer.device_pressure()
        # a snapshot is stale until the feed is re-measured — tracked by
        # the placer's pressure epoch (bumped by the serving layer each
        # step) so a fresh reading that happens to EQUAL the previous
        # one still resets the correction (steady-state traces repeat
        # values exactly; accumulating would double-count load the new
        # measurement already includes)
        snapshot = (placer.pressure_epoch, pressure)
        if snapshot != self._snapshot:
            self._snapshot = snapshot
            if len(self._ema) != placer.n_devices:
                self._ema = list(pressure)
            else:
                b = self.ema_beta
                self._ema = [b * e + (1 - b) * p
                             for e, p in zip(self._ema, pressure)]
            self._placed_since = [0] * placer.n_devices
        active = sum(placer.counts)
        per_req = sum(self._ema) / active if active else 0.0
        per_req = max(per_req, self._dep_share)
        return [p + per_req * n
                for p, n in zip(self._ema, self._placed_since)]

    def order(self, placer: "Placer") -> List[int]:
        pressure = self._corrected(placer)
        return sorted(range(placer.n_devices),
                      key=lambda d: (pressure[d], placer.bytes_used[d],
                                     placer.pages_used[d], d))

    def on_commit(self, placer: "Placer", device: int) -> None:
        if device < len(self._placed_since):
            self._placed_since[device] += 1

    def on_departure(self, placer: "Placer", device: int,
                     seconds: float) -> None:
        """A finishing request's own demand share leaves its link NOW:
        subtract it from the smoothed pressure instead of letting the
        EMA decay it over the next several snapshots (during which new
        requests would still see the departed load and avoid a link
        that is actually free).  The share also updates the per-request
        estimate the in-flight correction falls back on."""
        if seconds <= 0:
            return
        b = self.ema_beta
        self._dep_share = (b * self._dep_share + (1 - b) * seconds
                           if self._dep_share else seconds)
        if 0 <= device < len(self._ema):
            self._ema[device] = max(0.0, self._ema[device] - seconds)


class RadixAffinityPolicy(PressureAwarePolicy):
    """Prefix locality weighed against live link pressure (paper §A.3 +
    the "Unifying Sparse Attention with Hierarchical Memory"
    locality-first resolution): order devices by corrected pressure as
    ``pressure_aware`` does, but when the caller supplied an affinity
    hint — the device holding the request's radix-cached prefix, plus
    the seconds reuse there would save — promote that device to the
    front IF its pressure is within the saved seconds of the best
    candidate.  Reuse off-device is worthless (the pages cannot be read
    without crossing two links), so the comparison is exactly
    "locality benefit vs extra link exposure".  Capacity still always
    wins: ``Placer.place`` books the first *fitting* device in order.
    Without a hint (or without a pressure feed) the policy degrades to
    its parent."""

    name = "radix_affinity"

    def order(self, placer: "Placer") -> List[int]:
        pressure = self._corrected(placer)
        ordered = sorted(range(placer.n_devices),
                         key=lambda d: (pressure[d], placer.bytes_used[d],
                                        placer.pages_used[d], d))
        hint = placer.affinity_hint
        if hint is None:
            return ordered
        devs, bonus_s = hint
        devs = [d for d in devs if 0 <= d < placer.n_devices]
        if not devs:
            return ordered
        # PR 6: the hint may name SEVERAL devices (a replicated prefix —
        # every copy is equally reusable), so promote the cheapest copy,
        # not the single owner: the least-corrected-pressure replica
        # competes against the globally best link
        dev = min(devs, key=lambda d: (pressure[d], placer.bytes_used[d],
                                       placer.pages_used[d], d))
        if pressure[dev] <= pressure[ordered[0]] + max(bonus_s, 0.0):
            ordered.remove(dev)
            ordered.insert(0, dev)
        return ordered


POLICIES = {
    "round_robin": RoundRobinPolicy,
    "first_fit": FirstFitPolicy,
    "least_loaded": LeastLoadedPolicy,
    "pressure_aware": PressureAwarePolicy,
    "radix_affinity": RadixAffinityPolicy,
}


def make_policy(policy: str) -> PlacementPolicy:
    if policy not in POLICIES:
        raise ValueError(f"unknown placement policy {policy!r} "
                         f"(have {sorted(POLICIES)})")
    return POLICIES[policy]()


def policy_for_interleave(interleave: bool) -> str:
    """Map the paper's interleave on/off knob to a policy name."""
    return "round_robin" if interleave else "first_fit"


# ---------------------------------------------------------------------------
# the placer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Booking:
    device: int
    n_bytes: float
    n_pages: int


class Placer:
    """Capacity-aware request->device placement with byte AND page budgets.

    ``place`` walks devices in policy order and books the first that fits
    both budgets; ``release`` undoes a booking.  All serving layers share
    this class so their placement decisions agree by construction.
    """

    def __init__(self, n_devices: int, *, policy: str = "round_robin",
                 capacity_bytes: float = float("inf"),
                 capacity_pages: Optional[int] = None,
                 pressure_fn: Optional[Callable[[], Sequence[float]]] = None,
                 topology=None):
        assert n_devices >= 1
        self.n_devices = n_devices
        # optional FabricTopology (core/fabric.py): when attached, the
        # pressure feed is per-SEGMENT and device_pressure() projects
        # each device's BOTTLENECK-segment pressure (a device behind a
        # saturated trunk reads the trunk, not its idle leaf).  The
        # policies stay per-device — only the signal changes.
        self.topology = topology
        self.policy = make_policy(policy)
        self.capacity_bytes = capacity_bytes
        self.capacity_pages = (capacity_pages if capacity_pages is not None
                               else (1 << 62))
        self.bytes_used: List[float] = [0.0] * n_devices
        self.pages_used: List[int] = [0] * n_devices
        self.counts: List[int] = [0] * n_devices      # active requests
        self._bookings: Dict[int, _Booking] = {}
        self._pressure_fn = pressure_fn
        self.pressure_epoch = 0
        # transient per-placement hint (radix_affinity): set by place()
        # for the duration of the policy's order() call only
        self.affinity_hint: Optional[tuple] = None

    # -- live link-pressure feed (pressure_aware policy) -------------------
    def set_pressure_fn(self,
                        fn: Optional[Callable[[], Sequence[float]]]) -> None:
        """Attach the live per-device pressure source (demand fabric
        seconds per link, e.g. ``TrafficStats.device_demand_s()`` step
        deltas).  The feed is read at ``place`` time, so placement always
        sees the freshest pressure the serving layer measured.  In both
        serving layers the attached callable is the shared
        :class:`repro.serving.policy.PressureFeed` over a
        ``DemandTracker`` (serving/arbiter.py)."""
        self._pressure_fn = fn

    def note_pressure_update(self) -> None:
        """Mark the feed as re-measured (the serving layer calls this
        once per step).  The pressure_aware policy keys its in-flight
        booking correction on this epoch, NOT on value equality — a
        steady-state trace repeats pressure values exactly, and treating
        a fresh-but-equal reading as stale would keep accumulating
        synthetic load the new measurement already includes."""
        self.pressure_epoch += 1

    def device_pressure(self) -> List[float]:
        """Per-device link pressure from the attached feed (0.0 per
        device without one — pressure_aware then degrades to
        least_loaded).  Shorter feeds are zero-padded; longer ones
        truncated (the placer's device space is authoritative).

        With a topology attached the feed is per-SEGMENT and each
        device's reading is the max over the segments on its route —
        the bottleneck on the path a placement would load.  The flat
        star's identity routing makes this the plain per-device feed."""
        if self._pressure_fn is None:
            return [0.0] * self.n_devices
        raw = [max(float(p), 0.0) for p in self._pressure_fn()]
        if self.topology is not None:
            return self.topology.device_view(raw)
        return (raw + [0.0] * self.n_devices)[:self.n_devices]

    def corrected_pressure(self) -> List[float]:
        """Pressure as the active policy will see it at the NEXT
        placement: the raw feed plus pressure-keyed policies' in-flight
        booking correction.  The PR 6 replication trigger reads this —
        during a same-wave admission burst the raw feed is a stale
        snapshot, but every booking already committed raises its
        device's corrected pressure, so the burst itself can push the
        copy-holding link over the replication threshold before the
        feed catches up.  Pressure-blind policies fall back to the raw
        feed."""
        corr = getattr(self.policy, "_corrected", None)
        if corr is not None:
            return corr(self)
        return self.device_pressure()

    # -- placement ---------------------------------------------------------
    def fits(self, device: int, n_bytes: float = 0.0, n_pages: int = 0
             ) -> bool:
        return (self.bytes_used[device] + n_bytes <= self.capacity_bytes
                and self.pages_used[device] + n_pages <= self.capacity_pages)

    def place(self, request_id: int, *, n_bytes: float = 0.0,
              n_pages: int = 0, affinity=None,
              affinity_s: float = 0.0) -> Optional[int]:
        """Book ``request_id`` on the first policy-ordered device with
        room; returns the device or None if every device is full.

        ``affinity``/``affinity_s`` (radix_affinity policy): the
        device(s) holding the request's cached prefix — an int, or a
        sequence of ints when the prefix is replicated (PR 6) — and the
        seconds reuse there would save.  Pressure-blind policies ignore
        the hint; no policy may use it to override capacity — it only
        reorders candidates.
        """
        assert request_id not in self._bookings, \
            f"request {request_id} already placed"
        if affinity is None:
            self.affinity_hint = None
        else:
            devs = ((affinity,) if isinstance(affinity, int)
                    else tuple(affinity))
            self.affinity_hint = (devs, affinity_s) if devs else None
        try:
            order = self.policy.order(self)
        finally:
            self.affinity_hint = None
        for dev in order:
            if self.fits(dev, n_bytes, n_pages):
                self.bytes_used[dev] += n_bytes
                self.pages_used[dev] += n_pages
                self.counts[dev] += 1
                self._bookings[request_id] = _Booking(dev, n_bytes, n_pages)
                self.policy.on_commit(self, dev)
                return dev
        return None

    def adjust(self, device: int, *, n_bytes: float = 0.0,
               n_pages: int = 0) -> None:
        """Raw occupancy adjustment for non-request residents — the
        radix cache's retained prefix pages (core/sac.py) keep charging
        the device's byte/page budgets after their request's booking is
        gone, and are credited back when the index evicts them."""
        assert 0 <= device < self.n_devices, device
        self.bytes_used[device] = max(0.0, self.bytes_used[device] + n_bytes)
        self.pages_used[device] = max(0, self.pages_used[device] + n_pages)

    def note_departure(self, device: int, seconds: float) -> None:
        """Report a finished request's own measured demand share so
        pressure-keyed policies can subtract it from their smoothed
        per-link signal immediately (serving layers call this alongside
        their own pressure-feed correction at finish time)."""
        self.policy.on_departure(self, device, seconds)

    def shrink(self, request_id: int, *, n_bytes: float = 0.0,
               n_pages: int = 0) -> Tuple[float, int]:
        """Shrink a live booking in place (page dedup, PR 6): a request
        whose leading pages are refcount-shared with the radix cache
        returns its private copies to the pool, so its booking — and the
        device occupancy it charges — must drop by exactly that much NOW,
        not at release.  Release then subtracts only the shrunk booking,
        which is what keeps a departing sharer from subtracting bytes
        the cache (or another sharer) still holds.  Clamped to the
        booking; returns (bytes, pages) actually shrunk."""
        bk = self._bookings.get(request_id)
        if bk is None:
            return 0.0, 0
        n_bytes = min(max(n_bytes, 0.0), bk.n_bytes)
        n_pages = min(max(n_pages, 0), bk.n_pages)
        bk.n_bytes -= n_bytes
        bk.n_pages -= n_pages
        self.bytes_used[bk.device] = max(
            0.0, self.bytes_used[bk.device] - n_bytes)
        self.pages_used[bk.device] = max(
            0, self.pages_used[bk.device] - n_pages)
        return n_bytes, n_pages

    def release(self, request_id: int) -> Optional[int]:
        """Undo a booking; returns the device it lived on (None if
        unknown).  Subtracts the booking's CURRENT size — a booking
        shrunk by page dedup (``shrink``) releases only what it still
        holds, never bytes shared pages' other owners keep charging."""
        bk = self._bookings.pop(request_id, None)
        if bk is None:
            return None
        self.bytes_used[bk.device] = max(
            0.0, self.bytes_used[bk.device] - bk.n_bytes)
        self.pages_used[bk.device] = max(
            0, self.pages_used[bk.device] - bk.n_pages)
        self.counts[bk.device] -= 1
        return bk.device

    def device_of(self, request_id: int) -> Optional[int]:
        bk = self._bookings.get(request_id)
        return bk.device if bk else None

    # -- introspection -----------------------------------------------------
    def device_loads(self) -> List[int]:
        """Active request count per device."""
        return list(self.counts)

    def max_imbalance(self) -> int:
        loads = self.device_loads()
        return max(loads) - min(loads) if loads else 0


# ---------------------------------------------------------------------------
# convenience (paper Fig 13 ablation helper)
# ---------------------------------------------------------------------------


def pages_for_tokens(n_tokens: int, page_size: int) -> int:
    return max(1, math.ceil(max(n_tokens, 0) / max(page_size, 1)))


def interleaved_assignment(request_ids: Sequence[int], n_devices: int,
                           enabled: bool = True) -> List[int]:
    """Round-robin request -> pool-device assignment (capacity-free).

    With interleaving on, consecutive requests land on different pool
    devices so concurrent fetches spread across fabric links; off, all
    requests hit device 0 (the ablation baseline of paper Fig 13).

    Assignment is by ARRIVAL ORDER (the shared round-robin policy), not
    keyed on the ids — a pre-substrate version used ``rid % n_devices``,
    which coincides for sequential ids but not for arbitrary ones.
    """
    placer = Placer(n_devices, policy=policy_for_interleave(enabled))
    return [placer.place(i) for i, _ in enumerate(request_ids)]
