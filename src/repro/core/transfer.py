"""Fabric cost models: CXL / RDMA / local DRAM / TPU ICI.

These drive the event-driven serving simulator that reproduces the paper's
Figures 5 and 9-14.  Constants are calibrated against the paper's own
measurements (§3.2, Fig 5):

  - sparse fetch of 64-4096 MLA entries (1152 B each):
      CXL   = 1.04-1.64x local-DRAM latency,
      RDMA  = 4-19.7x local-DRAM, reaching ms-level at high entry counts;
  - "local DRAM" means *GPU-initiated* reads of host DRAM over PCIe
    (the paper's upper-bound backend), not CPU-local loads.

The RDMA model charges the full message-protocol stack the paper blames:
per-transfer setup (QP sync, doorbell, completion polling), per-segment
software overhead for scatter/gather lists, and message-size-limited
bandwidth.  The CXL model has near-zero protocol overhead but a lower
per-link bandwidth (PCIe5 x8 per device), which is why device interleaving
(paper §4.3.3) matters — the simulator models per-device link contention.

The ICI model is used for the TPU `pooled_hbm` backend mapping (DESIGN §2).

Consumers do not call these models directly for accounting: the shared
``FabricAccountant`` (core/traffic.py) wraps them so every serving layer
(engine, SACSystem, simulator) charges traffic into one ``TrafficStats``
schema.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

# ---------------------------------------------------------------------------
# QoS classes (fabric topology, core/fabric.py)
# ---------------------------------------------------------------------------
# Every transfer the accountant books carries one of two service classes.
# DEMAND traffic (decode-step top-k misses, prefill write-back) is on the
# token-latency critical path and owns the link.  SPECULATIVE traffic
# (arbiter-granted prefetch, warm-up) yields at congested fabric segments:
# on a topology with ``qos_spec_yield`` set, a segment services its
# speculative backlog only from the hide window left over after its demand
# backlog, and the un-serviced remainder is dropped from the step's
# exposure (speculated entries go stale by the next step, so deferring
# them has no value) and counted in ``TrafficStats.spec_yielded_s``.
QOS_DEMAND = 0
QOS_SPECULATIVE = 1


@dataclasses.dataclass(frozen=True)
class FabricModel:
    name: str
    base_latency_s: float        # one-time setup per fetch operation
    per_message_s: float         # per message / doorbell on the fabric
    per_entry_s: float           # per-segment software overhead (SGE build etc.)
    bandwidth_Bps: float         # per-initiator link bandwidth
    max_sge: int                 # segments coalesced per message
    granularity: int             # minimum transfer unit (bytes)
    congestion_n: float = 0.0    # per-entry overhead grows ~(1 + n/congestion_n)
                                 # (completion-queue pressure; 0 = none)

    def sparse_fetch_time(self, n_entries: int, entry_bytes: int,
                          contention: float = 1.0) -> float:
        """Time to fetch ``n_entries`` discrete entries (seconds).

        ``contention`` >= 1 scales the bandwidth term (link sharing).
        """
        if n_entries <= 0:
            return 0.0
        n_msgs = math.ceil(n_entries / self.max_sge)
        wire = math.ceil(entry_bytes / self.granularity) * self.granularity
        bw_t = n_entries * wire / self.bandwidth_Bps * contention
        cong = 1.0 + (n_entries / self.congestion_n if self.congestion_n else 0.0)
        return (self.base_latency_s + n_msgs * self.per_message_s
                + n_entries * self.per_entry_s * cong + bw_t)

    def per_entry_seconds(self, entry_bytes: int, *,
                          nominal_batch: int = 256) -> float:
        """Amortized seconds per entry for a sparse fetch of
        ``nominal_batch`` entries — the marginal cost the budget arbiter
        (serving/arbiter.py) uses to convert a link-seconds budget into a
        per-request speculative entry budget.  Amortizing over a batch
        spreads the one-time ``base_latency_s`` the way a real per-step
        miss burst does."""
        n = max(int(nominal_batch), 1)
        return self.sparse_fetch_time(n, entry_bytes) / n

    def bulk_transfer_time(self, n_bytes: int, contention: float = 1.0
                           ) -> float:
        """Streaming transfer of a contiguous region (full-prefetch path)."""
        if n_bytes <= 0:
            return 0.0
        n_msgs = max(1, math.ceil(n_bytes / (1 << 20)))  # 1 MiB messages
        return (self.base_latency_s + n_msgs * self.per_message_s
                + n_bytes / self.bandwidth_Bps * contention)


# ---------------------------------------------------------------------------
# fetch/compute overlap (fetch pipeline, serving/prefetch.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineModel:
    """Issued-vs-exposed split for pipelined fabric traffic.

    CXL's load/store semantics let a decode step's miss fetches (and all
    speculative prefetch) be *issued* into per-device double-buffered
    queues and drained while the step computes; only the tail that does
    not fit in the hide window stalls the step — the *exposed* time.

    ``depth`` is the number of in-flight step buffers (2 = classic double
    buffering: the fetch for step t+1 drains behind step t's compute);
    ``overlap_frac`` is the fraction of a step's compute the link can
    actually hide behind (dependency chains — the layer's own indexer and
    top-k must run before its miss set is known — keep it < 1).

    Invariant (tested): ``0 <= exposed_time(...) <= issued``.
    """

    depth: int = 2
    overlap_frac: float = 0.85

    def hide_window_s(self, compute_s: float) -> float:
        return max(self.overlap_frac, 0.0) * max(compute_s, 0.0) \
            * max(self.depth - 1, 0)

    def exposed_time(self, issued_s: float, compute_s: float) -> float:
        """Seconds of ``issued_s`` fabric time NOT hidden behind compute."""
        if issued_s <= 0.0:
            return 0.0
        return max(0.0, issued_s - self.hide_window_s(compute_s))


# serial reference: nothing hides, exposed == issued (the seed's model)
NO_OVERLAP = PipelineModel(depth=1, overlap_frac=0.0)


# ---------------------------------------------------------------------------
# calibrated fabrics (paper Fig 5 / §A.2)
# ---------------------------------------------------------------------------

# GPU reading host DRAM through PCIe5 x16: ~1.5 us base, ~60 GB/s effective.
DRAM = FabricModel("dram", base_latency_s=1.5e-6, per_message_s=0.0,
                   per_entry_s=0.0, bandwidth_Bps=60e9, max_sge=1 << 30,
                   granularity=64)

# CXL Type-3 pool behind an XConn switch: load/store semantics, no message
# protocol; 36 GB/s effective per x8 device link.
CXL = FabricModel("cxl", base_latency_s=0.8e-6, per_message_s=0.0,
                  per_entry_s=0.0, bandwidth_Bps=36e9, max_sge=1 << 30,
                  granularity=64)

# 100 Gb/s RNIC: QP sync / doorbell / completion-poll setup, 30-entry
# scatter/gather lists, per-segment software overhead that degrades under
# completion-queue pressure (the paper's "dozens of independent requests").
RDMA = FabricModel("rdma", base_latency_s=1e-6, per_message_s=0.3e-6,
                   per_entry_s=0.07e-6, bandwidth_Bps=12.5e9, max_sge=30,
                   granularity=256, congestion_n=1400)

# TPU ICI link (the pooled_hbm fabric on the TPU mapping): remote-DMA
# semantics, ~1 us software-visible latency, ~45 GB/s effective per link.
ICI = FabricModel("ici", base_latency_s=1.0e-6, per_message_s=0.0,
                  per_entry_s=0.0, bandwidth_Bps=45e9, max_sge=1 << 30,
                  granularity=32)

# local HBM (GPU-only baseline of Fig 12)
HBM = FabricModel("hbm", base_latency_s=0.1e-6, per_message_s=0.0,
                  per_entry_s=0.0, bandwidth_Bps=819e9, max_sge=1 << 30,
                  granularity=32)

FABRICS: Dict[str, FabricModel] = {f.name: f for f in
                                   (DRAM, CXL, RDMA, ICI, HBM)}


def fig5_ratios(n_entries: int, entry_bytes: int = 1152) -> Dict[str, float]:
    """Fetch-latency ratio vs the DRAM baseline (reproduces paper Fig 5)."""
    base = DRAM.sparse_fetch_time(n_entries, entry_bytes)
    return {name: f.sparse_fetch_time(n_entries, entry_bytes) / base
            for name, f in FABRICS.items() if name in ("cxl", "rdma", "dram")}
