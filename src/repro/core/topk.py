"""Top-k selection strategies over indexer scores.

``topk_select`` (re-exported from models/dsa.py) is the plain masked
``lax.top_k``.  ``topk_hierarchical`` is the *distributed* variant used as a
beyond-paper optimization (§Perf): when scores live sharded over the pool
axis, doing a local top-k per shard and re-selecting over the gathered
candidates moves ``shards * k`` score elements over the fabric instead of
the full ``[B, S]`` score matrix.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                   # jax >= 0.5
    _shard_map = jax.shard_map
    _NO_REP_CHECK = {"check_vma": False}
except AttributeError:                 # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
    _NO_REP_CHECK = {"check_rep": False}

from repro.models.dsa import NEG_INF, topk_select  # noqa: F401  (re-export)


def _hier_topk_local(scores, cache_len, *, k: int, axis: str):
    """shard_map body: local top-k then all-gather candidates + re-top-k.

    scores: [B_l, S_l]; cache_len: [B_l] -> (idx [B_l, k] global, valid).
    """
    S_local = scores.shape[-1]
    rank = jax.lax.axis_index(axis)
    base = rank * S_local
    pos = base + jnp.arange(S_local, dtype=jnp.int32)
    masked = jnp.where(pos[None, :] < cache_len[:, None], scores, NEG_INF)
    k_local = min(k, S_local)
    loc_scores, loc_idx = jax.lax.top_k(masked, k_local)
    loc_idx = loc_idx.astype(jnp.int32) + base
    # gather shards*k_local candidates everywhere, re-select
    cand_scores = jax.lax.all_gather(loc_scores, axis, axis=1, tiled=True)
    cand_idx = jax.lax.all_gather(loc_idx, axis, axis=1, tiled=True)
    top_scores, pos_in_cand = jax.lax.top_k(cand_scores, k)
    idx = jnp.take_along_axis(cand_idx, pos_in_cand, axis=1)
    valid = top_scores > NEG_INF / 2
    # position-sort the selected set (invalid lanes last), matching
    # dsa.topk_select: keeps sparse decode bit-exact vs dense and the
    # single-device path, and gathers monotone (see topk_select)
    order = jnp.argsort(jnp.where(valid, idx, jnp.int32(1 << 30)), axis=-1)
    return (jnp.take_along_axis(idx, order, axis=-1),
            jnp.take_along_axis(valid, order, axis=-1))


def make_hierarchical_topk(mesh: Mesh, k: int, *, batch_axes=("pod", "data"),
                           pool_axis: str = "model"):
    """(scores [B, S@pool_axis], cache_len [B]) -> (idx [B,k], valid [B,k])."""
    import functools
    batch = tuple(a for a in batch_axes if a in mesh.axis_names)
    body = functools.partial(_hier_topk_local, k=k, axis=pool_axis)
    # replication check off (check_vma / legacy check_rep): the tiled
    # all_gather makes every pool-axis rank's candidate set identical, so
    # the re-top-k output IS replicated over the pool axis — but the
    # inference can't prove it.
    return _shard_map(body, mesh=mesh,
                      in_specs=(P(batch, pool_axis), P(batch)),
                      out_specs=(P(batch, None), P(batch, None)),
                      **_NO_REP_CHECK)
