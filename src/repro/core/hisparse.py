"""Functional HiSparse hierarchical device buffer (paper Appendix C).

The decode instance keeps a small hot tier of KV entries in device HBM
(``device_buffer_size`` entries per request).  Every decode step the
swap-in performs, per request, the three operations of the HiSparse CUDA
kernel — all as pure JAX ops with static shapes so the whole thing is
jit/vmap-able and property-testable:

  1. **miss identification** — which of the step's top-k positions are not
     resident in the buffer (page-table lookup);
  2. **LRU eviction** — pick the least-recently-used resident slots that
     are *not* part of the current top-k as eviction victims (empty slots
     are filled first);
  3. **page-table update + fetch** — unmap victims, map fetched pages in,
     write the fetched data, bump recency clocks.

All scatters use a padding "sink" row (index ``buf``/``S``) for inactive
lanes so no two active lanes ever write the same slot — scatter-set order
is therefore deterministic.

The returned ``hits``/``misses`` counts drive the transfer cost model:
only misses cross the fabric (paper §5.5 — a larger buffer lowers miss
traffic, which is exactly what Fig 14 measures).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)
_BIG = jnp.int32(1 << 30)


class BufferState(NamedTuple):
    """Per-request hot-tier state (all leading dims = [B, ...])."""
    entries: jnp.ndarray      # [B, buf, d]   cached KV entries
    slot_pos: jnp.ndarray     # [B, buf]      global position held by slot (-1 empty)
    page_table: jnp.ndarray   # [B, S]        position -> slot (-1 not resident)
    last_use: jnp.ndarray     # [B, buf]      LRU clocks
    clock: jnp.ndarray        # [B]           step counter


def init_buffer(batch: int, buf_size: int, seq_len: int, entry_dim: int,
                dtype=jnp.bfloat16) -> BufferState:
    return BufferState(
        entries=jnp.zeros((batch, buf_size, entry_dim), dtype),
        slot_pos=jnp.full((batch, buf_size), EMPTY),
        page_table=jnp.full((batch, seq_len), EMPTY),
        last_use=jnp.zeros((batch, buf_size), jnp.int32),
        clock=jnp.zeros((batch,), jnp.int32),
    )


def lookup(state: BufferState, idx: jnp.ndarray
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Which of idx [B, k] are resident?  -> (slots [B,k], hit [B,k])."""
    slots = jnp.take_along_axis(state.page_table, idx, axis=1)
    return slots, slots >= 0


def _swap_in_one(entries, slot_pos, page_table, last_use, clock,
                 idx, fetched, valid):
    """Single-request swap-in (vmapped over B).

    idx: [k] positions requested this step (always in [0, S));
    fetched: [k, d] pool values for all of them (hits keep their buffered
    copy — static shapes); valid: [k] mask of real lanes.

    Note: if ``k > buf`` overflow misses stay unbuffered; accounting of
    hits is exact because reads happen before the swap-in.
    """
    buf = slot_pos.shape[0]
    k = idx.shape[0]
    S = page_table.shape[0]
    order = jnp.arange(k, dtype=jnp.int32)

    slots = page_table[idx]                                # [k]
    hit = (slots >= 0) & valid
    miss = (~hit) & valid
    # dedupe repeated positions within idx: only the first VALID
    # occurrence fills (invalid lanes must not shadow valid duplicates)
    idx_dedup = jnp.where(valid, idx, S)
    first_occ = jnp.full((S + 1,), k, jnp.int32).at[idx_dedup].min(order)
    miss = miss & (first_occ[idx_dedup] == order)

    # eviction order: empty slots first, then LRU, protected (current hits)
    # last.
    prot = jnp.zeros((buf,), bool).at[jnp.where(hit, slots, buf - 1)].max(hit)
    empty = slot_pos < 0
    key = jnp.where(empty, jnp.arange(buf, dtype=jnp.int32) - _BIG,
                    jnp.where(prot, _BIG, last_use))
    victim_order = jnp.argsort(key).astype(jnp.int32)      # [buf]

    miss_rank = jnp.cumsum(miss.astype(jnp.int32)) - 1     # [k]
    fillable = miss & (miss_rank < buf)
    assign = jnp.where(fillable,
                       victim_order[jnp.clip(miss_rank, 0, buf - 1)],
                       buf)                                # buf = sink row

    # --- padded updates: row S / row buf are write sinks ---
    pt = jnp.concatenate([page_table, jnp.full((1,), EMPTY)])
    sp = jnp.concatenate([slot_pos, jnp.full((1,), EMPTY)])
    old_pos = sp[assign]                                   # evicted position
    pt = pt.at[jnp.where(old_pos >= 0, old_pos, S)].set(EMPTY)
    pt = pt.at[jnp.where(fillable, idx, S)].set(assign)
    page_table = pt[:S]

    sp = sp.at[assign].set(jnp.where(fillable, idx, EMPTY))
    slot_pos = sp[:buf]

    ent = jnp.concatenate(
        [entries, jnp.zeros((1, entries.shape[-1]), entries.dtype)])
    ent = ent.at[assign].set(fetched.astype(entries.dtype))
    entries = ent[:buf]

    touched = jnp.where(hit, slots, assign)                # in [0, buf]
    lu = jnp.concatenate([last_use, jnp.zeros((1,), jnp.int32)])
    last_use = lu.at[touched].set(clock)[:buf]

    return (entries, slot_pos, page_table, last_use,
            hit.astype(jnp.int32).sum(), miss.astype(jnp.int32).sum())


def swap_in(state: BufferState, idx: jnp.ndarray, fetched: jnp.ndarray,
            valid: jnp.ndarray) -> Tuple[BufferState, jnp.ndarray, jnp.ndarray]:
    """Batched swap-in.  idx: [B,k]; fetched: [B,k,d]; valid: [B,k].

    Returns (state', hits [B], misses [B]).
    """
    clock = state.clock + 1
    entries, slot_pos, page_table, last_use, hits, misses = jax.vmap(
        _swap_in_one)(state.entries, state.slot_pos, state.page_table,
                      state.last_use, clock, idx, fetched, valid)
    return (BufferState(entries, slot_pos, page_table, last_use, clock),
            hits, misses)


def read_through(state: BufferState, idx: jnp.ndarray, fetched: jnp.ndarray,
                 valid: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, BufferState, jnp.ndarray, jnp.ndarray]:
    """Serve idx from the buffer where resident, else from ``fetched``
    (pool values), updating the buffer.  Returns (values [B,k,d], state',
    hits [B], misses [B]).

    Values are bit-identical with or without the buffer — the hot tier
    changes *traffic*, never results (the pool is authoritative; entries
    are immutable once written).
    """
    slots, hit = lookup(state, idx)
    buffered = jnp.take_along_axis(
        state.entries,
        jnp.clip(slots, 0, state.entries.shape[1] - 1)[..., None], axis=1)
    vals = jnp.where((hit & valid)[..., None], buffered.astype(fetched.dtype),
                     fetched)
    new_state, hits, misses = swap_in(state, idx, fetched, valid)
    return vals, new_state, hits, misses


# ---------------------------------------------------------------------------
# layered layout (serving engine: one buffer per pool layer)
# ---------------------------------------------------------------------------


def init_layered_buffer(n_layers: int, batch: int, buf_size: int,
                        seq_len: int, entry_dim: int,
                        dtype=jnp.bfloat16) -> BufferState:
    """Per-(layer, request) buffer stack: every field gains a leading
    [L] axis (entries [L, B, buf, d], page_table [L, B, S], ...).

    This is the ``hot_buf`` entry of the engine's serve_state pytree;
    the decode step threads per-layer slices through ``read_through``.
    """
    return BufferState(
        entries=jnp.zeros((n_layers, batch, buf_size, entry_dim), dtype),
        slot_pos=jnp.full((n_layers, batch, buf_size), EMPTY),
        page_table=jnp.full((n_layers, batch, seq_len), EMPTY),
        last_use=jnp.zeros((n_layers, batch, buf_size), jnp.int32),
        clock=jnp.zeros((n_layers, batch), jnp.int32),
    )


def reset_lane(state: BufferState, lane: int) -> BufferState:
    """Clear one request lane of a layered buffer ([L, B, ...] layout).

    Used when a serving slot is recycled: the next request must not see
    the previous occupant's residency (its pool pages are reused).
    Entries need no clearing — unmapped slots are unreachable.
    """
    return BufferState(
        entries=state.entries,
        slot_pos=state.slot_pos.at[:, lane].set(EMPTY),
        page_table=state.page_table.at[:, lane].set(EMPTY),
        last_use=state.last_use.at[:, lane].set(0),
        clock=state.clock.at[:, lane].set(0),
    )
