"""Functional HiSparse hierarchical device buffer (paper Appendix C).

The decode instance keeps a small hot tier of KV entries in device HBM
(``device_buffer_size`` entries per request).  Every decode step the
swap-in performs, per request, the three operations of the HiSparse CUDA
kernel — all as pure JAX ops with static shapes so the whole thing is
jit/vmap-able and property-testable:

  1. **miss identification** — which of the step's top-k positions are not
     resident in the buffer (page-table lookup);
  2. **LRU eviction** — pick the least-recently-used resident slots that
     are *not* part of the current top-k as eviction victims (empty slots
     are filled first);
  3. **page-table update + fetch** — unmap victims, map fetched pages in,
     write the fetched data, bump recency clocks.

All scatters use a padding "sink" row (index ``buf``/``S``) for inactive
lanes so no two active lanes ever write the same slot — scatter-set order
is therefore deterministic.

The returned ``hits``/``misses`` counts drive the transfer cost model:
only misses cross the fabric (paper §5.5 — a larger buffer lowers miss
traffic, which is exactly what Fig 14 measures).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

EMPTY = jnp.int32(-1)
# per-layer buffer sizing (serving/arbiter.py LayerSizer): a DISABLED
# slot belongs to no layer budget — it is never empty, never a victim,
# and never assigned, so a layered buffer can give each layer its own
# effective size inside one static [L, B, buf_max, ...] allocation
DISABLED = jnp.int32(-2)
_BIG = jnp.int32(1 << 30)


class BufferState(NamedTuple):
    """Per-request hot-tier state (all leading dims = [B, ...]).

    The ``pf_*`` fields are the speculative-prefetch bookkeeping of the
    fetch pipeline (serving/prefetch.py): ``pf_flag`` marks slots filled
    by ``warm_insert`` that have not been demand-hit yet; ``pf_inserted``
    / ``pf_used`` are cumulative per-request counters, so prefetch
    precision is measured *in-graph* (``wasted == inserted - used``).
    """
    entries: jnp.ndarray      # [B, buf, d]   cached KV entries
    slot_pos: jnp.ndarray     # [B, buf]      global position held by slot (-1 empty)
    page_table: jnp.ndarray   # [B, S]        position -> slot (-1 not resident)
    last_use: jnp.ndarray     # [B, buf]      LRU clocks
    clock: jnp.ndarray        # [B]           step counter
    pf_flag: jnp.ndarray      # [B, buf]      slot was prefetched, not yet used
    pf_inserted: jnp.ndarray  # [B]           cumulative warm-inserted entries
    pf_used: jnp.ndarray      # [B]           cumulative prefetched-then-hit


def init_buffer(batch: int, buf_size: int, seq_len: int, entry_dim: int,
                dtype=jnp.bfloat16) -> BufferState:
    return BufferState(
        entries=jnp.zeros((batch, buf_size, entry_dim), dtype),
        slot_pos=jnp.full((batch, buf_size), EMPTY),
        page_table=jnp.full((batch, seq_len), EMPTY),
        last_use=jnp.zeros((batch, buf_size), jnp.int32),
        clock=jnp.zeros((batch,), jnp.int32),
        pf_flag=jnp.zeros((batch, buf_size), bool),
        pf_inserted=jnp.zeros((batch,), jnp.int32),
        pf_used=jnp.zeros((batch,), jnp.int32),
    )


def lookup(state: BufferState, idx: jnp.ndarray
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Which of idx [B, k] are resident?  -> (slots [B,k], hit [B,k])."""
    slots = jnp.take_along_axis(state.page_table, idx, axis=1)
    return slots, slots >= 0


def _swap_in_one(entries, slot_pos, page_table, last_use, clock, pf_flag,
                 idx, fetched, valid):
    """Single-request swap-in (vmapped over B).

    idx: [k] positions requested this step (always in [0, S));
    fetched: [k, d] pool values for all of them (hits keep their buffered
    copy — static shapes); valid: [k] mask of real lanes.

    Note: if ``k > buf`` overflow misses stay unbuffered; accounting of
    hits is exact because reads happen before the swap-in.
    """
    buf = slot_pos.shape[0]
    k = idx.shape[0]
    S = page_table.shape[0]
    order = jnp.arange(k, dtype=jnp.int32)

    slots = page_table[idx]                                # [k]
    hit = (slots >= 0) & valid
    miss = (~hit) & valid
    # dedupe repeated positions within idx: only the first VALID
    # occurrence fills (invalid lanes must not shadow valid duplicates)
    idx_dedup = jnp.where(valid, idx, S)
    first_occ = jnp.full((S + 1,), k, jnp.int32).at[idx_dedup].min(order)
    miss = miss & (first_occ[idx_dedup] == order)

    # eviction order: empty slots first, then LRU, protected (current hits)
    # second-to-last, DISABLED slots (per-layer sizing) strictly last and
    # outside the assignable range.
    prot = jnp.zeros((buf,), bool).at[jnp.where(hit, slots, buf - 1)].max(hit)
    empty = slot_pos == EMPTY
    disabled = slot_pos == DISABLED
    key = jnp.where(empty, jnp.arange(buf, dtype=jnp.int32) - _BIG,
                    jnp.where(disabled, _BIG,
                              jnp.where(prot, _BIG - 1, last_use)))
    victim_order = jnp.argsort(key).astype(jnp.int32)      # [buf]
    n_slots = buf - disabled.astype(jnp.int32).sum()       # layer's size

    miss_rank = jnp.cumsum(miss.astype(jnp.int32)) - 1     # [k]
    fillable = miss & (miss_rank < n_slots)
    assign = jnp.where(fillable,
                       victim_order[jnp.clip(miss_rank, 0, buf - 1)],
                       buf)                                # buf = sink row

    # --- padded updates: row S / row buf are write sinks ---
    pt = jnp.concatenate([page_table, jnp.full((1,), EMPTY)])
    sp = jnp.concatenate([slot_pos, jnp.full((1,), EMPTY)])
    old_pos = sp[assign]                                   # evicted position
    pt = pt.at[jnp.where(old_pos >= 0, old_pos, S)].set(EMPTY)
    pt = pt.at[jnp.where(fillable, idx, S)].set(assign)
    page_table = pt[:S]

    sp = sp.at[assign].set(jnp.where(fillable, idx, EMPTY))
    slot_pos = sp[:buf]

    ent = jnp.concatenate(
        [entries, jnp.zeros((1, entries.shape[-1]), entries.dtype)])
    ent = ent.at[assign].set(fetched.astype(entries.dtype))
    entries = ent[:buf]

    touched = jnp.where(hit, slots, assign)                # in [0, buf]
    lu = jnp.concatenate([last_use, jnp.zeros((1,), jnp.int32)])
    last_use = lu.at[touched].set(clock)[:buf]

    # prefetch accounting: a demand hit on a prefetched slot consumes its
    # flag (counted once per slot — the scatter-max dedupes repeated idx);
    # demand fills overwrite any stale flag on the victim slot.
    hit_mask = jnp.zeros((buf + 1,), bool) \
        .at[jnp.where(hit, slots, buf)].max(hit)[:buf]
    pf_used = (pf_flag & hit_mask).astype(jnp.int32).sum()
    pf = jnp.concatenate([pf_flag & ~hit_mask, jnp.zeros((1,), bool)])
    pf_flag = pf.at[assign].set(False)[:buf]

    return (entries, slot_pos, page_table, last_use, pf_flag, pf_used,
            hit.astype(jnp.int32).sum(), miss.astype(jnp.int32).sum())


def swap_in(state: BufferState, idx: jnp.ndarray, fetched: jnp.ndarray,
            valid: jnp.ndarray) -> Tuple[BufferState, jnp.ndarray, jnp.ndarray]:
    """Batched swap-in.  idx: [B,k]; fetched: [B,k,d]; valid: [B,k].

    Returns (state', hits [B], misses [B]).
    """
    clock = state.clock + 1
    (entries, slot_pos, page_table, last_use, pf_flag, pf_used, hits,
     misses) = jax.vmap(_swap_in_one)(
        state.entries, state.slot_pos, state.page_table,
        state.last_use, clock, state.pf_flag, idx, fetched, valid)
    return (BufferState(entries, slot_pos, page_table, last_use, clock,
                        pf_flag, state.pf_inserted,
                        state.pf_used + pf_used),
            hits, misses)


def read_through(state: BufferState, idx: jnp.ndarray, fetched: jnp.ndarray,
                 valid: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, BufferState, jnp.ndarray, jnp.ndarray]:
    """Serve idx from the buffer where resident, else from ``fetched``
    (pool values), updating the buffer.  Returns (values [B,k,d], state',
    hits [B], misses [B]).

    Values are bit-identical with or without the buffer — the hot tier
    changes *traffic*, never results (the pool is authoritative; entries
    are immutable once written).
    """
    slots, hit = lookup(state, idx)
    buffered = jnp.take_along_axis(
        state.entries,
        jnp.clip(slots, 0, state.entries.shape[1] - 1)[..., None], axis=1)
    vals = jnp.where((hit & valid)[..., None], buffered.astype(fetched.dtype),
                     fetched)
    new_state, hits, misses = swap_in(state, idx, fetched, valid)
    return vals, new_state, hits, misses


# ---------------------------------------------------------------------------
# warm inserts (fetch pipeline: speculative prefetch + prefill warm-up)
# ---------------------------------------------------------------------------


def _warm_insert_one(entries, slot_pos, page_table, last_use, clock, pf_flag,
                     idx, vals, valid):
    """Single-request warm insert (vmapped over B).

    Insert-without-read: positions already resident are skipped (no hit
    counted, no recency bump for THEIR slots beyond what the demand path
    did), and the current step's working set — slots with
    ``last_use >= clock`` (this step's hits, demand fills, and earlier
    warm inserts) — is never evicted.  Inserted slots get the current
    clock: the speculation is that they are next step's hits, so they age
    exactly like this step's demand entries.
    """
    buf = slot_pos.shape[0]
    w = idx.shape[0]
    S = page_table.shape[0]
    order = jnp.arange(w, dtype=jnp.int32)

    resident = page_table[idx] >= 0
    want = valid & ~resident
    idx_dedup = jnp.where(want, idx, S)
    first_occ = jnp.full((S + 1,), w, jnp.int32).at[idx_dedup].min(order)
    want = want & (first_occ[idx_dedup] == order)

    empty = slot_pos == EMPTY
    disabled = slot_pos == DISABLED
    prot = (last_use >= clock) & ~empty & ~disabled
    key = jnp.where(empty, jnp.arange(buf, dtype=jnp.int32) - _BIG,
                    jnp.where(disabled, _BIG,
                              jnp.where(prot, _BIG - 1, last_use)))
    victim_order = jnp.argsort(key).astype(jnp.int32)      # [buf]
    avail = (buf - prot.astype(jnp.int32).sum()            # evictable slots
             - disabled.astype(jnp.int32).sum())

    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    fill = want & (rank < avail)
    assign = jnp.where(fill, victim_order[jnp.clip(rank, 0, buf - 1)],
                       buf)                                # buf = sink row

    pt = jnp.concatenate([page_table, jnp.full((1,), EMPTY)])
    sp = jnp.concatenate([slot_pos, jnp.full((1,), EMPTY)])
    old_pos = sp[assign]
    pt = pt.at[jnp.where(old_pos >= 0, old_pos, S)].set(EMPTY)
    pt = pt.at[jnp.where(fill, idx, S)].set(assign)
    page_table = pt[:S]

    sp = sp.at[assign].set(jnp.where(fill, idx, EMPTY))
    slot_pos = sp[:buf]

    ent = jnp.concatenate(
        [entries, jnp.zeros((1, entries.shape[-1]), entries.dtype)])
    ent = ent.at[assign].set(vals.astype(entries.dtype))
    entries = ent[:buf]

    lu = jnp.concatenate([last_use, jnp.zeros((1,), jnp.int32)])
    last_use = lu.at[assign].set(clock)[:buf]

    pf = jnp.concatenate([pf_flag, jnp.zeros((1,), bool)])
    pf_flag = pf.at[assign].set(fill)[:buf]

    return (entries, slot_pos, page_table, last_use, pf_flag,
            fill.astype(jnp.int32).sum())


def warm_insert(state: BufferState, idx: jnp.ndarray, vals: jnp.ndarray,
                valid: jnp.ndarray) -> Tuple[BufferState, jnp.ndarray]:
    """Batched warm insert.  idx: [B, w]; vals: [B, w, d]; valid: [B, w].

    Inserts pool values into the hot tier WITHOUT serving a read — no
    hit/miss is counted, current-step hits are never evicted, and already
    resident positions are skipped.  Returns (state', inserted [B]); the
    cumulative ``pf_inserted`` counter advances by the same amount.
    """
    (entries, slot_pos, page_table, last_use, pf_flag, ins) = jax.vmap(
        _warm_insert_one)(state.entries, state.slot_pos, state.page_table,
                          state.last_use, state.clock, state.pf_flag,
                          idx, vals, valid)
    return (BufferState(entries, slot_pos, page_table, last_use,
                        state.clock, pf_flag, state.pf_inserted + ins,
                        state.pf_used),
            ins)


def warm_lane(state: BufferState, lane, idx: jnp.ndarray,
              vals: jnp.ndarray, valid: jnp.ndarray
              ) -> Tuple[BufferState, jnp.ndarray]:
    """Warm-insert into one request lane of a layered buffer.

    state: layered ([L, B, ...]); idx: [L, w]; vals: [L, w, d];
    valid: [L, w].  The per-layer slices of lane ``lane`` form exactly the
    batched layout (L plays the batch axis), so this is ``warm_insert``
    over layers.  Returns (state', total entries inserted) — the prefill
    warm-up path of serving/prefetch.py (radix-reused pages + top-scoring
    prompt entries seeding the hot tier).
    """
    sub = BufferState(*(t[:, lane] for t in state))
    sub, ins = warm_insert(sub, idx, vals, valid)
    new = BufferState(*(full.at[:, lane].set(part)
                        for full, part in zip(state, sub)))
    return new, ins.sum()


# ---------------------------------------------------------------------------
# layered layout (serving engine: one buffer per pool layer)
# ---------------------------------------------------------------------------


def init_layered_buffer(n_layers: int, batch: int,
                        buf_size: Union[int, Sequence[int]],
                        seq_len: int, entry_dim: int,
                        dtype=jnp.bfloat16,
                        buf_max: Union[int, None] = None) -> BufferState:
    """Per-(layer, request) buffer stack: every field gains a leading
    [L] axis (entries [L, B, buf, d], page_table [L, B, S], ...).

    ``buf_size`` may be a single size (uniform layers, the PR 1 layout)
    or a per-layer sequence (serving/arbiter.py ``LayerSizer``): the
    allocation is ``max(sizes)`` wide and layer ``l``'s slots beyond
    ``sizes[l]`` are marked :data:`DISABLED` — never resident, never a
    victim — so each layer runs at its own effective capacity inside one
    static layout.  ``buf_max`` overrides the allocation width (must be
    >= every size): the headroom online re-sizing (``resize_layers``)
    needs to grow a layer past its initial share later.

    This is the ``hot_buf`` entry of the engine's serve_state pytree;
    the decode step threads per-layer slices through ``read_through``.
    """
    if isinstance(buf_size, (int, np.integer)):
        sizes = [int(buf_size)] * n_layers
    else:
        sizes = [int(s) for s in buf_size]
        assert len(sizes) == n_layers, (len(sizes), n_layers)
    if buf_max is None:
        buf_max = max(max(sizes), 1)
    else:
        buf_max = int(buf_max)
        assert buf_max >= max(max(sizes), 1), (buf_max, sizes)
    slot = np.arange(buf_max)[None, None, :]
    sz = np.asarray(sizes, np.int32)[:, None, None]
    slot_pos = jnp.asarray(
        np.where(np.broadcast_to(slot < sz, (n_layers, batch, buf_max)),
                 int(EMPTY), int(DISABLED)), jnp.int32)
    return BufferState(
        entries=jnp.zeros((n_layers, batch, buf_max, entry_dim), dtype),
        slot_pos=slot_pos,
        page_table=jnp.full((n_layers, batch, seq_len), EMPTY),
        last_use=jnp.zeros((n_layers, batch, buf_max), jnp.int32),
        clock=jnp.zeros((n_layers, batch), jnp.int32),
        pf_flag=jnp.zeros((n_layers, batch, buf_max), bool),
        pf_inserted=jnp.zeros((n_layers, batch), jnp.int32),
        pf_used=jnp.zeros((n_layers, batch), jnp.int32),
    )


def _resize_one(entries, slot_pos, page_table, last_use, pf_flag, enabled):
    """Single-lane layer re-sizing (vmapped over L*B).

    ``enabled``: [buf] bool — the slot belongs to the layer's NEW budget.
    Slots leaving the budget are evicted (their position unmapped from the
    page table) and marked DISABLED; slots entering it open as EMPTY.
    Slots enabled in both layouts are untouched — resident entries, their
    recency clocks, and their prefetch flags survive the resize, so
    decoded tokens cannot change (the pool stays authoritative either
    way; only *residency* moved).
    """
    S = page_table.shape[0]
    displaced = (~enabled) & (slot_pos >= 0)
    pt = jnp.concatenate([page_table, jnp.full((1,), EMPTY)])
    pt = pt.at[jnp.where(displaced, slot_pos, S)].set(EMPTY)
    page_table = pt[:S]
    slot_pos = jnp.where(~enabled, DISABLED,
                         jnp.where(slot_pos == DISABLED, EMPTY, slot_pos))
    last_use = jnp.where(enabled, last_use, 0)
    pf_flag = pf_flag & enabled
    return entries, slot_pos, page_table, last_use, pf_flag


def resize_layers(state: BufferState, sizes: Sequence[int]) -> BufferState:
    """Re-apportion a layered buffer's per-layer capacities IN PLACE.

    state: layered ([L, B, buf_max, ...]); sizes: [L] new per-layer slot
    budgets (each <= buf_max — the static allocation width is the hard
    ceiling).  Layer ``l`` keeps its first ``sizes[l]`` slots enabled and
    the rest DISABLED: entries displaced by a shrink are evicted (their
    next demand read is an honest miss), entries in surviving slots are
    never corrupted, and the cumulative ``pf_*`` counters are preserved
    (a displaced prefetched entry simply counts as wasted speculation,
    exactly like an LRU eviction would).

    This is the engine's online LayerSizer path (serving/arbiter.py):
    every ``resize_interval`` steps the measured per-layer miss rates
    re-apportion the hot tier without reallocating the serve state.
    """
    L, B, buf_max = state.slot_pos.shape
    sz = np.asarray([int(s) for s in sizes], np.int32)
    assert sz.shape == (L,), (sz.shape, L)
    assert sz.max(initial=0) <= buf_max and sz.min(initial=1) >= 0, \
        (sizes, buf_max)
    enabled = jnp.asarray(
        np.broadcast_to(np.arange(buf_max)[None, :] < sz[:, None],
                        (L, buf_max)))

    def flat(t):
        return t.reshape(L * B, *t.shape[2:])

    en = jnp.repeat(enabled, B, axis=0)                    # [L*B, buf]
    entries, slot_pos, page_table, last_use, pf_flag = jax.vmap(
        _resize_one)(flat(state.entries), flat(state.slot_pos),
                     flat(state.page_table), flat(state.last_use),
                     flat(state.pf_flag), en)

    def unflat(t):
        return t.reshape(L, B, *t.shape[1:])

    return BufferState(
        entries=unflat(entries), slot_pos=unflat(slot_pos),
        page_table=unflat(page_table), last_use=unflat(last_use),
        clock=state.clock, pf_flag=unflat(pf_flag),
        pf_inserted=state.pf_inserted, pf_used=state.pf_used)


def reset_lane(state: BufferState, lane: int) -> BufferState:
    """Clear one request lane of a layered buffer ([L, B, ...] layout).

    Used when a serving slot is recycled: the next request must not see
    the previous occupant's residency (its pool pages are reused).
    Entries need no clearing — unmapped slots are unreachable.  DISABLED
    slots (per-layer sizing) keep their marker: layer capacities are a
    property of the buffer layout, not of the occupant.
    """
    lane_slots = state.slot_pos[:, lane]
    cleared = jnp.where(lane_slots == DISABLED, DISABLED, EMPTY)
    return BufferState(
        entries=state.entries,
        slot_pos=state.slot_pos.at[:, lane].set(cleared),
        page_table=state.page_table.at[:, lane].set(EMPTY),
        last_use=state.last_use.at[:, lane].set(0),
        clock=state.clock.at[:, lane].set(0),
        pf_flag=state.pf_flag.at[:, lane].set(False),
        pf_inserted=state.pf_inserted.at[:, lane].set(0),
        pf_used=state.pf_used.at[:, lane].set(0),
    )
