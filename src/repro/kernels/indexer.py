"""Pallas TPU kernel: lightning indexer scoring.

scores[s] = sum_h w[h] * ReLU(q[h] . keys[s]) / sqrt(di)

Grid over S blocks; each step does a [block_s, di] x [di, H] matmul on the
MXU, ReLU on the VPU, and a weighted reduction over heads.  q/w are small
and live fully in VMEM (index_map pinned to block 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _indexer_kernel(keys_ref, q_ref, w_ref, out_ref, *, di: int):
    keys = keys_ref[...].astype(jnp.float32)          # [bs, di]
    q = q_ref[...].astype(jnp.float32)                # [H, di]
    w = w_ref[...].astype(jnp.float32)                # [1, H]
    logits = jax.nn.relu(
        jax.lax.dot_general(keys, q, (((1,), (1,)), ((), ())))
    ) * (1.0 / np.sqrt(di))                           # [bs, H]
    out_ref[...] = jax.lax.dot_general(
        logits, w, (((1,), (1,)), ((), ()))).reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def indexer_scores(q: jnp.ndarray, w: jnp.ndarray, keys: jnp.ndarray, *,
                   block_s: int = 512, interpret: bool = True) -> jnp.ndarray:
    """q: [H, di]; w: [H]; keys: [S, di] -> scores [S] f32."""
    S, di = keys.shape
    H = q.shape[0]
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    kern = functools.partial(_indexer_kernel, di=di)
    out = pl.pallas_call(
        kern,
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, di), lambda i: (i, 0)),
            pl.BlockSpec((H, di), lambda i: (0, 0)),
            pl.BlockSpec((1, H), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, 1), jnp.float32),
        interpret=interpret,
    )(keys, q, w.reshape(1, H))
    return out[:, 0]
