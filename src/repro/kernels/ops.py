"""jit'd batched wrappers over the Pallas kernels (+ ref dispatch).

``use_pallas=False`` (default on this CPU container) routes to the pure-jnp
oracles in ref.py — the compiled dry-run uses that path, which XLA:TPU
fuses equivalently; on real TPU hardware flip ``use_pallas=True`` (kernels
are validated in interpret mode by tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.gather_kv import gather_kv, gather_kv_pages
from repro.kernels.indexer import indexer_scores as indexer_scores_pl
from repro.kernels.scatter_kv import scatter_kv
from repro.kernels.sparse_attn import NEG_INF, sparse_attn


def batched_gather(kv: jnp.ndarray, idx: jnp.ndarray, *,
                   use_pallas: bool = False, interpret: bool = True
                   ) -> jnp.ndarray:
    """kv: [B, S, d]; idx: [B, k] -> [B, k, d]."""
    if use_pallas:
        return jax.vmap(lambda a, b: gather_kv(a, b, interpret=interpret)
                        )(kv, idx)
    return jax.vmap(ref.gather_kv_ref)(kv, idx)


def batched_indexer_scores(q: jnp.ndarray, w: jnp.ndarray, keys: jnp.ndarray,
                           *, use_pallas: bool = False,
                           interpret: bool = True) -> jnp.ndarray:
    """q: [B, H, di]; w: [B, H]; keys: [B, S, di] -> [B, S] f32."""
    if use_pallas:
        return jax.vmap(lambda a, b, c: indexer_scores_pl(
            a, b, c, interpret=interpret))(q, w, keys)
    return jax.vmap(ref.indexer_scores_ref)(q, w, keys)


def batched_sparse_mla(q_lat: jnp.ndarray, q_pe: jnp.ndarray,
                       entries: jnp.ndarray, valid: jnp.ndarray, *,
                       dc: int, scale: float, use_pallas: bool = False,
                       interpret: bool = True) -> jnp.ndarray:
    """q_lat: [B,H,dc]; q_pe: [B,H,dr]; entries: [B,k,dc+dr]; valid: [B,k]
    -> out_lat [B,H,dc] f32."""
    if use_pallas:
        q = jnp.concatenate([q_lat, q_pe], axis=-1)
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        return jax.vmap(lambda a, b, c, d: sparse_attn(
            a, b, c, d, scale=scale, interpret=interpret))(
                q, entries, entries[..., :dc], bias)
    return jax.vmap(functools.partial(ref.sparse_mla_attn_ref, dc=dc,
                                      scale=scale))(q_lat, q_pe, entries,
                                                    valid)


def batched_sparse_gqa(q: jnp.ndarray, entries: jnp.ndarray,
                       valid: jnp.ndarray, *, n_kv: int,
                       use_pallas: bool = False, interpret: bool = True
                       ) -> jnp.ndarray:
    """q: [B,H,hd]; entries: [B,k,2*n_kv*hd]; valid: [B,k] -> [B,H,hd]."""
    B, H, hd = q.shape
    k = entries.shape[1]
    if use_pallas:
        kv = entries.reshape(B, k, 2, n_kv, hd)
        keys = kv[:, :, 0].transpose(0, 2, 1, 3)       # [B, n_kv, k, hd]
        vals = kv[:, :, 1].transpose(0, 2, 1, 3)
        qg = q.reshape(B, n_kv, H // n_kv, hd)
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        scale = 1.0 / np.sqrt(hd)

        def per_group(qr, kk, vv, bb):
            return sparse_attn(qr, kk, vv, bb, scale=scale,
                               interpret=interpret)

        out = jax.vmap(jax.vmap(per_group, in_axes=(0, 0, 0, None)),
                       in_axes=(0, 0, 0, 0))(qg, keys, vals, bias)
        return out.reshape(B, H, hd)
    return jax.vmap(functools.partial(ref.sparse_gqa_attn_ref, n_kv=n_kv)
                    )(q, entries, valid)


def batched_scatter(pool: jnp.ndarray, entries: jnp.ndarray,
                    idx: jnp.ndarray, *, use_pallas: bool = False,
                    interpret: bool = True) -> jnp.ndarray:
    """pool: [B,S,d]; entries: [B,k,d]; idx: [B,k] -> updated pool."""
    if use_pallas:
        return jax.vmap(lambda p, e, i: scatter_kv(p, e, i,
                                                   interpret=interpret)
                        )(pool, entries, idx)
    return jax.vmap(ref.scatter_kv_ref)(pool, entries, idx)
