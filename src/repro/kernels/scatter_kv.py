"""Pallas TPU kernel: coalesced KV write-back (SAC write path).

The paper's GPU write path uses warp-coalesced ``st.global.b64`` stores to
push prefill KV into the CXL pool.  The TPU analogue: scalar-prefetched
destination indices drive the *output* BlockSpec, so each grid step DMAs
one entry row VMEM->HBM directly into its pool slot.  The pool buffer is
input/output-aliased — unwritten rows keep their previous contents
(in-place scatter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(idx_ref, entries_ref, pool_ref, out_ref):
    out_ref[...] = entries_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_kv(pool: jnp.ndarray, entries: jnp.ndarray, idx: jnp.ndarray,
               *, interpret: bool = True) -> jnp.ndarray:
    """pool: [S, d]; entries: [k, d]; idx: [k] distinct rows -> updated pool."""
    k, d = entries.shape
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),       # entries
                pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),  # pool (aliased)
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},   # pool arg (after idx prefetch, entries)
        interpret=interpret,
    )(idx, entries, pool)
