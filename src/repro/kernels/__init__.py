"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships as <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), with ops.py as the jit'd batched wrapper and ref.py as the
pure-jnp oracle.  Validated in interpret mode on CPU
(tests/test_kernels.py); ``use_pallas=True`` activates them on TPU.

- gather_kv:   scalar-prefetch sparse KV gather (the SAC read path)
- scatter_kv:  coalesced write-back (the SAC write path)
- indexer:     lightning-indexer scoring (MXU matmul + weighted ReLU)
- sparse_attn: top-k sparse attention, online softmax (MLA + MQA/GQA)
"""
