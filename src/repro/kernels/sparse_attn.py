"""Pallas TPU kernel: top-k sparse attention with online softmax.

One kernel covers both attention forms used at decode time (DESIGN.md §4):

  - **absorbed MLA** (deepseek): q = concat(q_lat, q_pe) [H, dc+dr],
    keys = fetched latent entries [k, dc+dr], vals = entries[:, :dc];
  - **MQA / per-group GQA**: q [n_rep, hd], keys/vals [k, hd]
    (GQA = vmap over kv groups in ops.py).

Grid over k blocks; m/l/acc accumulators live in VMEM scratch and persist
across the sequential TPU grid (flash pattern: init at step 0, divide at
the last step).  ``bias`` carries the validity mask (-inf for invalid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _sparse_attn_kernel(q_ref, k_ref, v_ref, bias_ref, out_ref,
                        m_ref, l_ref, acc_ref, *, scale: float,
                        n_blocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                 # [H, dq]
    keys = k_ref[...].astype(jnp.float32)              # [bk, dq]
    vals = v_ref[...].astype(jnp.float32)              # [bk, dv]
    bias = bias_ref[...].astype(jnp.float32)           # [1, bk]

    s = jax.lax.dot_general(q, keys, (((1,), (1,)), ((), ()))) * scale
    s = s + bias                                       # [H, bk]

    m_prev, l_prev = m_ref[...], l_ref[...]            # [H, 1]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)                             # [H, bk]
    corr = jnp.exp(m_prev - m_new)                     # [H, 1]
    l_new = l_prev * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, vals, (((1,), (0,)), ((), ())))             # [H, dv]
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(i == n_blocks - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] /
                        jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_k", "interpret"))
def sparse_attn(q: jnp.ndarray, keys: jnp.ndarray, vals: jnp.ndarray,
                bias: jnp.ndarray, *, scale: float, block_k: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """q: [H, dq]; keys: [k, dq]; vals: [k, dv]; bias: [k] f32 (0 / -inf)
    -> out [H, dv] f32."""
    H, dq = q.shape
    k, dv = vals.shape
    block_k = min(block_k, k)
    assert k % block_k == 0, (k, block_k)
    n_blocks = k // block_k
    kern = functools.partial(_sparse_attn_kernel, scale=scale,
                             n_blocks=n_blocks)
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((H, dq), lambda i: (0, 0)),
            pl.BlockSpec((block_k, dq), lambda i: (i, 0)),
            pl.BlockSpec((block_k, dv), lambda i: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((H, dv), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, dv), jnp.float32),
        scratch_shapes=[
            # pltpu.VMEM is the canonical scratch constructor and exists
            # across jax versions (MemorySpace.VMEM is 0.5+-only)
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, keys, vals, bias.reshape(1, k))
