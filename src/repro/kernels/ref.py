"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def gather_kv_ref(kv: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """kv: [S, d]; idx: [k] int32 -> [k, d]."""
    return jnp.take(kv, idx, axis=0)


def indexer_scores_ref(q: jnp.ndarray, w: jnp.ndarray, keys: jnp.ndarray
                       ) -> jnp.ndarray:
    """Lightning indexer: q [H, di], w [H], keys [S, di] -> scores [S].

    I[s] = sum_h w[h] * ReLU(q[h] . k[s]) / sqrt(di)
    """
    di = q.shape[-1]
    logits = jax.nn.relu(keys.astype(jnp.float32)
                         @ q.astype(jnp.float32).T) / np.sqrt(di)  # [S, H]
    return logits @ w.astype(jnp.float32)


def sparse_mla_attn_ref(q_lat: jnp.ndarray, q_pe: jnp.ndarray,
                        entries: jnp.ndarray, valid: jnp.ndarray,
                        dc: int, scale: float) -> jnp.ndarray:
    """Absorbed-MLA attention over fetched latent entries.

    q_lat: [H, dc]; q_pe: [H, dr]; entries: [k, dc+dr]; valid: [k]
    -> out_lat [H, dc].
    """
    c = entries[:, :dc].astype(jnp.float32)
    k_pe = entries[:, dc:].astype(jnp.float32)
    s = (q_lat.astype(jnp.float32) @ c.T
         + q_pe.astype(jnp.float32) @ k_pe.T) * scale      # [H, k]
    s = jnp.where(valid[None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return p @ c                                           # [H, dc]


def sparse_gqa_attn_ref(q: jnp.ndarray, entries: jnp.ndarray,
                        valid: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """GQA attention over fetched entries.

    q: [H, hd]; entries: [k, 2*n_kv*hd] (stacked k,v); valid: [k]
    -> out [H, hd].
    """
    H, hd = q.shape
    k = entries.shape[0]
    kv = entries.reshape(k, 2, n_kv, hd)
    keys, vals = kv[:, 0].astype(jnp.float32), kv[:, 1].astype(jnp.float32)
    n_rep = H // n_kv
    qf = q.astype(jnp.float32).reshape(n_kv, n_rep, hd) / np.sqrt(hd)
    s = jnp.einsum("grd,kgd->grk", qf, keys)
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("grk,kgd->grd", p, vals)
    return out.reshape(H, hd)


def scatter_kv_ref(pool: jnp.ndarray, entries: jnp.ndarray,
                   idx: jnp.ndarray) -> jnp.ndarray:
    """pool: [S, d]; entries: [k, d]; idx: [k] -> pool with rows written."""
    return pool.at[idx].set(entries.astype(pool.dtype))
