"""Pallas TPU kernel: scalar-prefetch sparse KV gather (SAC read path).

The CXL analogue on TPU (DESIGN.md §2): instead of warp-coalesced
``ld.global.b64`` loads, the top-k indices are scalar-prefetched into SMEM
*before* the kernel body runs, and drive the ``BlockSpec.index_map`` — so
the TPU DMA engine streams exactly the requested KV rows HBM->VMEM, one
descriptor per row, with no intermediate staging.  This is the TPU-native
form of a fine-grained, memory-semantic gather.

Grid: one step per gathered row.  kv blocks are (1, d) — the row picked by
``idx[i]``; out blocks are (1, d) at row ``i``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, kv_ref, out_ref):
    # the DMA engine has already landed kv[idx[i]] in VMEM; copy to out
    out_ref[...] = kv_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_kv(kv: jnp.ndarray, idx: jnp.ndarray, *, interpret: bool = True
              ) -> jnp.ndarray:
    """kv: [S, d] (pool shard, HBM); idx: [k] int32 -> [k, d].

    Out-of-range indices must be pre-clamped by the caller (the pooled
    fetch masks them after the gather).
    """
    k = idx.shape[0]
    d = kv.shape[-1]
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0))],
            out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((k, d), kv.dtype),
        interpret=interpret,
    )(idx, kv)


def _gather_block_kernel(idx_ref, kv_ref, out_ref):
    out_ref[...] = kv_ref[...]


@functools.partial(jax.jit, static_argnames=("page", "interpret"))
def gather_kv_pages(kv: jnp.ndarray, page_idx: jnp.ndarray, *, page: int = 16,
                    interpret: bool = True) -> jnp.ndarray:
    """Page-granular gather: fetch whole pages of ``page`` consecutive rows.

    kv: [S, d] with S % page == 0; page_idx: [n_pages] page numbers
    -> [n_pages * page, d].  Fewer, larger DMA descriptors — the knob the
    paper's ``page_size`` controls.
    """
    n = page_idx.shape[0]
    d = kv.shape[-1]
    return pl.pallas_call(
        _gather_block_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[pl.BlockSpec((page, d),
                                   lambda i, idx_ref: (idx_ref[i], 0))],
            out_specs=pl.BlockSpec((page, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n * page, d), kv.dtype),
        interpret=interpret,
    )(page_idx, kv)
