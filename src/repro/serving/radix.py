"""Radix prefix cache over pool pages (paper §A.3: custom Radix Cache
integration within HiSparse; KV fully offloaded to the pool backend).

Token sequences are interned in a radix tree whose edges carry token-id
chunks; every *paged* node maps a page-aligned prefix to the pool pages
(real :class:`~repro.core.metadata.PoolAllocator` ids) that back it.
Lookup returns the longest cached prefix so prefill can skip
recomputation of the matched tokens and the fabric write of the matched
pages (Round-2 "cache hit" scenario = full hit) — reuse is only valid on
the device the pages live on, which is what the ``radix_affinity``
placement policy (core/placement.py) trades against link pressure.

Lifecycle contract (the PR 5 correctness property, tests/test_radix.py):

  - ``insert`` registers a request's **actual** pool pages and reports
    whether it took them (an identical prefix already cached keeps the
    first copy; the caller then must NOT hand those pages over);
  - ``pin``/``release`` refcount a matched path for a request's
    lifetime; **eviction never drops a pinned prefix**, and an edge
    split inherits the refcount so pin/release walks stay balanced
    across structural changes;
  - ``evict_lru`` drops unpinned LRU leaves and *returns* the freed
    (device, pages) so the owner (``SACSystem``) can return them to the
    allocator — and it re-merges/cleans the page-less split nodes left
    behind, so the node count stays bounded by the live paths;
  - ``invalidate_pages`` purges every node whose backing pages the pool
    just freed — the index never returns a (device, pages) tuple the
    ``PoolAllocator`` considers free, under ANY interleaving of
    admit/finish/evict (hypothesis-tested).

A node's ``pages`` list is cumulative: it covers the node's FULL prefix
from the root (each request writes its own copy of the whole prefix, so
one allocation backs one node — page ids are never shared between
nodes).  ``match`` therefore reports the deepest paged node's (device,
pages) as the reusable unit, with the match length rounded DOWN to page
granularity — a raw edge walk can overshoot into page-less split nodes,
and crediting those tokens would count reuse no page actually backs.

PR 6 adds hot-prefix REPLICATION: a paged node can carry full copies of
its page list on other devices (``add_replica``), all registered in the
same owner map and reported by ``match`` through ``MatchResult.copies``
so placement can pick the cheapest copy.  Replicas are second-class on
the way out: per-device eviction drops them before primaries, a primary
whose pages are evicted/invalidated promotes its hottest surviving
replica, and only the loss of the LAST copy kills the node's payload —
a cached prefix always retains one primary.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class _Node:
    node_id: int
    edge: Tuple[int, ...] = ()                    # tokens on the edge in
    pages: List[int] = dataclasses.field(default_factory=list)
    device: int = -1
    children: Dict[int, "_Node"] = dataclasses.field(default_factory=dict)
    parent: Optional["_Node"] = None
    refs: int = 0
    last_use: float = 0.0
    # PR 6 hot-prefix replication: additional full copies of this node's
    # cumulative page list on OTHER devices (device -> page list), each
    # with its own LRU stamp so replica eviction is per-copy.  The
    # (pages, device) pair above stays the PRIMARY copy — a paged node
    # always retains one primary (eviction promotes a replica first).
    replicas: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    replica_use: Dict[int, float] = dataclasses.field(default_factory=dict)

    def depth_tokens(self) -> int:
        n, d = self, 0
        while n is not None:
            d += len(n.edge)
            n = n.parent
        return d


@dataclasses.dataclass(frozen=True)
class MatchResult:
    """One prefix lookup: raw walk length vs the page-backed reuse."""

    tokens: int                 # raw matched tokens (token-granular walk,
                                # may end mid-edge)
    paged_tokens: int           # page-granular reusable prefix length
    device: int                 # device of the backing node (-1: none)
    pages: List[int]            # backing pages covering the matched
                                # prefix (a leading slice of the backing
                                # node's cumulative page list)
    pin_tokens: Tuple[int, ...] = ()
                                # the BACKING node's full token prefix —
                                # what a caller must pin to keep the
                                # reused pages alive (the backing node
                                # may sit deeper than the match point)
    copies: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
                                # EVERY device holding a copy of the
                                # backing node -> that copy's FULL page
                                # list (primary + replicas); ``device``/
                                # ``pages`` above remain the primary's
                                # matched slice for back-compat

    @property
    def hit(self) -> bool:
        return self.paged_tokens > 0


class RadixIndex:
    """page_size-granular radix tree: prefix tokens -> (device, pages)."""

    def __init__(self, page_size: int = 16):
        self.page_size = page_size
        self.root = _Node(0)
        self._ids = itertools.count(1)
        self._clock = itertools.count(1)
        # (device, page) -> the one node whose pages list contains it
        # (page ids come from per-request allocations, so no sharing)
        self._page_owner: Dict[Tuple[int, int], _Node] = {}

    # -- lookup ---------------------------------------------------------------
    def _walk(self, tokens: Sequence[int]) -> Tuple[int, List[_Node]]:
        """Longest whole-edge walk; returns (tokens matched, path nodes)."""
        node = self.root
        i = 0
        path: List[_Node] = []
        toks = tuple(tokens)
        while True:
            nxt = node.children.get(toks[i]) if i < len(toks) else None
            if nxt is None:
                break
            el = len(nxt.edge)
            if toks[i:i + el] != nxt.edge:
                break
            i += el
            node = nxt
            node.last_use = next(self._clock)
            path.append(node)
        return i, path

    def _prefix_tokens(self, node: _Node) -> Tuple[int, ...]:
        parts = []
        while node is not None and node is not self.root:
            parts.append(node.edge)
            node = node.parent
        return tuple(t for edge in reversed(parts) for t in edge)

    @staticmethod
    def _best_paged(sub_root: _Node) -> Optional[_Node]:
        """Hottest paged node in a subtree (every node below the match
        point shares the matched prefix, so any of their cumulative page
        lists backs it — prefer the most recently used copy)."""
        best = None
        stack = [sub_root]
        while stack:
            n = stack.pop()
            if n.pages and (best is None or n.last_use > best.last_use):
                best = n
            stack.extend(n.children.values())
        return best

    def match(self, tokens: Sequence[int]) -> MatchResult:
        """Longest cached prefix with its page backing.

        The walk is TOKEN-granular: it descends whole matching edges and
        then extends into the next edge as far as tokens agree (a shared
        prefix that diverges mid-edge — the common case before any split
        exists — still matches).  The page backing comes from the
        hottest paged node at or below the match point: every node in
        that subtree shares the matched prefix, and its cumulative page
        list's leading slice covers it.  ``paged_tokens`` rounds the
        match DOWN to page granularity — reuse is page-granular, and the
        pre-PR 5 accounting credited split-node tokens no page backs.
        ``pin_tokens`` is the backing node's own prefix: pinning it (not
        just the matched tokens) is what keeps the reused pages alive,
        since the backing copy may sit deeper than the match point.
        """
        node = self.root
        i = 0
        toks = tuple(tokens)
        sub_root = self.root
        while True:
            nxt = node.children.get(toks[i]) if i < len(toks) else None
            if nxt is None:
                sub_root = node
                break
            el = len(nxt.edge)
            common = 0
            while (common < el and i + common < len(toks)
                   and nxt.edge[common] == toks[i + common]):
                common += 1
            i += common
            if common < el:
                # diverged (or query exhausted) mid-edge: everything
                # under nxt still shares the first i tokens
                sub_root = nxt
                break
            node = nxt
            node.last_use = next(self._clock)
        if sub_root is self.root:
            return MatchResult(i, 0, -1, [])
        backing = self._best_paged(sub_root)
        if backing is None:
            return MatchResult(i, 0, -1, [])
        paged = (i // self.page_size) * self.page_size
        paged = min(paged, len(backing.pages) * self.page_size)
        if paged <= 0:
            return MatchResult(i, 0, -1, [])
        backing.last_use = next(self._clock)
        copies = {backing.device: list(backing.pages)}
        for dev, pgs in backing.replicas.items():
            copies[dev] = list(pgs)
        return MatchResult(i, paged, backing.device,
                           list(backing.pages[:paged // self.page_size]),
                           self._prefix_tokens(backing), copies)

    def match_prefix(self, tokens: Sequence[int]
                     ) -> Tuple[int, List[Tuple[int, List[int]]]]:
        """Legacy tuple API: (raw tokens matched, [(device, pages), ...]
        along the path).  Prefer :meth:`match` — it reports the
        page-granular reuse the serving layers must account."""
        i, path = self._walk(tokens)
        return i, [(n.device, list(n.pages)) for n in path if n.pages]

    # -- insert ---------------------------------------------------------------
    def insert(self, tokens: Sequence[int], device: int, pages: List[int]
               ) -> int:
        """Register ``tokens`` (page-aligned length) as cached by ``pages``.

        Returns the number of pages the index actually took: ``0`` when
        an identical prefix is already cached (the first copy wins — the
        caller keeps ownership of ``pages``), else ``len(pages)`` (the
        caller must keep those pages allocated until the index gives
        them back through ``evict_lru`` or ``invalidate_pages``).
        """
        toks = tuple(tokens)
        assert len(toks) % self.page_size == 0, "insert page-aligned prefixes"
        if not toks:
            return 0
        node = self.root
        i = 0
        while i < len(toks):
            nxt = node.children.get(toks[i])
            if nxt is None:
                child = _Node(next(self._ids), edge=toks[i:], parent=node)
                node.children[toks[i]] = child
                node = child
                i = len(toks)
                break
            # walk common prefix of edge
            el = len(nxt.edge)
            common = 0
            while (common < el and i + common < len(toks)
                   and nxt.edge[common] == toks[i + common]):
                common += 1
            if common == el:
                node = nxt
                i += el
                continue
            # split edge at `common`; the mid node inherits the refcount
            # so a pin taken before the split still releases balanced
            # (pin/release walk EVERY node on the path)
            mid = _Node(next(self._ids), edge=nxt.edge[:common], parent=node,
                        refs=nxt.refs, last_use=nxt.last_use)
            node.children[toks[i]] = mid
            nxt.edge = nxt.edge[common:]
            nxt.parent = mid
            mid.children[nxt.edge[0]] = nxt
            # pages stay with the deeper node (they cover its full prefix)
            node = mid
            i += common
        node.last_use = next(self._clock)
        if node.pages:
            return 0        # identical prefix already cached: keep it
        node.pages = list(pages)
        node.device = device
        for p in pages:
            assert (device, p) not in self._page_owner, \
                f"page {(device, p)} already backs node " \
                f"{self._page_owner[(device, p)].node_id}"
            self._page_owner[(device, p)] = node
        return len(pages)

    def _find_paged(self, tokens: Sequence[int]) -> Optional[_Node]:
        """The paged node whose full prefix is exactly ``tokens`` (whole-
        edge walk ending on a node boundary), or None."""
        toks = tuple(tokens)
        node = self.root
        i = 0
        while i < len(toks):
            nxt = node.children.get(toks[i])
            if nxt is None or toks[i:i + len(nxt.edge)] != nxt.edge:
                return None
            i += len(nxt.edge)
            node = nxt
        return node if (node is not self.root and node.pages) else None

    def add_replica(self, tokens: Sequence[int], device: int,
                    pages: List[int]) -> int:
        """Register ``pages`` as a full copy of the prefix ``tokens`` on
        another ``device`` (hot-prefix replication, PR 6).

        Returns the pages taken (0 if the prefix is not cached as an
        exact paged node, the device already holds a copy, or the page
        count does not mirror the primary's) — the caller keeps
        ownership on 0, hands it over otherwise (pages come back through
        ``evict_lru`` / ``invalidate_pages`` like primary pages)."""
        node = self._find_paged(tokens)
        if (node is None or node.device == device
                or device in node.replicas
                or len(pages) != len(node.pages)):
            return 0
        for p in pages:
            assert (device, p) not in self._page_owner, \
                f"replica page {(device, p)} already backs node " \
                f"{self._page_owner[(device, p)].node_id}"
        node.replicas[device] = list(pages)
        node.replica_use[device] = next(self._clock)
        for p in pages:
            self._page_owner[(device, p)] = node
        return len(pages)

    # -- pin / release --------------------------------------------------------
    def pin(self, tokens: Sequence[int]) -> None:
        self._walk_refs(tokens, +1)

    def release(self, tokens: Sequence[int]) -> None:
        self._walk_refs(tokens, -1)

    def _walk_refs(self, tokens: Sequence[int], delta: int) -> None:
        node = self.root
        i = 0
        toks = tuple(tokens)
        while i < len(toks):
            nxt = node.children.get(toks[i])
            if nxt is None or toks[i:i + len(nxt.edge)] != nxt.edge:
                break
            nxt.refs = max(0, nxt.refs + delta)
            i += len(nxt.edge)
            node = nxt

    # -- eviction / invalidation ----------------------------------------------
    def _drop_payload(self, node: _Node) -> List[Tuple[int, List[int]]]:
        """Forget a node's ENTIRE page backing — the primary copy and
        every replica (owner-map consistent).  Returns freed
        (device, pages) tuples, one per copy."""
        freed: List[Tuple[int, List[int]]] = []
        for dev in list(node.replicas):
            got = self._drop_replica(node, dev)
            if got is not None:
                freed.append(got)
        if node.pages:
            freed.append((node.device, node.pages))
            for p in node.pages:
                self._page_owner.pop((node.device, p), None)
            node.pages = []
            node.device = -1
        return freed

    def _drop_replica(self, node: _Node, device: int
                      ) -> Optional[Tuple[int, List[int]]]:
        """Forget one replica copy; the primary (and the node) survive."""
        pages = node.replicas.pop(device, None)
        node.replica_use.pop(device, None)
        if pages is None:
            return None
        for p in pages:
            self._page_owner.pop((device, p), None)
        return (device, pages)

    def _promote_replica(self, node: _Node) -> bool:
        """Make the hottest replica the node's primary copy (called when
        the primary's pages are being evicted/invalidated but replicas
        survive — a prefix always retains one primary).  The owner map
        needs no update: replica pages already point at this node."""
        if not node.replicas:
            return False
        dev = max(node.replicas, key=lambda d: node.replica_use.get(d, 0.0))
        node.pages = node.replicas.pop(dev)
        node.device = dev
        node.replica_use.pop(dev, None)
        return True

    def _cleanup(self, node: Optional[_Node]) -> None:
        """Re-merge / remove the structural debris a removal leaves:
        walking up from ``node``, drop page-less childless unpinned
        nodes, and fold a page-less unpinned single-child node into its
        child (edge concat) — the split-node leak of the pre-PR 5
        ``evict_lru``, which kept every dead mid node forever."""
        while node is not None and node is not self.root:
            parent = node.parent
            if not node.pages and node.refs == 0:
                if not node.children:
                    parent.children.pop(node.edge[0], None)
                elif len(node.children) == 1:
                    (child,) = node.children.values()
                    child.edge = node.edge + child.edge
                    child.parent = parent
                    parent.children[child.edge[0]] = child
            node = parent

    def evict_lru(self, n_leaves: int = 1, *, device: Optional[int] = None
                  ) -> List[Tuple[int, List[int]]]:
        """Drop up to n unpinned LRU leaves; returns freed (device, pages).

        A pinned prefix (any node with refs > 0 on its path) is never
        dropped — pins protect ancestors by construction, since a pin
        walk increments every node down the path.

        ``device`` restricts victims to unpinned COPIES on that device —
        a replica, or a primary, on a leaf or an interior node (pool-
        pressure relief must not drain healthy devices' caches; a global
        LRU walk would evict the cluster's coldest prefixes first no
        matter whose budget is blocked).  Replicas evict FIRST (cheapest
        relief: the node keeps its primary and stays matchable) and a
        primary with surviving replicas is only demoted — its pages free
        and the hottest replica is promoted, so a prefix always retains
        one primary.  Without ``device``, any unpinned leaf — including
        page-less debris — qualifies (its replicas go with it), which is
        what collapses the tree on drain.
        """
        freed: List[Tuple[int, List[int]]] = []
        evicted = 0
        while evicted < n_leaves:
            # ONE tree walk per batch (not per victim): collect every
            # candidate, sort LRU-first, evict up to the budget.
            # Evicting one candidate never invalidates another — cleanup
            # only removes/merges page-less refs-0 nodes (never
            # candidates), and a promotion moves a copy from a DIFFERENT
            # device, never another candidate of this batch's device.
            if device is None:
                cands = [(1, n.last_use, n) for n in self._all_nodes()
                         if not n.children and n.refs == 0
                         and n is not self.root]
            else:
                cands = []
                for n in self._all_nodes():
                    if n is self.root or n.refs != 0:
                        continue
                    if device in n.replicas:
                        cands.append((0, n.replica_use.get(device, 0.0), n))
                    elif n.pages and n.device == device:
                        cands.append((1, n.last_use, n))
            if not cands:
                break
            cands.sort(key=lambda c: (c[0], c[1]))
            for is_primary, _, victim in cands[:n_leaves - evicted]:
                if device is not None and not is_primary:
                    got = self._drop_replica(victim, device)
                    if got is not None:
                        freed.append(got)
                elif device is not None and victim.replicas:
                    # demote the primary: free its pages, promote the
                    # hottest replica — node structure untouched
                    freed.append((victim.device, victim.pages))
                    for p in victim.pages:
                        self._page_owner.pop((victim.device, p), None)
                    victim.pages = []
                    victim.device = -1
                    self._promote_replica(victim)
                else:
                    freed.extend(self._drop_payload(victim))
                    if not victim.children:
                        parent = victim.parent
                        if parent is not None:
                            parent.children.pop(victim.edge[0], None)
                        self._cleanup(parent)
                    else:
                        self._cleanup(victim)
                evicted += 1
            if device is None and evicted < n_leaves:
                continue    # leaf eviction exposes new leaves: re-walk
            break
        return freed

    def invalidate_pages(self, device: int, pages: Iterable[int]
                         ) -> int:
        """Purge every node backed by any of these (freed) pool pages.

        Called by the pool owner the moment it frees pages a request
        left behind, so the index can never hand out a (device, pages)
        tuple the allocator considers free.  Invalidation is per COPY:
        a freed replica page drops only that replica (the primary and
        the node survive); a freed primary page drops the primary's
        whole pages list (partially freed prefixes are unreadable) and
        promotes a surviving replica if any — only a node whose LAST
        copy is invalidated loses its payload and gets the structural
        cleanup.  Returns nodes that lost at least one copy.
        """
        victims: Dict[int, list] = {}   # id(node) -> [node, primary?, devs]
        for p in pages:
            node = self._page_owner.get((device, p))
            if node is None:
                continue
            ent = victims.setdefault(id(node), [node, False, set()])
            if node.device == device:
                ent[1] = True
            elif device in node.replicas:
                ent[2].add(device)
        for node, primary_hit, rep_devs in victims.values():
            for d in rep_devs:
                self._drop_replica(node, d)
            if not primary_hit:
                continue
            for p in node.pages:
                self._page_owner.pop((node.device, p), None)
            node.pages = []
            node.device = -1
            if self._promote_replica(node):
                continue
            if not node.children and node.refs == 0:
                if node.parent is not None:
                    node.parent.children.pop(node.edge[0], None)
                self._cleanup(node.parent)
            else:
                self._cleanup(node)
        return len(victims)

    # -- introspection --------------------------------------------------------
    def _all_nodes(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def owns(self, device: int, page: int) -> bool:
        """True iff some node's payload currently references this page."""
        return (device, page) in self._page_owner

    def replica_pages(self, device: Optional[int] = None) -> int:
        """Pages held by replica (non-primary) copies, one device or all."""
        return sum(len(p) for n in self._all_nodes()
                   for d, p in n.replicas.items()
                   if device is None or d == device)

    def n_nodes(self) -> int:
        """Node count excluding the root (boundedness invariant)."""
        return sum(1 for _ in self._all_nodes()) - 1

    def n_paged_nodes(self) -> int:
        return sum(1 for n in self._all_nodes() if n.pages)

    def cached_pages(self) -> Dict[Tuple[int, int], "_Node"]:
        """Live (device, page) -> node map (the owner index)."""
        return dict(self._page_owner)

    def n_cached_tokens(self) -> int:
        return sum(len(n.pages) * self.page_size for n in self._all_nodes())
