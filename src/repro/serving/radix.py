"""Radix prefix cache over pool pages (paper §A.3: custom Radix Cache
integration within HiSparse; KV fully offloaded to the pool backend).

Token sequences are interned in a radix tree whose edges carry token-id
chunks; every node maps a page-aligned prefix to pool pages.  Lookup
returns the longest cached prefix (page granular) so prefill can skip
recomputation (Round-2 "cache hit" scenario = full hit).  Eviction is
LRU by leaf with reference counting — pages pinned by in-flight requests
are never evicted.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class _Node:
    node_id: int
    edge: Tuple[int, ...] = ()                    # tokens on the edge in
    pages: List[int] = dataclasses.field(default_factory=list)
    device: int = -1
    children: Dict[int, "_Node"] = dataclasses.field(default_factory=dict)
    parent: Optional["_Node"] = None
    refs: int = 0
    last_use: float = 0.0

    def depth_tokens(self) -> int:
        n, d = self, 0
        while n is not None:
            d += len(n.edge)
            n = n.parent
        return d


class RadixIndex:
    """page_size-granular radix tree: prefix tokens -> (device, pages)."""

    def __init__(self, page_size: int = 16):
        self.page_size = page_size
        self.root = _Node(0)
        self._ids = itertools.count(1)
        self._clock = itertools.count(1)

    # -- lookup ---------------------------------------------------------------
    def match_prefix(self, tokens: Sequence[int]
                     ) -> Tuple[int, List[Tuple[int, List[int]]]]:
        """Longest cached page-aligned prefix.

        Returns (n_tokens_matched, [(device, pages), ...] along the path).
        """
        node = self.root
        i = 0
        out: List[Tuple[int, List[int]]] = []
        toks = tuple(tokens)
        while True:
            nxt = node.children.get(toks[i]) if i < len(toks) else None
            if nxt is None:
                break
            el = len(nxt.edge)
            if toks[i:i + el] != nxt.edge:
                break
            i += el
            node = nxt
            node.last_use = next(self._clock)
            if node.pages:
                out.append((node.device, node.pages))
        return i, out

    # -- insert ---------------------------------------------------------------
    def insert(self, tokens: Sequence[int], device: int, pages: List[int]
               ) -> None:
        """Register ``tokens`` (page-aligned length) as cached with pages."""
        toks = tuple(tokens)
        assert len(toks) % self.page_size == 0, "insert page-aligned prefixes"
        node = self.root
        i = 0
        while i < len(toks):
            nxt = node.children.get(toks[i])
            if nxt is None:
                child = _Node(next(self._ids), edge=toks[i:], parent=node)
                node.children[toks[i]] = child
                node = child
                i = len(toks)
                break
            # walk common prefix of edge
            el = len(nxt.edge)
            common = 0
            while (common < el and i + common < len(toks)
                   and nxt.edge[common] == toks[i + common]):
                common += 1
            if common == el:
                node = nxt
                i += el
                continue
            # split edge at `common`
            mid = _Node(next(self._ids), edge=nxt.edge[:common], parent=node)
            node.children[toks[i]] = mid
            nxt.edge = nxt.edge[common:]
            nxt.parent = mid
            mid.children[nxt.edge[0]] = nxt
            # move pages proportionally? pages stay with the deeper node
            node = mid
            i += common
        node.pages = list(pages)
        node.device = device
        node.last_use = next(self._clock)

    # -- pin / release ------------------------------------------------------------
    def pin(self, tokens: Sequence[int]) -> None:
        self._walk_refs(tokens, +1)

    def release(self, tokens: Sequence[int]) -> None:
        self._walk_refs(tokens, -1)

    def _walk_refs(self, tokens: Sequence[int], delta: int) -> None:
        node = self.root
        i = 0
        toks = tuple(tokens)
        while i < len(toks):
            nxt = node.children.get(toks[i])
            if nxt is None or toks[i:i + len(nxt.edge)] != nxt.edge:
                break
            nxt.refs = max(0, nxt.refs + delta)
            i += len(nxt.edge)
            node = nxt

    # -- eviction -------------------------------------------------------------------
    def evict_lru(self, n_leaves: int = 1) -> List[Tuple[int, List[int]]]:
        """Drop up to n unpinned LRU leaves; returns freed (device, pages)."""
        freed: List[Tuple[int, List[int]]] = []
        for _ in range(n_leaves):
            leaves = [n for n in self._all_nodes()
                      if not n.children and n.refs == 0 and n is not self.root]
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_use)
            if victim.pages:
                freed.append((victim.device, victim.pages))
            parent = victim.parent
            if parent is not None:
                parent.children.pop(victim.edge[0], None)
        return freed

    def _all_nodes(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def n_cached_tokens(self) -> int:
        return sum(len(n.pages) * self.page_size for n in self._all_nodes())
