"""Event-driven cluster simulator for disaggregated sparse-attention serving.

Reproduces the paper's evaluation (Figs 9-14) on the calibrated fabric
models of core/transfer.py.  One simulated server = ``n_lanes`` DP-attention
decode lanes (paper: 8xH20, TP8 + DP-attention 8) + a prefill stage +
a disaggregated pool backend.

Backend semantics (the crux of the paper):

  - **cxl** (SAC): no *full* prefetch.  Every decode step, each request
    fetches its per-layer top-k *misses* straight from the pool; per-
    pool-device links serialize their demand (interleaving spreads
    requests).  ``SimConfig.prefetch_width`` adds the fetch pipeline's
    *speculative* per-step prefetch (serving/prefetch.py) and the
    overlap knobs split fabric time into issued vs exposed seconds.
  - **rdma**: full-prefetch.  A request only becomes decodable after its
    ENTIRE prefix KV crosses the NIC (FIFO, shared aggregate bandwidth) —
    the transmission bottleneck (P1); resident KV consumes local DRAM —
    the memory wall (P2).  During decode, swap-in traffic contends with
    ongoing prefetch traffic on the PCIe bus (paper §5.1: 1.8x TBT).
  - **dram**: non-disaggregated upper bound — pool in local DRAM.
  - **hbm**: GPU-only baseline — zero fetch cost but KV capacity caps the
    resident batch (fig 12 plateau).

The decode-step cost model:
  t_step = t_weights + t_batch_compute + max(0, t_fetch - overlap * t_weights)
  t_fetch = max over pool devices of (sum of that device's miss bytes / bw)

The HiSparse hot-buffer hit model: consecutive-step top-k sets overlap
heavily; a buffer of ``buf`` entries (per layer per request) retains
``h = rho(ctx) * buf / (buf + topk)`` of each step's top-k, where rho
decays slowly with context (score drift grows with more candidates).
``hit_rate`` is evaluated per request on its OWN context length, so a
mixed-length trace charges each request its own miss traffic.  The model
is calibrated against the real in-graph HiSparse buffer
(core/hisparse.py) two ways: directly in tests/test_hisparse.py, and
against the serving engine's *measured* hit rate (the engine decodes
with the real buffer wired into its jitted step) in
tests/test_engine_buffer.py.

Shared substrate: placement decisions come from core/placement.py (via
the embedded Scheduler) and per-device fetch demand is accumulated in a
core/traffic.py ``FabricAccountant`` — the same schema the real engine
reports, so simulator and engine traffic numbers are directly
comparable.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.fabric import FabricTopology
from repro.core.traffic import FabricAccountant
from repro.core.transfer import PipelineModel, QOS_SPECULATIVE
from repro.serving.arbiter import (ArbiterConfig, BudgetArbiter,
                                   DemandTracker, LayerSizer,
                                   resize_allocation_width)
from repro.serving.policy import (LocalityBonus, PrefillSchedule,
                                  PressureFeed, ReplicationPolicy,
                                  WarmupPressureSeed, make_admission)
from repro.serving.prefetch import analytic_prefetch, analytic_warmup
from repro.serving.request import Request, summarize
from repro.serving.scheduler import Scheduler, SchedulerConfig

REARRANGE_BW = 10e9       # page-first -> layer-first re-layout engine (P1)


# ---------------------------------------------------------------------------
# profiles
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Decode/prefill cost constants for one served model."""
    name: str
    n_attn_layers: int
    topk: int
    entry_bytes: int
    weights_bytes_per_gpu: float      # resident weights read per step
    hbm_bw_Bps: float = 4.0e12        # H20
    flops_per_gpu: float = 148e12     # H20 bf16 dense
    flops_eff: float = 0.45
    active_params: float = 37e9       # per-token FLOPs = 2 * this
    n_lanes: int = 8                  # DP-attention width

    @property
    def base_step_s(self) -> float:
        return self.weights_bytes_per_gpu / self.hbm_bw_Bps

    def per_token_compute_s(self) -> float:
        """Marginal decode compute per token across the whole server
        (MoE/FFN is TP over all GPUs; attention DP over lanes)."""
        flops = 2 * self.active_params \
            + 2 * self.n_attn_layers * self.topk * self.entry_bytes  # attn
        return flops / (self.n_lanes * self.flops_per_gpu * self.flops_eff)

    def prefill_s(self, ctx: int) -> float:
        """Compute-bound prefill of a ctx-token prompt on one lane group."""
        flops = 2 * self.active_params * ctx \
            + self.n_attn_layers * self.topk * ctx * 600  # indexer+sparse attn
        return flops / (self.n_lanes * self.flops_per_gpu * self.flops_eff)

    def kv_bytes_per_token(self) -> float:
        return self.n_attn_layers * self.entry_bytes


def profile_from_config(cfg: ModelConfig, **kw) -> ModelProfile:
    entry = cfg.kv_bytes_per_token_layer
    quant = 0.5 if cfg.name.startswith("deepseek") else 2.0  # AWQ-4bit paper
    weights = cfg.param_count() * quant / kw.pop("n_gpus", 8)
    return ModelProfile(
        name=cfg.name, n_attn_layers=max(cfg.n_attn_layers, 1),
        topk=cfg.sac.topk, entry_bytes=entry,
        weights_bytes_per_gpu=weights,
        active_params=cfg.active_param_count(), **kw)


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    name: str                          # cxl | rdma | dram | hbm
    fetch_bw_Bps: float                # per pool device (cxl) / bus (dram)
    n_pool_devices: int = 2
    interleave: bool = True
    prefetch: bool = False             # full-prefetch before decode (rdma)
    nic_bw_Bps: float = 100e9          # pool-node egress bandwidth
    pcie_contention: float = 0.45      # swap-bw fraction lost during prefetch
    local_dram_bytes: float = 2e12
    hbm_kv_bytes: float = float("inf")
    fetch_base_s: float = 1e-6         # per-step fabric setup
    layer_latency_s: float = 10e-6     # per-layer swap-in launch + fabric
                                       # round-trip (CXL pays the switch hop)
    admit_overhead_s: float = 0.08     # scheduling + metadata ops per request
                                       # (CXL: load/store metadata §4.3.1;
                                       #  RDMA: RPC metadata service)


def default_backends(**overrides) -> Dict[str, BackendProfile]:
    """Paper §A.2 hardware: 2x CXL Type-3 devices behind an XConn switch
    (PCIe5 x8 links), loopback RNIC pool (100 Gb/s per NIC — the pool
    node's egress is the shared bottleneck), 2 TB local DRAM, 8x H20."""
    b = {
        "cxl": BackendProfile("cxl", fetch_bw_Bps=32e9, n_pool_devices=2,
                              layer_latency_s=25e-6, admit_overhead_s=0.15),
        "rdma": BackendProfile("rdma", fetch_bw_Bps=90e9, n_pool_devices=1,
                               prefetch=True, interleave=False,
                               nic_bw_Bps=14e9, pcie_contention=0.95,
                               layer_latency_s=10e-6, admit_overhead_s=0.25),
        "dram": BackendProfile("dram", fetch_bw_Bps=90e9, n_pool_devices=2,
                               interleave=True, layer_latency_s=12e-6,
                               admit_overhead_s=0.18),
        "hbm": BackendProfile("hbm", fetch_bw_Bps=4e12, n_pool_devices=1,
                              hbm_kv_bytes=45e9 * 8, interleave=False,
                              layer_latency_s=2e-6, admit_overhead_s=0.18),
    }
    for k, v in overrides.items():
        b[k] = v
    return b


# ---------------------------------------------------------------------------
# HiSparse hot-buffer hit model
# ---------------------------------------------------------------------------


def hit_rate(buf: int, topk: int, ctx: int, *, miss_base: float = 0.10,
             ctx_slope: float = 0.35, miss_floor: float = 0.004) -> float:
    """Fraction of a step's top-k served from the device buffer.

    Consecutive decode steps' top-k sets overlap heavily (the salient
    context drifts slowly); a buffer of ``buf`` entries retains roughly
    the last ``buf/topk`` steps' selections, and the recurrence
    probability of an entry last used ``j`` steps ago decays ~1/j — so
    the miss mass beyond the buffer horizon scales ~(topk/buf)^2.
    Longer contexts spread indexer scores over more candidates (more
    churn): misses grow log-linearly in context.  ``miss_floor`` is the
    fresh-context fraction (never-before-selected positions).
    Calibrated against the real HiSparse buffer (core/hisparse.py) in
    tests/test_hisparse.py.
    """
    if buf <= 0:
        return 0.0
    ratio = topk / buf
    miss = (miss_base * ratio * ratio
            * (1.0 + ctx_slope * math.log2(max(ctx, 16384) / 16384))
            + miss_floor)
    return max(0.0, 1.0 - min(miss, 1.0))


def analytic_resize(sizes: List[int], topk: int, ctx_ref: float, *,
                    device_buffer: int) -> List[int]:
    """Analytic twin of the engine's online LayerSizer re-sizing.

    The engine re-apportions the hot tier every ``resize_interval``
    steps from the measured per-layer miss rates of that interval;
    analytically those converge to the miss rates of the *current* sizes
    at the trace's context mix, so the steady state is one LayerSizer
    evaluation at that fixed point.  The hard per-layer cap is the SAME
    ``resize_allocation_width`` formula the engine allocates with.
    """
    total = sum(sizes)
    width = resize_allocation_width(sizes, device_buffer)
    rates = [1.0 - hit_rate(s, topk, int(ctx_ref)) for s in sizes]
    return LayerSizer(len(sizes), total, topk=topk,
                      max_slots=width).sizes(rates)


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimConfig:
    concurrency: int = 64
    device_buffer: int = 6144
    overlap_frac: float = 0.0          # fetch/compute overlap (off: swap-in
                                       # is on the per-layer critical path)
    pipeline_depth: int = 2            # double-buffered fetch queues; the
                                       # hide window is overlap_frac *
                                       # t_comp * (depth - 1) (PipelineModel)
    prefetch_width: int = 0            # speculative entries/layer/step; the
                                       # analytic twin of the engine's
                                       # in-graph prefetch (prefetch.py)
    arbiter: bool = False              # cross-request prefetch budget
                                       # arbitration (serving/arbiter.py):
                                       # per-device demand pressure shrinks
                                       # the granted speculative width
    link_budget_frac: float = 1.0      # arbiter link budget vs hide window
    min_prefetch_width: int = 0        # granted-width floor
    warmup_entries: int = 0            # prefill warm-up seeds per layer —
                                       # models the engine's cold-start
                                       # miss reduction (analytic_warmup)
    warm_precision: float = 0.7        # fraction of warm seeds that land
                                       # in the first step's actual top-k
    layer_buffer_sizes: Optional[List[int]] = None
                                       # per-layer hot-tier sizes (the
                                       # LayerSizer apportioning); None =
                                       # uniform device_buffer per layer
    placement: Optional[str] = None    # scheduler placement policy
                                       # override; "pressure_aware" feeds
                                       # the placer the analytic per-step
                                       # demand seconds (the same signal
                                       # the engine measures)
    page_size: int = 16                # pool page tokens (SACConfig.
                                       # page_size twin): radix reuse
                                       # credit is floored to whole pages
                                       # exactly like the engine's
    radix_affinity: bool = False       # analytic radix prefix cache: a
                                       # request whose prefix_group is
                                       # already cached gets that device
                                       # as a placement affinity hint
                                       # (policy "radix_affinity" unless
                                       # `placement` overrides) and, when
                                       # it lands there, skips the matched
                                       # tokens' prefill compute + pool
                                       # write — the twin of the engine's
                                       # RadixIndex loop (capacity/
                                       # eviction effects stay with the
                                       # engine's real allocator)
    replicate_prefixes: bool = False   # PR 6 hot-prefix replication twin:
                                       # when the corrected pressure on a
                                       # cached prefix's cheapest copy-
                                       # holding link covers the one-time
                                       # copy cost within
                                       # `replicate_horizon` steps, the
                                       # group gains a copy on the least-
                                       # pressured other link (copy
                                       # traffic charged, unkeyed)
    replicate_horizon_steps: int = 64  # payback horizon in decode steps
                                       # (SACConfig.replicate_horizon_
                                       # steps twin; named identically so
                                       # sweeps set the same knob on both
                                       # sides — sacheck twin-coverage)
    dedup_pages: bool = False          # PR 6 page-dedup twin: a same-
                                       # device hit returns the matched
                                       # bytes from the request's booking
                                       # (Scheduler.shrink_booking) — the
                                       # pages are refcount-shared with
                                       # the cache, not privately held
    radix_admission: bool = False      # PR 6 radix-aware admission twin:
                                       # the wait queue orders by paged
                                       # match length (FCFS tie-break)
                                       # via Scheduler.set_reuse_fn
    precision_weighted: bool = False   # arbiter grants split per request
                                       # by analytic prefetch precision
    resize_interval: int = 0           # > 0 models online LayerSizer
                                       # re-sizing: layer sizes evaluated
                                       # at the analytic miss-rate fixed
                                       # point instead of the given prior
    round1: bool = False               # cold cache: prefill + write first
    prefill_concurrency: int = 8
    max_sim_s: float = 1e5
    # --- PR 8: continuous batching + disaggregated prefill ---
    colocated_prefill: bool = False    # charge prefill compute + pool
                                       # write INSIDE the decode loop (the
                                       # engine's monolithic/chunked
                                       # colocated path) instead of
                                       # admitting straight to decode;
                                       # round1=True stays the
                                       # disaggregated twin (separate
                                       # prefill lanes + handoff)
    prefill_chunk_tokens: int = 0      # > 0 with colocated_prefill: each
                                       # pending prompt advances one
                                       # bounded chunk per decode step
                                       # (0 = monolithic, the whole
                                       # prompt in one stall)
    slo_ttft_s: float = 0.0            # SLO targets forwarded to
    slo_tbt_s: float = 0.0             # summarize() attainment fractions
    # --- PR 10: shared admission policy (SACConfig twins) ---
    admission: Optional[str] = None    # queue-ordering policy: None keeps
                                       # the legacy mapping (radix when
                                       # radix_admission is on, else
                                       # fcfs); "fcfs" | "radix" | "edf"
                                       # (EDF deadline = arrival_s +
                                       # slo_ttft_s)
    shed_queue_depth: int = 0          # > 0 (EDF only): drop the arrived
                                       # backlog beyond this many
                                       # earliest-deadline waiting
                                       # requests (never dispatched)
    # --- PR 7: CXL fabric topology (core/fabric.py) ---
    topology: Optional[str] = None     # fabric spec ("tree:NxS", "multi_
                                       # switch:NxS", "mesh:NxP", ...);
                                       # None = flat star — one dedicated
                                       # host port per device, bit-
                                       # identical to the pre-PR 7 flat
                                       # per-device accounting.  Timing
                                       # always honors the topology: the
                                       # step's fetch time is the max
                                       # per-SEGMENT drain time (a shared
                                       # trunk serializes the traffic of
                                       # every device behind it)
    segment_aware: bool = True         # control plane (placer pressure,
                                       # DemandTracker, arbiter budgets)
                                       # reads per-SEGMENT bottleneck
                                       # pressure along each path.  False
                                       # = segment-BLIND baseline: timing
                                       # still pays the topology but the
                                       # control loop only sees flat
                                       # per-device endpoint demand — the
                                       # A/B cell of benchmarks/
                                       # fabric_sweep.py
    warmup_pressure_seed: bool = False # PR 7 satellite (engine twin):
                                       # seed the placement pressure feed
                                       # from BOOKED prefill-write demand
                                       # during the window before the
                                       # FIRST decode step only
    replica_reads: bool = False        # PR 7 satellite (engine twin):
                                       # re-pick the least-bottleneck-
                                       # pressured replica of a cached
                                       # prefix every step; the matched
                                       # fraction of the request's misses
                                       # follows the read device
    replicate_horizon: dataclasses.InitVar[Optional[int]] = None
                                       # deprecated pre-PR 9 spelling of
                                       # replicate_horizon_steps, accepted
                                       # at construction only

    def __post_init__(self, replicate_horizon: Optional[int]) -> None:
        if replicate_horizon is not None:
            self.replicate_horizon_steps = int(replicate_horizon)


class _Prefetch:
    """FIFO bulk-transfer queue over a shared link (the RDMA NIC)."""

    def __init__(self, bw_Bps: float):
        self.bw = bw_Bps
        self.queue: deque = deque()    # (request_id, bytes_left)
        self.inflight_bytes = 0.0

    def enqueue(self, rid: int, n_bytes: float):
        self.queue.append([rid, n_bytes])
        self.inflight_bytes += n_bytes

    def advance(self, dt: float) -> List[int]:
        """Progress by dt seconds; return completed request ids."""
        budget = self.bw * dt
        done = []
        while self.queue and budget > 0:
            head = self.queue[0]
            take = min(head[1], budget)
            head[1] -= take
            budget -= take
            self.inflight_bytes -= take
            if head[1] <= 1e-6:
                done.append(head[0])
                self.queue.popleft()
        return done

    def busy(self) -> bool:
        return bool(self.queue)

    def eta_next(self) -> float:
        if not self.queue:
            return float("inf")
        return self.queue[0][1] / self.bw


def simulate(reqs: List[Request], model: ModelProfile,
             backend: BackendProfile, sim: SimConfig) -> Dict[str, float]:
    """Run the trace to completion; returns summarize() metrics."""
    # deep-copy request records so traces can be reused across backends
    reqs = [dataclasses.replace(r) for r in reqs]
    # any PR 6 mechanism implies the radix prefix cache exists
    use_radix = bool(sim.radix_affinity or sim.replicate_prefixes
                     or sim.dedup_pages or sim.radix_admission)
    # PR 7: the switch fabric.  ``topo`` always shapes TIMING (per-segment
    # drain); ``ctl_topo`` additionally shapes the CONTROL PLANE (pressure
    # feed, tracker, arbiter budgets) unless segment_aware is off — the
    # segment-blind A/B baseline of benchmarks/fabric_sweep.py.
    topo = FabricTopology.from_spec(sim.topology, backend.n_pool_devices)
    ctl_topo = topo if sim.segment_aware else None
    n_slots = ctl_topo.n_segments if ctl_topo is not None \
        else backend.n_pool_devices
    sched = Scheduler(SchedulerConfig(
        concurrency=sim.concurrency,
        n_pool_devices=backend.n_pool_devices,
        interleave=backend.interleave,
        placement=sim.placement or ("radix_affinity" if use_radix
                                    else None),
        pool_device_bytes=backend.local_dram_bytes / backend.n_pool_devices
        if backend.name != "hbm" else float("inf"),
        local_dram_bytes=(backend.local_dram_bytes if backend.prefetch
                          else float("inf")),
        hbm_kv_bytes=backend.hbm_kv_bytes,
        bytes_per_token=model.kv_bytes_per_token(),
        topology=ctl_topo,
    ))
    prefetch = _Prefetch(backend.nic_bw_Bps)
    rearrange = _Prefetch(REARRANGE_BW)
    t = 0.0
    arrivals = deque(sorted(reqs, key=lambda r: r.arrival_s))
    waiting_prefetch: Dict[int, Request] = {}
    decoding: Dict[int, Request] = {}
    prefill_q: deque = deque()
    prefill_done: List[Tuple[float, Request]] = []
    prefill_busy_until = [0.0] * max(sim.prefill_concurrency, 1)
    # trunk write serialization (PR 7): concurrent prefill pool-writes
    # whose routes cross the same multi-device segment serialize on it
    # (a switch trunk carries one device-link's worth of upstream
    # bandwidth).  Single-device segments keep the independent-lane
    # model, so the flat star — no shared segments — is bit-identical
    # to the pre-fabric behavior.
    seg_write_busy = [0.0] * topo.n_segments
    n_done = 0
    acct = FabricAccountant(n_devices=backend.n_pool_devices,
                            topology=topo)

    # per-request miss traffic: each request's hot-buffer hit rate depends
    # on its OWN context length (mixed-length traces are the norm).
    # Speculative prefetch (fetch pipeline) lifts the hit rate and issues
    # its own fabric traffic — the analytic twin of the engine's in-graph
    # speculation (serving/prefetch.py).
    pipeline = PipelineModel(depth=sim.pipeline_depth,
                             overlap_frac=sim.overlap_frac)
    step_topk = model.n_attn_layers * model.topk
    if sim.layer_buffer_sizes:
        # per-layer hot-tier sizing (serving/arbiter.py LayerSizer): the
        # request's steady hit rate is the mean of per-layer hit rates at
        # each layer's own capacity
        sizes = list(sim.layer_buffer_sizes)
        if sim.resize_interval:
            sizes = analytic_resize(sizes, model.topk,
                                    sum(r.context_len for r in reqs)
                                    / max(len(reqs), 1),
                                    device_buffer=sim.device_buffer)
        base_hit = {r.request_id:
                    sum(hit_rate(s, model.topk, r.context_len)
                        for s in sizes) / max(len(sizes), 1)
                    for r in reqs}
    else:
        base_hit = {r.request_id: hit_rate(sim.device_buffer, model.topk,
                                           r.context_len) for r in reqs}

    # steady-state prefetch outcome at a granted width w, cached per
    # (request, w) — the arbiter re-grants every step but the analytic
    # model only depends on (base_hit, w)
    _pf_cache: Dict[Tuple[int, int], Tuple[float, float, float]] = {}

    def pf_at(rid: int, w: int) -> Tuple[float, float, float]:
        key = (rid, w)
        if key not in _pf_cache:
            h2, issued = analytic_prefetch(base_hit[rid], w, model.topk)
            _pf_cache[key] = (h2, issued * model.n_attn_layers,
                              (h2 - base_hit[rid]) * step_topk)
        return _pf_cache[key]

    # the budget arbiter, evaluated analytically on the same grant logic
    # the engine runs (serving/arbiter.py): per-device demand seconds
    # observed last step shape this step's speculative widths
    arb = None
    if sim.arbiter and sim.prefetch_width:
        arb = BudgetArbiter(
            ArbiterConfig(max_width=sim.prefetch_width,
                          min_width=sim.min_prefetch_width,
                          link_budget_frac=sim.link_budget_frac,
                          precision_weighted=sim.precision_weighted),
            entry_s=model.entry_bytes / backend.fetch_bw_Bps,
            n_layers=model.n_attn_layers, pipeline=pipeline,
            topology=ctl_topo)
    # per-link AND per-request analytic demand (the engine's
    # DemandTracker twin): a finishing request's own share leaves its
    # link's pressure signal immediately, not via EMA decay.  With a
    # control-plane topology the tracker runs in SEGMENT space.
    tracker = DemandTracker(backend.n_pool_devices, ctl_topo)

    def _ctl_route(dev: int):
        return ctl_topo.route(dev) if ctl_topo is not None else (dev,)

    # PR 7 satellite (engine twin): before the first decode step the
    # demand feed is silent, so wave-1 admissions herd onto the prefix
    # owner — seed the feed with each admission's BOOKED prefill-write
    # demand until the first real measurement lands.  The window and
    # the feed are the SHARED control-plane objects
    # (serving/policy/seeding.py) the engine wires into its own placer.
    warm_seed = WarmupPressureSeed(bool(sim.warmup_pressure_seed),
                                   n_slots)
    _pressure = PressureFeed(tracker, warm_seed)

    # pressure_aware / radix_affinity placement reads the live analytic
    # demand seconds — the same per-link signal the engine feeds its
    # own placer (per-segment when the control plane is topology-aware;
    # the placer projects it to per-device bottleneck pressure)
    sched.set_pressure_fn(_pressure)
    grant_sum = grant_n = 0
    replica_redirects = [0]

    # analytic radix prefix cache (SimConfig.radix_affinity): group id ->
    # [cached prefix tokens, devices holding a copy].  First writer wins,
    # like the engine's RadixIndex.insert; replication (PR 6) appends
    # copy devices.  Reuse is only real when placement lands the request
    # on A device holding a copy — exactly the locality-vs-pressure
    # decision the radix_affinity policy arbitrates.  ``matched`` carries
    # each admitted request's reused tokens into the prefill model
    # (skipped compute + write).
    radix_cache: Dict[int, list] = {}
    matched: Dict[int, int] = {}
    write_bw = backend.fetch_bw_Bps * backend.n_pool_devices
    page = max(int(sim.page_size), 1)
    replicated_b = [0.0]
    dedup_b = [0.0]

    def _paged(tokens: int) -> int:
        """Reuse is page-granular, exactly as the engine credits it —
        a raw prefix_len would diverge for unaligned prefixes."""
        return (tokens // page) * page

    def _group_hit(r: Request):
        """(paged hit tokens, copy-device list) for ``r``'s group, or
        None when nothing usable is cached."""
        if not use_radix or r.prefix_group is None:
            return None
        cached = radix_cache.get(r.prefix_group)
        if cached is None:
            return None
        plen = _paged(min(cached[0], r.prefix_len))
        if plen <= 0:
            return None
        return plen, cached[1]

    # the locality-bonus FORMULA is the shared policy object
    # (serving/policy/locality.py) bound to the simulator's analytic
    # costs — the engine binds the same class to its fabric/profile
    _locality = LocalityBonus(
        prefill_s=model.prefill_s,
        write_s=lambda n: n * model.kv_bytes_per_token() / write_bw)
    # replication trigger twin: pick + fire/hold are the shared
    # ReplicationPolicy (serving/policy/replication.py)
    _repl = ReplicationPolicy(
        horizon_steps=int(sim.replicate_horizon_steps))

    def _bonus_s(r: Request, plen: int) -> float:
        return _locality(r.context_len, plen)

    def _maybe_replicate(plen: int, devices: list) -> None:
        """Hot-prefix replication twin (the engine's _maybe_replicate):
        fire when the reuse benefit covers the one-time copy cost AND
        the CORRECTED pressure on the cheapest copy-holding link (the
        placer's view including in-flight bookings — same-wave bursts
        count before the demand feed catches up) exceeds the copy cost
        amortized over ``replicate_horizon_steps`` steps, copying to the
        least-pressured copy-free link (never a hotter one) — the
        shared :class:`ReplicationPolicy` decides both.  Copy traffic
        is charged unkeyed (cache-owned; no departure subtracts it) on
        both links."""
        pressure = sched.placer.corrected_pressure()
        others = [d for d in range(backend.n_pool_devices)
                  if d not in devices]
        pick = _repl.pick(pressure, devices, others,
                          sched.placer.bytes_used)
        if pick is None:
            return
        src, dst = pick
        copy_b = plen * model.kv_bytes_per_token()
        copy_cost = copy_b / backend.fetch_bw_Bps
        # benefit proxy: the locality bonus of a full-prefix reuse
        bonus = (model.prefill_s(plen) +
                 copy_b / write_bw)
        if not _repl.should_fire(pressure[src], pressure[dst], bonus,
                                 copy_cost):
            return
        devices.append(dst)
        acct.record_copy_bytes(copy_b)
        acct.charge_seconds(copy_cost)
        tracker.note_transfer(src, copy_cost)
        tracker.note_transfer(dst, copy_cost)
        replicated_b[0] += copy_b

    def _affinity(r: Request):
        hit = _group_hit(r)
        if hit is None:
            return None
        plen, devices = hit
        if sim.replicate_prefixes:
            _maybe_replicate(plen, devices)
        return tuple(devices), _bonus_s(r, plen)

    def _note_radix(r: Request) -> None:
        """Post-placement accounting (the Scheduler admit hook — runs
        after EACH placement, so same-wave requests see earlier ones):
        record the reuse (hits on any copy-holding device) and register
        the first cached copy of a new group."""
        if r.prefix_group is None:
            return
        cached = radix_cache.get(r.prefix_group)
        if cached is not None and r.pool_device in cached[1]:
            hit = _paged(min(cached[0], r.prefix_len))
            if hit > 0:
                matched[r.request_id] = hit
                if sim.dedup_pages:
                    # page-dedup twin: the matched bytes are refcount-
                    # shared with the cache, not privately booked
                    dedup_b[0] += sched.shrink_booking(
                        r, hit * model.kv_bytes_per_token())
        elif cached is None:
            radix_cache[r.prefix_group] = [r.prefix_len, [r.pool_device]]

    def _reuse_score(r: Request) -> float:
        hit = _group_hit(r)
        return float(hit[0]) if hit is not None else 0.0

    def _seed_pressure(r: Request) -> None:
        """Warm-up pressure seeding: charge the admitted request's booked
        prefill-write seconds along its device's path (runs AFTER
        ``_note_radix``, so a dedup/radix hit seeds only the unmatched
        residue — the engine reads the same booked write_back traffic
        via ``TrafficStats.segment_demand_s``)."""
        eff = r.context_len - matched.get(r.request_id, 0)
        s = eff * model.kv_bytes_per_token() / write_bw
        warm_seed.note_admission(_ctl_route(r.pool_device), s)

    def _admit_hook(r: Request) -> None:
        if use_radix:
            _note_radix(r)
        _seed_pressure(r)

    # the shared admission policy (serving/policy/admission.py): the
    # SAME factory + classes the engine constructs, with the analytic
    # prefix-cache lookup bound as the radix scorer
    admission = make_admission(
        sim.admission, radix_admission=bool(sim.radix_admission),
        slo_ttft_s=float(sim.slo_ttft_s),
        shed_queue_depth=int(sim.shed_queue_depth),
        score_fn=_reuse_score, has_radix=use_radix)
    sched.set_admission_policy(admission)
    if use_radix:
        sched.set_affinity_fn(_affinity)
    if use_radix or sim.warmup_pressure_seed:
        sched.set_admit_fn(_admit_hook)

    # prefill warm-up's cold-start miss reduction: a request's FIRST
    # decode step runs against a cold hot tier, lifted to the modeled
    # warm-up hit rate when warmup_entries seeds it (analytic_warmup —
    # the simulator twin of the engine's prefill warm_lane path)
    cold = {r.request_id for r in reqs}
    cold_hit = analytic_warmup(sim.warmup_entries, model.topk,
                               sim.device_buffer,
                               precision=sim.warm_precision)
    warm_inserts = (min(sim.warmup_entries, sim.device_buffer)
                    * model.n_attn_layers if sim.warmup_entries else 0)
    cold_hits_seen: List[float] = []

    # colocated chunked prefill (PR 8): rid -> [request, tokens left].
    # Each decode-loop iteration advances every pending prompt by one
    # bounded chunk; the chunk's compute + pool-write tail joins the
    # step's duration — the analytic twin of the engine's
    # _advance_chunk_jobs (monolithic = one whole-prompt chunk).
    pending_chunk: Dict[int, list] = {}
    # the shared prefill schedule (serving/policy/prefill.py): round1
    # is the disaggregated twin (separate lanes + handoff), colocated
    # chunking reads the same chunk_take the engine's
    # _advance_chunk_jobs uses
    prefill_schedule = PrefillSchedule.from_knobs(
        bool(sim.round1), int(sim.prefill_chunk_tokens),
        int(sim.prefill_concurrency))
    n_shed = [0]

    def admit_ready(now: float):
        nonlocal n_done
        shed0 = len(sched.shed_log)
        admitted = sched.try_admit(now)
        # shed requests leave the system without decoding: they count
        # toward completion (the open-loop drain must terminate) but
        # never toward summarize(), which only reads finished requests
        n_shed[0] += len(sched.shed_log) - shed0
        n_done += len(sched.shed_log) - shed0
        for r in admitted:
            if sim.round1:
                prefill_q.append(r)
            elif backend.prefetch:
                prefetch.enqueue(
                    r.request_id, r.context_len * model.kv_bytes_per_token())
                waiting_prefetch[r.request_id] = r
            elif sim.colocated_prefill:
                pending_chunk[r.request_id] = [
                    r, r.context_len - matched.get(r.request_id, 0)]
            else:
                decoding[r.request_id] = r

    while n_done < len(reqs) and t < sim.max_sim_s:
        t_iter0 = t         # a decoding request's token gap spans the
                            # whole iteration (chunk stalls included)
        # arrivals
        while arrivals and arrivals[0].arrival_s <= t:
            sched.submit(arrivals.popleft())
        admit_ready(t)

        # prefill stage (round 1): assign queued requests to free lanes
        if sim.round1:
            for i in range(len(prefill_busy_until)):
                if prefill_busy_until[i] <= t and prefill_q:
                    r = prefill_q.popleft()
                    # a radix hit skips the matched prefix's recompute
                    # AND its pool write (the cached copy is device-
                    # local) — the engine's _fill_slots twin
                    eff_ctx = r.context_len - matched.get(r.request_id, 0)
                    dur = model.prefill_s(eff_ctx)
                    # pool write (layer-wise bulk) on the backend fabric,
                    # serialized on any shared trunk along the owning
                    # device's route (flat star: exactly wb / write_bw)
                    wb = eff_ctx * model.kv_bytes_per_token()
                    acct.record_write_bytes(wb)
                    xfer = topo.transfer_seconds(r.pool_device,
                                                 wb / write_bw)
                    trunks = [sg for sg in topo.route(r.pool_device)
                              if sg in topo.shared_segments]
                    if trunks:
                        # a shared trunk drains at its own scaled LINK
                        # rate, not the pool's striped aggregate — the
                        # shared port is the write's bottleneck
                        xfer = max(xfer, max(
                            wb / (backend.fetch_bw_Bps
                                  * max(topo.segments[sg].bandwidth_scale,
                                        1e-12))
                            for sg in trunks))
                        start = max([t] + [seg_write_busy[sg]
                                           for sg in trunks])
                        for sg in trunks:
                            seg_write_busy[sg] = start + xfer
                        dur += (start - t) + xfer
                    else:
                        dur += xfer
                    prefill_busy_until[i] = t + dur
                    r.first_token_s = t + dur      # TTFT = prefill completion
                    r.generated = 1
                    prefill_done.append((t + dur, r))
            for ready, r in list(prefill_done):
                if ready <= t:
                    decoding[r.request_id] = r
                    prefill_done.remove((ready, r))

        # colocated prefill (PR 8): advance every pending prompt ONE
        # chunk; its compute + pool-write tail advances the wall clock
        # before (and instead of stalling inside) the decode step —
        # completed prompts join the batch this same iteration, exactly
        # like the engine splicing at the top of step()
        if pending_chunk:
            t_chunks = 0.0
            for rid in list(pending_chunk):
                r, left = pending_chunk[rid]
                take = prefill_schedule.chunk_take(left)
                t_chunks += model.prefill_s(take)
                if take > 0:
                    wb = take * model.kv_bytes_per_token()
                    acct.record_write_bytes(wb)
                    xfer = topo.transfer_seconds(r.pool_device,
                                                 wb / write_bw)
                    acct.charge_seconds(xfer)
                    t_chunks += xfer
                pending_chunk[rid][1] = left - take
                if pending_chunk[rid][1] <= 0:
                    del pending_chunk[rid]
                    decoding[rid] = r
            t += t_chunks

        if not decoding:
            if pending_chunk:
                # chunked prefills advanced (time moved) but none
                # finished — loop again rather than event-jumping
                continue
            # jump to the next event
            cands = []
            if arrivals:
                cands.append(arrivals[0].arrival_s)
            if prefetch.busy():
                cands.append(t + prefetch.eta_next())
            if rearrange.busy():
                cands.append(t + rearrange.eta_next())
            if sim.round1 and prefill_done:
                cands.append(min(rd for rd, _ in prefill_done))
            if sim.round1 and prefill_q:
                cands.append(min(prefill_busy_until))
            nxt = min(cands, default=t)
            if nxt <= t or nxt == float("inf"):
                break
            for rid in prefetch.advance(nxt - t):
                rearrange.enqueue(
                    rid, waiting_prefetch[rid].context_len
                    * model.kv_bytes_per_token())
            for rid in rearrange.advance(nxt - t):
                decoding[rid] = waiting_prefetch.pop(rid)
            t = nxt
            continue

        # ---- one decode step over the active batch ----
        batch = len(decoding)
        t_comp = model.base_step_s + batch * model.per_token_compute_s()
        # fetch demand per pool device (shared traffic substrate)
        if backend.name == "hbm":
            t_fetch = t_exposed = 0.0
        else:
            # PR 7 replica-aware reads (engine twin): re-pick the least-
            # bottleneck-pressured copy of each request's cached prefix
            # THIS step; the matched fraction of its misses (and its
            # speculative prefetch) reads from that copy, so grants and
            # demand charges follow the read device
            reads: Dict[int, Tuple[int, int, float]] = {}
            replica_on = sim.replica_reads and use_radix
            pres = (list(sched.placer.device_pressure())
                    if replica_on else None)
            # within-step booking: charge each reader's expected step
            # demand onto its chosen devices as reads are assigned —
            # the pressure feed refreshes only BETWEEN steps, so
            # without it every reader of a hot prefix herds onto the
            # same least-pressured copy each step (the copies flip-flop
            # in lockstep and the per-step bottleneck never improves)
            est_s = step_topk * model.entry_bytes / backend.fetch_bw_Bps
            for r in decoding.values():
                own = r.pool_device
                rd, frac = own, 0.0
                hit = matched.get(r.request_id, 0)
                if replica_on and hit > 0 and r.prefix_group is not None:
                    cached = radix_cache.get(r.prefix_group)
                    if cached is not None:
                        copies = sorted(set(cached[1]) | {own})
                        rd = min(copies, key=lambda d: (pres[d], d))
                        if rd != own:
                            frac = min(hit / max(r.context_len, 1), 1.0)
                            replica_redirects[0] += 1
                if pres is not None:
                    pres[rd] += frac * est_s
                    pres[own] += (1.0 - frac) * est_s
                reads[r.request_id] = (own, rd, frac)
            grants = None
            if arb is not None:
                dev_reqs: Dict[int, List[int]] = {}
                precision = None
                if arb.cfg.precision_weighted:
                    # analytic per-request precision: the cumulative
                    # prefetch attribution the accountant tracked (the
                    # same TrafficStats signal the engine feeds)
                    precision = {}
                for r in decoding.values():
                    dev_reqs.setdefault(reads[r.request_id][1],
                                        []).append(r.request_id)
                    if precision is not None:
                        precision[r.request_id] = \
                            acct.stats.request_precision(r.request_id)
                grants = arb.grant(t_comp, tracker.last_demand_s, dev_reqs,
                                   precision=precision)
            # per-SLOT demand-only backlog (segment space when the
            # control plane is topology-aware, device space otherwise) —
            # next step's pressure signal
            demand_ctl = [0.0] * n_slots
            req_miss_b: Dict[int, float] = {}
            for r in decoding.values():
                rid = r.request_id
                w = (grants[rid] if grants is not None
                     else sim.prefetch_width)
                if grants is not None:
                    grant_sum += w
                    grant_n += 1
                was_cold = rid in cold
                if was_cold:
                    # first decode step: cold tier, warm-up seeds only.
                    # With the arbiter on, the warm burst drew from the
                    # same link budget (grant_warmup) at prefill time
                    cold.discard(rid)
                    w_warm = sim.warmup_entries
                    if arb is not None and w_warm:
                        # hide window = the (radix-shortened) prefill
                        # this warm burst rode behind, as in the engine
                        w_warm = arb.grant_warmup(
                            model.prefill_s(
                                r.context_len
                                - matched.get(r.request_id, 0)),
                            tracker.last_demand_s, r.pool_device,
                            min(w_warm, sim.device_buffer))
                    h = (cold_hit if w_warm == sim.warmup_entries
                         else analytic_warmup(w_warm, model.topk,
                                              sim.device_buffer,
                                              precision=sim.warm_precision))
                    cold_hits_seen.append(h)
                    pf_n = float(min(w_warm, sim.device_buffer)
                                 * model.n_attn_layers
                                 if w_warm else 0.0)
                    pf_u = min(h * step_topk, pf_n)
                else:
                    h, pf_n, pf_u = pf_at(rid, w)
                miss_b = step_topk * (1 - h) * model.entry_bytes
                pf_b = pf_n * model.entry_bytes
                own, rd, frac = reads[rid]
                pfx_b = miss_b * frac         # matched-prefix share ->
                                              # the replica read device
                if pfx_b:
                    acct.add_step_demand(rd, pfx_b)
                    for slot in _ctl_route(rd):
                        demand_ctl[slot] += pfx_b
                acct.add_step_demand(own, miss_b - pfx_b)
                for slot in _ctl_route(own):
                    demand_ctl[slot] += miss_b - pfx_b
                if pf_b:
                    # speculation is QoS-classed: at qos_spec_yield
                    # topologies it can only fill the hide window left
                    # after demand (the drain below), and it follows
                    # the read device like the engine's prefetch lane
                    acct.add_step_demand(rd, pf_b, qos=QOS_SPECULATIVE)
                req_miss_b[rid] = miss_b
                acct.record_hits(h * step_topk, (1 - h) * step_topk)
                if pf_n:
                    # warm-up (cold step) stays UNkeyed like the engine:
                    # keying the burst would tank a fresh request's
                    # precision before its first real speculation
                    acct.record_prefetch(pf_n, pf_u,
                                         key=None if was_cold else rid)
                    acct.record_prefetch_bytes(pf_b)
            step_demand = acct.drain_step()     # per-SEGMENT bytes
            bw = backend.fetch_bw_Bps
            if backend.prefetch and (prefetch.busy() or rearrange.busy()):
                bw *= (1 - backend.pcie_contention)   # PCIe bus contention
            # arbiter feedback: this step's demand-only (non-speculative)
            # seconds per slot are next step's pressure signal, split
            # per request so a departure subtracts its own share
            tracker.set_step([d / bw for d in demand_ctl],
                             {rid: b / bw for rid, b in req_miss_b.items()})
            sched.note_pressure_update()
            # per-SEGMENT drain: a shared trunk serializes everything
            # behind it, so the step's fetch tail is the BOTTLENECK
            # segment's drain time (flat star: exactly the old per-
            # device max)
            seg_s = topo.segment_seconds(step_demand, bw)
            spec_s = topo.segment_seconds(acct.step_spec_bytes, bw)
            t_fetch = (max(seg_s) + backend.fetch_base_s
                       + model.n_attn_layers * backend.layer_latency_s)
            if topo.qos_spec_yield:
                # QoS: speculation yields to demand at congested
                # segments — only DEMAND traffic can stall the step,
                # and spec beyond each segment's leftover hide window
                # arrives too late to help (dropped from exposure,
                # counted in spec_yielded_s; it stays issued)
                dem_s = [a - b for a, b in zip(seg_s, spec_s)]
                t_exposed = pipeline.exposed_time(
                    max(dem_s) + backend.fetch_base_s
                    + model.n_attn_layers * backend.layer_latency_s,
                    t_comp)
                window = pipeline.hide_window_s(t_comp)
                acct.record_spec_yield(sum(
                    max(0.0, sp - max(0.0, window - dm))
                    for sp, dm in zip(spec_s, dem_s)))
            else:
                # issued vs exposed: only the tail of the step's fetch
                # that does not fit the double-buffered hide window
                # stalls decode
                t_exposed = pipeline.exposed_time(t_fetch, t_comp)
            acct.charge_segment_seconds(seg_s, spec_s)
            acct.charge_seconds(t_fetch)
            acct.charge_exposed(t_exposed)
        warm_seed.deactivate()     # first decode step ends warm seeding
        dt = t_comp + t_exposed
        t += dt

        # prefetch progress during the step; completed transfers queue for
        # the page-first -> layer-first rearrangement engine (P1)
        for rid in prefetch.advance(dt):
            rearrange.enqueue(
                rid, waiting_prefetch[rid].context_len
                * model.kv_bytes_per_token())
        for rid in rearrange.advance(dt):
            decoding[rid] = waiting_prefetch.pop(rid)

        # token accounting
        finished = []
        for r in decoding.values():
            r.generated += 1
            if r.first_token_s < 0:
                r.first_token_s = t + backend.admit_overhead_s
            else:
                r.tbt_max_s = max(r.tbt_max_s, t - t_iter0)
            if r.generated >= r.output_len:
                r.finish_s = t
                finished.append(r)
        for r in finished:
            decoding.pop(r.request_id, None)
            sched.finish(r)
            # per-request demand attribution: the departing request's
            # own share leaves its link's pressure signal immediately
            share = tracker.depart(r.request_id, r.pool_device)
            sched.note_departure(r.pool_device, share)
            acct.stats.drop_request(r.request_id)
            n_done += 1

    out = summarize(reqs, slo_ttft_s=sim.slo_ttft_s,
                    slo_tbt_s=sim.slo_tbt_s)
    out.update(fabric_time_s=acct.stats.fabric_time_s,
               issued_fabric_s=acct.stats.issued_fabric_s,
               exposed_fabric_s=acct.stats.exposed_fabric_s,
               bytes_fetched=acct.stats.bytes_fetched,
               bytes_written=acct.stats.bytes_written,
               critical_demand_bytes=acct.stats.critical_demand_bytes,
               critical_issued_s=acct.stats.critical_issued_s,
               spec_yielded_s=acct.stats.spec_yielded_s,
               replica_redirects=float(replica_redirects[0]),
               shed_requests=float(n_shed[0]),
               radix_hit_tokens=float(sum(matched.values())),
               replicated_bytes=replicated_b[0],
               dedup_shared_bytes=dedup_b[0],
               pool_bytes_per_req=(sched.booked_bytes_cum
                                   / max(n_done, 1)),
               prefetch_bytes=acct.stats.prefetch_bytes,
               prefetched_entries=acct.stats.prefetched_entries,
               prefetch_useful=acct.stats.prefetch_useful,
               sim_hit_rate=acct.stats.hit_rate,
               cold_hit_rate=(sum(cold_hits_seen) / len(cold_hits_seen)
                              if cold_hits_seen else cold_hit))
    # per-SEGMENT traffic (lists — benchmarks/fabric_sweep.py computes
    # trunk/leaf hotspot ratios from these against the topology)
    out["segment_demand_bytes"] = list(acct.stats.segment_demand_bytes)
    out["segment_issued_s"] = list(acct.stats.segment_issued_s)
    if arb is not None:
        out["arbiter_width_mean"] = (grant_sum / grant_n if grant_n
                                     else 0.0)
    return out


def run_backend_sweep(reqs: List[Request], model: ModelProfile,
                      backends: Dict[str, BackendProfile], sim: SimConfig
                      ) -> Dict[str, Dict[str, float]]:
    return {name: simulate(reqs, model, b, sim)
            for name, b in backends.items()}


def replay_engine_timeline(eng, reqs: List[Request],
                           *, max_steps: int = 100_000) -> List[Request]:
    """Analytic replay of the engine's continuous-batching loop (PR 8).

    Reproduces :meth:`Engine.step`'s virtual-clock sequencing — arrival-
    gated admission into freed slots, chunked / monolithic / disagg-lane
    prefill, cold-read decode charging, idle jumps to the next event —
    using the engine's OWN cost objects (``eng.profile``,
    ``eng.sac.fabric``, ``eng.sac.entry_bytes``), so per-request
    ``dispatch_s`` / ``first_token_s`` / ``finish_s`` must agree with a
    real engine run to float precision.

    Valid for the parity regime the rolling-admission tests pin down:
    cold reads (``device_buffer == 0``), radix/prefetch/warm-up off,
    overlap off, flat star topology (timing independent of placement).
    Returns fresh request copies carrying the replayed timestamps.

    Admission and prefill-mode dispatch consume the engine's OWN
    shared policy objects (``eng.admission_policy``,
    ``eng.prefill_schedule`` — serving/policy/), so engine/replay
    parity on these decisions is object identity, not reimplementation.
    """
    cfg = eng.cfg
    fabric = eng.sac.fabric
    entry_b = eng.sac.entry_bytes
    policy = eng.admission_policy
    schedule = eng.prefill_schedule
    wb_layers = max(cfg.n_attn_layers, 1)
    n_kv = max(getattr(eng.model, "n_kv", 1), 1)
    k = min(cfg.sac.topk, eng.max_ctx)
    eps = 1e-12

    reqs = sorted((dataclasses.replace(
        r, dispatch_s=-1.0, first_token_s=-1.0, finish_s=-1.0,
        generated=0, tbt_max_s=0.0, out_tokens=None)
        for r in reqs), key=lambda r: r.request_id)
    queue: List[Request] = list(reqs)      # engine submit order (FCFS)
    slots: List[Optional[Request]] = [None] * eng.slots
    # chunked mode: slot -> [request, effective tokens left]
    jobs: List[Optional[list]] = [None] * eng.slots
    # disagg mode: prefill lanes + handoff records [ready_s, request]
    lane_busy = [0.0] * eng.prefill_lanes
    handoffs: List[list] = []
    shed: List[Request] = []
    clock = 0.0

    def write_s(n_tokens: int) -> float:
        return fabric.bulk_transfer_time(n_tokens * entry_b * wb_layers)

    def prefill_one(r: Request) -> float:
        """Prefill compute + exposed pool write for a whole prompt."""
        return (eng.profile.prefill_s(r.context_len)
                + write_s(r.context_len))

    def eligible() -> Optional[Request]:
        """The next request the shared admission policy would admit
        (None when nothing has arrived on the replay clock)."""
        elig = policy.eligible(queue, clock)
        if not elig:
            return None
        return queue[policy.select(queue, elig)]

    def fill() -> bool:
        nonlocal clock
        progressed = False
        drop = policy.shed(queue, clock)     # EDF load shedding, same
        for i in reversed(drop):             # policy object the engine
            shed.append(queue.pop(i))        # sheds through
        if schedule.disagg:
            for s in range(eng.slots):           # adopt ready handoffs
                if slots[s] is not None:
                    continue
                ready = [h for h in handoffs if h[0] <= clock + eps]
                if not ready:
                    break
                h = min(ready, key=lambda h: (h[0], h[1].request_id))
                handoffs.remove(h)
                slots[s] = h[1]                  # no warm-up traffic in
                progressed = True                # the parity regime
            for lane in range(eng.prefill_lanes):
                if lane_busy[lane] > clock + eps:
                    continue
                r = eligible()
                if r is None:
                    break
                queue.remove(r)
                r.dispatch_s = clock
                ready_s = clock + prefill_one(r)
                lane_busy[lane] = ready_s
                handoffs.append([ready_s, r])
                progressed = True
            return progressed
        if schedule.chunked:
            for s in range(eng.slots):           # bind arrivals to jobs
                if slots[s] is not None or jobs[s] is not None:
                    continue
                r = eligible()
                if r is None:
                    break
                queue.remove(r)
                r.dispatch_s = clock
                jobs[s] = [r, r.context_len]
                progressed = True
            for s in range(eng.slots):           # advance one chunk each
                if jobs[s] is None:
                    continue
                r, left = jobs[s]
                take = schedule.chunk_take(left)
                jobs[s][1] = left - take
                if jobs[s][1] <= 0:
                    jobs[s] = None
                    slots[s] = r
                clock += eng.profile.prefill_s(take) + \
                    (write_s(take) if take > 0 else 0.0)
                progressed = True
            return progressed
        for s in range(eng.slots):               # monolithic colocated
            if slots[s] is not None:
                continue
            r = eligible()
            if r is None:
                break
            queue.remove(r)
            r.dispatch_s = clock
            clock += prefill_one(r)
            slots[s] = r
            progressed = True
        return progressed

    def inflight() -> bool:
        return any(j is not None for j in jobs) or bool(handoffs)

    steps = 0
    while queue or any(s is not None for s in slots) or inflight():
        steps += 1
        assert steps < max_steps, "replay failed to drain"
        progressed = fill()
        occupied = [s for s in range(eng.slots) if slots[s] is not None]
        if not occupied:
            if not progressed:
                cands = [r.arrival_s for r in queue] \
                    + [h[0] for h in handoffs]
                future = [c for c in cands if c > clock]
                if not future:
                    break
                clock = min(future)
                fill()
                occupied = [s for s in range(eng.slots)
                            if slots[s] is not None]
            if not occupied:
                continue
        # one decode step: modeled compute + cold-read fetch per slot
        # (overlap off: every issued second is exposed)
        t_comp = eng.step_compute_s(len(occupied))
        fetch = 0.0
        for s in occupied:
            r = slots[s]
            prev_len = r.context_len + r.generated
            n = min(k * n_kv, prev_len * n_kv or 1)
            fetch += fabric.sparse_fetch_time(n, entry_b)
        clock += t_comp + fetch
        for s in occupied:
            r = slots[s]
            r.generated += 1
            if r.first_token_s < 0:
                r.first_token_s = clock
            if r.generated >= r.output_len:
                r.finish_s = clock
                slots[s] = None
    return reqs
