"""Fetch pipeline: speculative prefetch + prefill warm-up planning.

SAC's decode-side wins assume the per-step top-k *miss* fetches can be
pipelined behind compute (CXL load/store semantics make the issue cheap);
this module is the host half of that pipeline:

  - :class:`FetchPlanner` builds the **prefill warm-up plan**: the hot
    tier of a freshly placed request is seeded from (a) the trailing
    pages of the radix-reused prefix (they were the previous occupant's
    working set for the same tokens) and (b) the top-scoring prompt
    entries per layer, emitted in-graph by ``prefill`` (scored against
    the last prompt position's activations — the closest proxy for the
    first decode query).  The plan is applied with
    ``hisparse.warm_lane`` (insert-without-read) so results never change.
  - **Speculative per-step prefetch** runs fully in-graph
    (``dsa.speculate_next_topk`` inside ``sac.sparse_attend``): ranks
    [k, k+w) of the current step's indexer scores are warm-inserted for
    step t+1.  The planner's analytic counterpart
    (:func:`analytic_prefetch`) gives the simulator the same knob.
  - The **issued/exposed split** lives in the shared substrate
    (``transfer.PipelineModel`` + ``traffic.OverlapQueue``): fetches are
    issued into per-device double-buffered queues and only the tail that
    does not fit the hide window is exposed step time.

Everything here changes *traffic and timing only*: decoded tokens are
bit-identical with the pipeline on or off (tests/test_prefetch.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import kv_layer_windows


@dataclasses.dataclass
class WarmupPlan:
    """One request's prefill warm-up: per-layer positions to seed."""

    idx: jnp.ndarray        # [L, w_total] int32 pool positions
    valid: jnp.ndarray      # [L, w_total] bool


class FetchPlanner:
    """Host-side planner for the fetch pipeline of one serving engine.

    The planner owns no device state — it turns host facts (radix match
    length, prompt length) plus the in-graph warm-candidate tensor into
    the index plan ``hisparse.warm_lane`` applies.
    """

    def __init__(self, cfg: ModelConfig, *, n_layers: int,
                 layer_windows: Optional[List[int]] = None):
        self.cfg = cfg
        self.sac = cfg.sac
        self.n_layers = max(n_layers, 1)
        wins = (kv_layer_windows(cfg) if layer_windows is None
                else list(layer_windows))
        self.layer_windows = (wins + [0] * self.n_layers)[:self.n_layers]

    def warmup_plan(self, warm_idx: Optional[jnp.ndarray],
                    matched_tokens: int, prompt_len: int
                    ) -> Optional[WarmupPlan]:
        """Merge score-based and radix-based warm-up candidates.

        warm_idx: [L, w] per-layer top-scoring prompt positions (from
        ``prefill``; lanes of -1 mark masked-out candidates on windowed
        layers; None when score warm-up is off); matched_tokens is
        the radix prefix hit (page-aligned).  Duplicates across the two
        sources are fine — ``warm_insert`` skips already-resident
        positions, so the radix tail lanes only fill what scores missed.
        """
        r = min(max(int(self.sac.warmup_radix), 0), prompt_len)
        parts_idx, parts_valid = [], []
        if warm_idx is not None and warm_idx.shape[-1]:
            scores_idx = np.asarray(warm_idx, np.int32)
            parts_idx.append(np.maximum(scores_idx, 0))
            parts_valid.append(scores_idx >= 0)
        if r:
            # trailing positions of the reused prefix (the radix hit is
            # layer-agnostic); lanes below the match length are invalid
            # when the prefix was shorter, and windowed layers only get
            # positions their decode mask (pos > cache_len - window) can
            # still select — anything older is guaranteed waste
            pos = np.arange(matched_tokens - r, matched_tokens)
            valid = pos >= 0
            wins = np.asarray(self.layer_windows)[:, None]    # [L, 1]
            in_window = (wins == 0) | (pos[None, :] > prompt_len - wins)
            pos = np.clip(pos, 0, max(prompt_len - 1, 0))
            parts_idx.append(
                np.broadcast_to(pos[None, :], (self.n_layers, r))
                .astype(np.int32))
            parts_valid.append(valid[None, :] & in_window)
        if not parts_idx:
            return None
        idx = np.concatenate(parts_idx, axis=1)
        valid = np.concatenate(parts_valid, axis=1)
        if not valid.any():
            return None
        return WarmupPlan(idx=jnp.asarray(idx), valid=jnp.asarray(valid))


def cap_warmup(plan: Optional[WarmupPlan], width: int
               ) -> Optional[WarmupPlan]:
    """Cap a warm-up plan at ``width`` valid lanes per layer.

    The warm-up arbitration path (``BudgetArbiter.grant_warmup``): lanes
    are kept best-first (score-based seeds precede the radix tail in the
    plan), so a budget cut drops the least certain seeds first — the
    exact analogue of ``dsa.budget_mask`` on decode speculation.  Returns
    None when nothing survives (pure traffic shaping; skipping the warm
    burst entirely never changes decoded tokens).
    """
    if plan is None or width >= plan.idx.shape[1]:
        return plan
    if width <= 0:
        return None
    keep = jnp.cumsum(plan.valid.astype(jnp.int32), axis=1) <= width
    valid = plan.valid & keep
    if not bool(np.asarray(valid).any()):
        return None
    return WarmupPlan(idx=plan.idx, valid=valid)


# ---------------------------------------------------------------------------
# analytic counterpart (serving/simulator.py)
# ---------------------------------------------------------------------------


def analytic_prefetch(base_hit: float, width: int, topk: int,
                      *, churn_cover: float = 0.25,
                      spill_frac: float = 0.5) -> Tuple[float, float]:
    """Analytic model of speculative prefetch, mirroring the engine.

    The hot tier's misses are the *entrants* of each step's top-k;
    speculation over ranks [k, k+width) catches the fraction of entrants
    that were already near the cut the step before — modeled as
    ``cover = width / (width + churn_cover * topk)`` (deep entrants
    jumping from far below the cut stay misses).  The caught entrants
    (``useful = cover * miss * topk`` per layer per step) were all
    warm-inserted, plus a spill of speculation that never lands
    (``spill_frac * width * miss`` — resident candidates are skipped
    in-graph, so a stable top-k issues almost nothing); issued entries =
    useful + spill, which keeps the schema invariant ``prefetched >=
    useful`` (wasted >= 0) that the engine measures in-graph.

    Returns ``(hit', issued_entries_per_layer_step)`` with
    ``(hit' - base_hit) * topk <= issued``; ``hit' >= base_hit``
    always; calibrated loosely against the engine-measured drift trace
    in tests/test_prefetch.py.
    """
    base_hit = min(max(base_hit, 0.0), 1.0)
    if width <= 0 or topk <= 0:
        return base_hit, 0.0
    miss = 1.0 - base_hit
    cover = width / (width + churn_cover * topk)
    useful = cover * miss * topk
    hit2 = base_hit + useful / topk       # == 1 - miss * (1 - cover)
    issued = useful + spill_frac * width * miss
    return hit2, issued


def analytic_warmup(warmup_entries: int, topk: int, buf: int,
                    *, precision: float = 0.7) -> float:
    """Analytic model of prefill warm-up's cold-start miss reduction.

    A freshly placed request's first decode step starts with an empty hot
    tier — every top-k read is a miss — unless prefill warm-up seeded it
    (FetchPlanner.warmup_plan + ``hisparse.warm_lane``).  The seeds are
    the top-``warmup_entries`` prompt positions by indexer score against
    the *last prompt position* — a proxy for the first decode query —
    plus radix-reused tail pages, so only a ``precision`` fraction of
    the seeded coverage lands in the actual first top-k.  At most
    ``buf`` seeds fit the tier and at most ``topk`` can be demand-hit.

    Returns the modeled first-step hit rate (0 when warm-up is off);
    monotone non-decreasing in ``warmup_entries`` — the simulator-side
    twin of the engine's measured cold-start reduction
    (tests/test_arbiter.py asserts both directions).
    """
    if warmup_entries <= 0 or topk <= 0 or buf <= 0:
        return 0.0
    cover = min(warmup_entries, buf, topk) / topk
    return cover * min(max(precision, 0.0), 1.0)
