"""Decode engine: continuous batching over the SAC cache — the *real*
JAX serving path (compiled prefill/decode steps + host-side SACSystem
bookkeeping), runnable end-to-end on CPU with reduced configs.

This is the functional counterpart of the simulator: the simulator
answers "what would the cluster do", the engine actually *does* it for
small models — real top-k selection, real pool reads/writes, real radix
prefix reuse, and the real HiSparse hot buffer (core/hisparse.py) wired
into the jitted decode step.  With the buffer enabled (default), every
step's top-k reads go through the in-graph read-through: decoded tokens
are bit-identical to the buffer-off path, but residency is *measured*,
and only misses are charged to the fabric (paper §5.5 miss-only
traffic).  ``EngineStats.buffer_hits/buffer_misses`` are therefore live
numbers, grounded against the simulator's analytic ``hit_rate()`` model
in tests/test_engine_buffer.py.

The fetch pipeline (``prefetch=True``, serving/prefetch.py) adds
speculative next-step prefetch (in-graph, ``dsa.speculate_next_topk``),
prefill-time warm-up of the hot tier (radix-reused prefix tail +
top-scoring prompt entries, applied with ``hisparse.warm_lane``), and
overlap-aware charging: fetches are *issued* into per-device
double-buffered queues and only the unhidden tail is *exposed* step
time (``TrafficStats.issued_fabric_s >= exposed_fabric_s``).  None of it
changes decoded tokens — prefetch touches only the hot tier, and the
pool stays authoritative.

Engine latency metrics are deterministic: ``now`` is a virtual clock
advanced by the modeled per-step time (compute from the simulator's
``ModelProfile`` constants + exposed fabric), so TTFT/TBT are
reproducible and directly comparable to the simulator's.

Placement and traffic accounting go through the shared substrate
(core/placement.py, core/traffic.py): the engine's ``SACSystem`` places
each request's pool pages with the same policy the scheduler and
simulator use, and charges fetch/write traffic to the same
``TrafficStats`` schema the simulator reports.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hisparse
from repro.core.sac import SACSystem
from repro.core.traffic import TrafficStats
from repro.core.transfer import PipelineModel
from repro.models.model import build_model
from repro.models.transformer import kv_layer_windows
from repro.serving.arbiter import (ArbiterConfig, BudgetArbiter,
                                   DemandTracker, LayerSizer,
                                   resize_allocation_width)
from repro.serving.policy import (LocalityBonus, PrefillSchedule,
                                  PressureFeed, RadixAdmission,
                                  ReplicationPolicy, WarmupPressureSeed,
                                  make_admission)
from repro.serving.prefetch import FetchPlanner, cap_warmup
from repro.serving.radix import RadixIndex
from repro.serving.request import Request, summarize
from repro.serving.simulator import profile_from_config


@dataclasses.dataclass
class EngineStats:
    """Engine counters; fabric traffic lives in the shared TrafficStats
    schema (the same object the engine's SACSystem accountant charges)."""

    steps: int = 0
    tokens: int = 0
    radix_hit_tokens: int = 0       # PAGE-GRANULAR tokens whose prefill
                                    # compute + pool write were skipped
                                    # because the prefix was cached on
                                    # the request's own pool device
    radix_hit_requests: int = 0     # requests with a same-device hit
    radix_evicted_pages: int = 0    # cached-prefix pages returned to the
                                    # pool under page pressure
    resizes: int = 0                # online LayerSizer re-apportionings
                                    # actually applied
    resize_skips: int = 0           # intervals skipped by the hysteresis
                                    # epsilon (rates barely moved)
    replicated_pages: int = 0       # hot-prefix replica pages copied to a
                                    # second pool device (PR 6)
    dedup_shared_pages: int = 0     # request pages refcount-shared with
                                    # the cache instead of held privately
    replica_redirects: int = 0      # slot-steps whose prefix reads went
                                    # to a less-pressured replica device
                                    # instead of the slot's own (PR 7
                                    # replica-aware grants)
    shed_requests: int = 0          # requests dropped by EDF load
                                    # shedding before admission (PR 10
                                    # SLO-aware admission policy)
    traffic: TrafficStats = dataclasses.field(default_factory=TrafficStats)
    # measured per-layer hot-tier outcomes ([L] arrays, accumulated per
    # step) — the LayerSizer's miss-rate signal (serving/arbiter.py)
    layer_hits: Optional[np.ndarray] = None
    layer_misses: Optional[np.ndarray] = None

    def layer_miss_rates(self) -> Optional[np.ndarray]:
        """Per-layer miss fraction of the layer's demand top-k reads."""
        if self.layer_hits is None or self.layer_misses is None:
            return None
        tot = self.layer_hits + self.layer_misses
        return self.layer_misses / np.maximum(tot, 1)

    @property
    def pool_entries_fetched(self) -> int:
        """Entries that crossed the fabric (demand misses + prefetch) —
        the shared ``TrafficStats.entries_fetched`` counter, not a
        separately drifting engine tally."""
        return int(self.traffic.entries_fetched)

    @property
    def buffer_hits(self) -> int:
        return int(self.traffic.buffer_hits)

    @property
    def buffer_misses(self) -> int:
        return int(self.traffic.buffer_misses)

    @property
    def fabric_time_s(self) -> float:
        return self.traffic.fabric_time_s

    @property
    def issued_fabric_s(self) -> float:
        return self.traffic.issued_fabric_s

    @property
    def exposed_fabric_s(self) -> float:
        return self.traffic.exposed_fabric_s

    @property
    def hit_rate(self) -> float:
        return self.traffic.hit_rate

    @property
    def prefetched_entries(self) -> int:
        return int(self.traffic.prefetched_entries)

    @property
    def prefetch_useful(self) -> int:
        return int(self.traffic.prefetch_useful)

    @property
    def prefetch_wasted(self) -> int:
        return int(self.traffic.prefetch_wasted)

    @property
    def prefetch_precision(self) -> float:
        return self.traffic.prefetch_precision


@dataclasses.dataclass
class _PrefillJob:
    """A prefill in flight (PR 8: chunked / disaggregated prefill).

    All host-side admission work is already done when the job exists —
    pool pages booked (``rp``), radix pins held, dedup shared, dispatch
    stamped — but the jitted prefill + state splice are DEFERRED to
    completion (``Engine._complete_prefill``).  A mid-flight slot
    therefore holds no decodable state at all, so the decoded tokens
    cannot depend on the chunk schedule: chunking and disaggregation
    change timing and traffic, never tokens (the repo invariant)."""

    req: Request
    prompt: np.ndarray
    matched: int                 # page-granular radix-hit tokens
    pins: List[list]             # radix paths pinned for the lifetime
    rp: object                   # the SACSystem placement record
    dedup_n: int                 # pages refcount-shared with the cache
    copies: tuple                # replica-read copy devices (PR 7)
    frac: float                  # prefix read fraction for replica reads
    done_tokens: int = 0         # effective tokens already chunked
    ready_s: float = -1.0        # disagg: handoff-ready wall-clock time

    @property
    def effective(self) -> int:
        """Prompt tokens that actually cost compute + pool write (the
        radix-matched prefix is copied device-locally)."""
        return len(self.prompt) - self.matched


class Engine:
    """Fixed-slot continuous batching engine.

    ``slots`` requests decode together in one compiled step; finished
    slots are refilled from the queue (prefill on demand, with radix
    prefix reuse).  The pool state is the serve_state pytree of
    models/transformer.py; per-slot independence is guaranteed by the
    batch dimension.

    ``track_buffer`` wires the HiSparse hot buffer into the decode step
    (``device_buffer`` entries per layer per slot, default
    ``cfg.sac.device_buffer_size``); fabric time is then charged on
    measured misses only.  Off, every step is charged the full cold-read
    top-k transfer.

    ``prefetch`` turns on the fetch pipeline (serving/prefetch.py):
    speculative in-graph prefetch of ``cfg.sac.prefetch_width`` entries
    per layer per step, prefill warm-up of the hot tier, and overlap
    queues (issued vs exposed fabric seconds).  ``prefetch_fn`` overrides
    the in-graph speculation ``(scores, cache_len) -> (idx, valid)`` —
    the hook parity tests use to replay controlled drift.  ``overlap``
    forces the overlap queues on/off independently of prefetch (default:
    on when prefetch or ``cfg.sac.overlap_fetch`` is set).

    ``arbiter`` (default ``cfg.sac.arbiter``) turns on cross-request
    prefetch budget arbitration (serving/arbiter.py): each step, last
    step's measured per-device demand seconds shrink or grow every
    request's granted speculative width, passed into the jitted decode
    as a per-slot budget tensor.  ``layer_sizing`` (default
    ``cfg.sac.layer_sizing``) apportions the hot tier's total slot
    budget across layers via the LayerSizer instead of uniformly.
    Neither changes decoded tokens (property-tested in
    tests/test_arbiter.py).

    PR 4 closes the remaining control loops:

      - ``placement`` (default ``cfg.sac.placement``) overrides the pool
        placement policy; ``"pressure_aware"`` feeds the placer the
        engine's live per-device demand seconds so new requests land on
        the least-pressured fabric link;
      - ``cfg.sac.precision_weighted`` splits each device's grant budget
        across its requests by their measured prefetch precision (the
        per-request ``TrafficStats.request_pf`` attribution) instead of
        uniformly;
      - ``cfg.sac.resize_interval`` re-apportions the hot tier online:
        every that many steps the LayerSizer re-runs on the measured
        per-layer miss rates and the hisparse DISABLED sentinels are
        re-marked in place (``hisparse.resize_layers``);
      - with the arbiter on, prefill warm-up bursts draw from the same
        per-device link budget (``BudgetArbiter.grant_warmup`` caps the
        warm-up plan's width).

    All four change traffic and timing only — decoded tokens are
    bit-identical with every knob on or off.

    PR 5 makes the radix prefix cache request-lifetime-correct and
    closes the prefix-locality loop: the index holds the request's
    ACTUAL pool pages (pinned for the request's lifetime, retained
    under cache ownership at finish, evicted back to the allocator
    under pool page pressure, purged the moment ``sac.release`` frees
    them); ``placement="radix_affinity"`` weighs a matched prefix's
    device against live link pressure; and a same-device hit skips the
    matched pages' pool write and shortens the modeled prefill
    (``radix_hit_tokens`` changes timing and traffic — never tokens:
    prefill always recomputes the full prompt in-graph).  ``radix=False``
    disables the cache entirely (the A/B baseline).

    PR 6 trades pool bytes for link bandwidth on hot prefixes:

      - ``replicate_prefixes`` (default ``cfg.sac.replicate_prefixes``)
        copies a matched prefix's pages to the least-pressured pool
        device when the corrected pressure on the copy-holding link
        covers the one-time copy cost within
        ``cfg.sac.replicate_horizon_steps`` decode steps — placement
        then picks the cheapest COPY (``MatchResult.copies``) instead
        of the single owner, splitting a hot prefix's load across
        links;
      - ``dedup_pages`` (default ``cfg.sac.dedup_pages``) refcount-
        shares a same-device match's cached pages with the new slot
        instead of holding private pool copies (decode never mutates
        prefix pages, so no copy-on-write is needed) — the slot's
        booking shrinks by the shared bytes, multiplying effective pool
        capacity under shared-prefix load;
      - ``radix_admission`` (default ``cfg.sac.radix_admission``)
        admits the waiting request with the longest page-granular match
        against the current tree (FCFS tie-break) so batches sharing a
        prefix land while the copy is hot.

    All three change traffic, timing, and pool bytes — never decoded
    tokens (prefill still recomputes the full prompt in-graph; page ids
    are host-side bookkeeping).
    """

    def __init__(self, cfg: ModelConfig, *, slots: int = 4,
                 max_ctx: int = 256, backend: str = "cxl",
                 mode: str = "sac", track_buffer: bool = True,
                 device_buffer: Optional[int] = None,
                 prefetch: bool = False, prefetch_fn=None,
                 overlap: Optional[bool] = None,
                 arbiter: Optional[bool] = None,
                 layer_sizing: Optional[str] = None,
                 placement: Optional[str] = None,
                 radix: bool = True,
                 replicate_prefixes: Optional[bool] = None,
                 dedup_pages: Optional[bool] = None,
                 radix_admission: Optional[bool] = None,
                 admission: Optional[str] = None,
                 shed_queue_depth: Optional[int] = None,
                 topology=None,
                 warmup_pressure_seed: Optional[bool] = None,
                 replica_reads: Optional[bool] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 disagg: Optional[bool] = None,
                 prefill_lanes: Optional[int] = None,
                 topk_fn=None, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.max_ctx = max_ctx
        buffered = (track_buffer and cfg.sac.enabled and not cfg.enc_dec
                    and mode == "sac")
        self.device_buffer = 0
        if buffered:
            self.device_buffer = (cfg.sac.device_buffer_size
                                  if device_buffer is None else device_buffer)
        self.prefetch = bool(prefetch and self.device_buffer)
        # topk_fn overrides the indexer's top-k selection inside the jitted
        # step (scores, cache_len) -> (idx, valid); used by parity tests to
        # replay controlled top-k traces through the real buffer wiring
        opts = {}
        if self.prefetch:
            opts["prefetch_width"] = int(cfg.sac.prefetch_width)
            opts["score_margin"] = float(cfg.sac.score_margin)
            if prefetch_fn is not None:
                opts["prefetch_fn"] = prefetch_fn
            if cfg.sac.warmup_entries > 0:
                opts["warmup_w"] = int(cfg.sac.warmup_entries)
        self.model = build_model(cfg, mode=mode, topk_fn=topk_fn,
                                 opts=opts or None)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.placement = placement if placement is not None \
            else cfg.sac.placement
        # fabric topology (core/fabric.py): one object shared by the
        # accountant (per-segment charging), placer (bottleneck-pressure
        # projection), demand tracker, and arbiter.  None -> cfg.sac
        # spec -> flat star (bit-identical to flat per-device accounting)
        self.sac = SACSystem(cfg, backend=backend,
                             placement=self.placement,
                             topology=(topology if topology is not None
                                       else cfg.sac.topology))
        self.topology = self.sac.topology
        # radix prefix cache: the SACSystem owns its page lifecycle
        # (retention at finish, eviction under pressure, purge on free)
        self.radix = (RadixIndex(page_size=cfg.sac.page_size)
                      if radix else None)
        self.sac.attach_radix(self.radix)
        # PR 6 knobs (all gated on the radix cache existing)
        has_radix = self.radix is not None
        self.replicate_on = bool(
            (cfg.sac.replicate_prefixes if replicate_prefixes is None
             else replicate_prefixes) and has_radix)
        self.dedup_on = bool((cfg.sac.dedup_pages if dedup_pages is None
                              else dedup_pages) and has_radix)
        # admission policy (serving/policy/admission.py): the ONE
        # arrival-gate + queue-ordering + shedding object shared with
        # the simulator twin and the analytic replay.  name=None keeps
        # the legacy mapping (radix when the PR 6 knob is on, else
        # FCFS); "edf" adds SLO-aware ordering + optional load shedding
        self.admission_policy = make_admission(
            cfg.sac.admission if admission is None else admission,
            radix_admission=bool(
                cfg.sac.radix_admission if radix_admission is None
                else radix_admission),
            slo_ttft_s=float(cfg.sac.slo_ttft_s),
            shed_queue_depth=int(
                cfg.sac.shed_queue_depth if shed_queue_depth is None
                else shed_queue_depth),
            score_fn=self._radix_score, has_radix=has_radix)
        self.admission_on = isinstance(self.admission_policy,
                                       RadixAdmission)
        # PR 7 satellites: warm-up-only pressure seeding (the feed is
        # silent before the first decode step — seed it from BOOKED
        # demand so wave-1 admissions stop herding; always-on regresses
        # under dedup, see benchmarks/locality_sweep.py) and replica-
        # aware per-step reads (prefix fetches go to the least-pressured
        # copy each step instead of the copy frozen at placement)
        self.warm_seed_on = bool(
            cfg.sac.warmup_pressure_seed if warmup_pressure_seed is None
            else warmup_pressure_seed)
        self.replica_reads_on = bool(
            (cfg.sac.replica_reads if replica_reads is None
             else replica_reads) and has_radix)
        # PR 8: continuous batching + disaggregated prefill.  Admission
        # is ALWAYS gated on the virtual clock vs arrival_s (the open-
        # loop bugfix); chunk_tokens > 0 splices a prompt in over
        # bounded chunks interleaved with decode steps; disagg runs
        # prefill on separate lanes (their own busy-until times on the
        # shared wall clock) and hands completed prefills to the decode
        # loop through _PrefillJob handoff records.  Chunking is a
        # colocated-engine concern: disagg lanes never block decode, so
        # chunk_tokens is ignored there.
        self.chunk_tokens = int(cfg.sac.prefill_chunk_tokens
                                if prefill_chunk_tokens is None
                                else prefill_chunk_tokens)
        self.disagg_on = bool(cfg.sac.disagg_prefill if disagg is None
                              else disagg)
        self.prefill_lanes = max(1, int(cfg.sac.prefill_lanes
                                        if prefill_lanes is None
                                        else prefill_lanes))
        self._jobs: List[Optional[_PrefillJob]] = [None] * slots
        self._lane_busy: List[float] = [0.0] * self.prefill_lanes
        self._handoffs: List[_PrefillJob] = []
        # per-slot (replica copy devices, prefix read fraction) of the
        # matched cached prefix — the backing pin held for the slot's
        # lifetime keeps the copy set valid
        self._slot_prefix: List[tuple] = [((), 0.0) for _ in range(slots)]
        # per-slot radix bookkeeping: (pinned token paths — the matched
        # BACKING prefix and the request's own aligned path — and the
        # pages the index registered from this request's allocation)
        self._slot_radix: List[tuple] = [([], 0) for _ in range(slots)]
        # the engine's stats share the SACSystem accountant's TrafficStats:
        # every charged fetch/write and recorded hit/miss lands here
        self.stats = EngineStats(traffic=self.sac.traffic.stats)
        self.planner = (FetchPlanner(cfg, n_layers=max(self.model.n_kv, 1))
                        if self.prefetch else None)
        self.pipeline = PipelineModel(depth=cfg.sac.pipeline_depth,
                                      overlap_frac=cfg.sac.overlap_frac)
        self.overlap_on = (bool(self.prefetch or cfg.sac.overlap_fetch)
                           if overlap is None else bool(overlap))
        if self.overlap_on:
            self.sac.traffic.enable_overlap(self.pipeline)
        # virtual clock: per-step compute from the simulator's profile
        # constants, so engine latency numbers are deterministic and
        # engine/simulator timing is built from the same model
        self.profile = profile_from_config(cfg)
        self.clock_s = 0.0
        # fabric budget arbiter (serving/arbiter.py): grants per-slot
        # speculative widths from last step's measured demand backlog
        self.arbiter_on = bool((cfg.sac.arbiter if arbiter is None
                                else arbiter) and self.prefetch)
        self.arbiter: Optional[BudgetArbiter] = None
        self.last_grants: Dict[int, int] = {}
        self._grant_sum = 0
        self._grant_n = 0
        # per-link AND per-request demand-step deltas (serving/arbiter.py
        # DemandTracker): the pressure feed subtracts a finishing
        # request's own share from its link immediately at departure
        self._demand = DemandTracker(self.sac.n_devices, self.topology)
        # shared control-plane objects (serving/policy/): the SAME
        # classes the simulator twin and the analytic replay construct,
        # so parity tests assert object identity instead of float
        # agreement.  The pressure feed is wired here (not earlier)
        # because it closes over the demand tracker; no placement can
        # have happened yet, so the placer never saw the gap.
        self.warm_seed = WarmupPressureSeed(
            self.warm_seed_on, len(self._demand.last_demand_s))
        self.pressure_feed = PressureFeed(
            self._demand, self.warm_seed,
            booked_fn=lambda: self.stats.traffic.segment_demand_s())
        self.sac.set_pressure_fn(self.pressure_feed)
        self.replication = ReplicationPolicy(
            horizon_steps=int(cfg.sac.replicate_horizon_steps))
        self.locality_bonus = LocalityBonus(
            prefill_s=self.profile.prefill_s,
            write_s=self._prefix_write_s)
        self.prefill_schedule = PrefillSchedule.from_knobs(
            self.disagg_on, self.chunk_tokens, self.prefill_lanes)
        self.shed: List[Request] = []
        if self.arbiter_on:
            self.arbiter = BudgetArbiter.from_fabric(
                ArbiterConfig(max_width=int(cfg.sac.prefetch_width),
                              min_width=int(cfg.sac.min_prefetch_width),
                              link_budget_frac=float(
                                  cfg.sac.link_budget_frac),
                              precision_weighted=bool(
                                  cfg.sac.precision_weighted)),
                self.sac.fabric, self.sac.entry_bytes,
                n_layers=max(self.model.n_kv, 1), pipeline=self.pipeline,
                topology=self.topology)
        # per-layer hot-tier sizing: apportion the uniform total
        # (device_buffer * n_layers) by the LayerSizer's windowed prior.
        # resize_interval > 0 re-apportions ONLINE from the measured
        # per-layer miss rates: the static allocation then carries
        # headroom (2x the widest initial layer, capped at the total) so
        # layers can grow past their initial share, and the resize-time
        # LayerSizer gets that width as its hard per-layer cap.
        self.layer_sizing = (cfg.sac.layer_sizing if layer_sizing is None
                             else layer_sizing)
        self.resize_interval = (int(cfg.sac.resize_interval)
                                if self.device_buffer else 0)
        self.buffer_sizes: Optional[List[int]] = None
        self.buffer_width: Optional[int] = None
        self._sizer: Optional[LayerSizer] = None
        if self.device_buffer and (self.layer_sizing != "uniform"
                                   or self.resize_interval):
            n_kv = max(self.model.n_kv, 1)
            total = self.device_buffer * n_kv
            wins = (kv_layer_windows(cfg)
                    if self.layer_sizing != "uniform" else None)
            self.buffer_sizes = LayerSizer(
                n_kv, total, layer_windows=wins,
                topk=cfg.sac.topk).sizes()
            if self.resize_interval:
                self.buffer_width = resize_allocation_width(
                    self.buffer_sizes, self.device_buffer)
                self._sizer = LayerSizer(
                    n_kv, total, layer_windows=wins, topk=cfg.sac.topk,
                    max_slots=self.buffer_width)

        self._decode = jax.jit(self.model.decode)
        self._prefill_one = jax.jit(
            lambda p, toks: self.model.prefill(p, toks))
        self._warm = jax.jit(self._warm_apply)
        self.state = self.model.init_serve_state(
            slots, max_ctx,
            device_buffer=self.buffer_sizes or self.device_buffer,
            buffer_width=self.buffer_width)
        if self.device_buffer:
            n_kv = max(self.model.n_kv, 1)
            self.stats.layer_hits = np.zeros(n_kv)
            self.stats.layer_misses = np.zeros(n_kv)
            # resize-interval snapshot of the cumulative layer counters
            self._layer_mark = (np.zeros(n_kv), np.zeros(n_kv))
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_tokens: List[List[int]] = [[] for _ in range(slots)]
        self.queue: List[Request] = []
        # resize hysteresis: rates at the last sizer EVALUATION (skips
        # keep the reference, so slow drift accumulates against it) —
        # when no layer moved more than cfg.sac.resize_epsilon since,
        # the sizer run (and its sentinel churn) is skipped
        self._resize_rates_ref: Optional[List[float]] = None

    @property
    def _last_demand_s(self) -> List[float]:
        """Last step's per-SEGMENT demand seconds (departures already
        subtracted) — the arbiter's and the placer's pressure signal
        (the placer projects each device's path bottleneck from it).
        Delegates to the shared :class:`PressureFeed` (the same object
        wired into ``set_pressure_fn``): the PR 7 warm-up-only seeding
        window — booked prefill-write demand overlaid before the first
        decode step only — lives once, in serving/policy/seeding.py."""
        return self.pressure_feed()

    def _radix_score(self, req: Request) -> int:
        """Radix-admission score: this request's page-granular match
        length against the CURRENT tree (the admission policy's
        ``score_fn``)."""
        return self.radix.match(
            req.prompt_tokens[: req.context_len].tolist()).paged_tokens

    def _prefix_write_s(self, matched: int) -> float:
        """Pool-write seconds the matched prefix tokens skip — the
        engine-native cost the shared :class:`LocalityBonus` formula
        is bound to (the simulator binds its analytic striped-pool
        write bandwidth instead)."""
        return self.sac.fabric.bulk_transfer_time(
            matched * self.sac.entry_bytes
            * max(self.cfg.n_attn_layers, 1))

    # -- submission --------------------------------------------------------------
    def submit(self, req: Request):
        assert req.prompt_tokens is not None, "engine needs real tokens"
        assert req.context_len + req.output_len <= self.max_ctx, \
            "request exceeds engine max_ctx"
        self.queue.append(req)

    def _interval_miss_rates(self) -> Optional[List[float]]:
        """Per-layer miss rates of the CURRENT resize interval: deltas
        of the cumulative layer counters against the snapshot taken at
        the previous resize.  Layers with no reads this interval fall
        back to rate 0 (the sizer's epsilon keeps them eligible)."""
        if self.stats.layer_hits is None:
            return None
        hits = self.stats.layer_hits.copy()
        misses = self.stats.layer_misses.copy()
        mark_h, mark_m = self._layer_mark
        self._layer_mark = (hits, misses)
        dh, dm = hits - mark_h, misses - mark_m
        return [float(m) / max(float(h + m), 1.0)
                for h, m in zip(dh, dm)]

    # -- modeled step time --------------------------------------------------------
    def step_compute_s(self, batch: int) -> float:
        """Modeled decode-step compute for ``batch`` occupied slots."""
        return (self.profile.base_step_s
                + batch * self.profile.per_token_compute_s())

    @staticmethod
    def _warm_apply(hot, kv_pool, lane, idx, valid):
        """Seed one slot's hot-tier lanes from its pool slice (prefill
        warm-up): gather the planned positions' entries and warm-insert
        them (insert-without-read; never evicts current-step hits)."""
        pool_lane = jnp.take(kv_pool, lane, axis=1)          # [L, S, d]
        idx = jnp.clip(idx, 0, pool_lane.shape[1] - 1)
        vals = jax.vmap(lambda p, i: p[i])(pool_lane, idx)   # [L, w, d]
        return hisparse.warm_lane(hot, lane, idx, vals, valid)

    # -- slot refill -------------------------------------------------------------
    def _locality_bonus_s(self, prompt_len: int, matched: int) -> float:
        """Seconds a same-device radix hit saves: the matched tokens'
        modeled prefill compute plus their skipped pool write — the
        ``affinity_s`` weight the radix_affinity placement policy holds
        against live link pressure.  The FORMULA is the shared
        :class:`LocalityBonus` (serving/policy/locality.py) — the
        simulator's ``_bonus_s`` binds the same object to its analytic
        costs."""
        return self.locality_bonus(prompt_len, matched)

    def _eligible_indices(self) -> List[int]:
        """Queue indices whose requests have ARRIVED on the virtual
        clock — the open-loop admission gate (PR 8).  Before it,
        _fill_slots popped the queue FCFS regardless of ``arrival_s``,
        so every open-loop trace was silently served as if all requests
        arrived at t=0 and arrival-anchored TTFT was meaningless.
        Delegates to the shared admission policy's arrival gate."""
        return self.admission_policy.eligible(self.queue, self.clock_s)

    def _pick_queue_index(self, eligible: List[int]) -> int:
        """The next queue index to admit among the ARRIVED requests —
        the shared policy's ``select``: FCFS by default, longest radix
        match first under radix admission, earliest deadline first
        under EDF (ties always break FCFS)."""
        return self.admission_policy.select(self.queue, eligible)

    def _shed_waiting(self):
        """Load shedding (EDF + ``shed_queue_depth``): drop the arrived
        backlog beyond the policy's keep set BEFORE admission.  Shed
        requests leave the queue and never decode — they stay on
        ``self.shed`` (and out of summarize(), which only counts
        finished requests)."""
        drop = self.admission_policy.shed(self.queue, self.clock_s)
        for i in reversed(drop):
            self.shed.append(self.queue.pop(i))
        if drop:
            self.stats.shed_requests = len(self.shed)

    def _prefill_inflight(self) -> bool:
        """Any admitted prefill not yet spliced into a decode slot —
        chunked jobs mid-flight or disagg handoffs awaiting adoption."""
        return (any(j is not None for j in self._jobs)
                or bool(self._handoffs))

    def _next_event_s(self) -> Optional[float]:
        """The earliest future event the idle engine can jump to: the
        next arrival or the next handoff completion."""
        cands = [r.arrival_s for r in self.queue]
        cands += [h.ready_s for h in self._handoffs]
        future = [c for c in cands if c > self.clock_s]
        return min(future) if future else None

    def _maybe_replicate(self, m, toks: List[int], prompt_len: int):
        """Hot-prefix replication trigger.  Fire when (a) the reuse
        benefit itself covers the one-time copy cost and (b) the
        CORRECTED pressure on the prefix's cheapest copy-holding link —
        the raw feed plus the placer's in-flight booking correction, so
        a same-wave admission burst counts before the feed catches up —
        exceeds the one-time copy cost amortized over
        ``cfg.sac.replicate_horizon_steps`` decode steps, with the copy
        going to the least-pressured copy-free link (never a hotter
        one).  Per-step backlog on the owning link must cover the bulk
        copy's per-step share, or a lightly-loaded fabric would
        replicate everything for nothing.  The (src, dst) pick and the
        fire/hold predicate are the shared :class:`ReplicationPolicy`
        (serving/policy/replication.py) — the simulator twin consumes
        the same object.  Returns the re-match (placement must see the
        new copy) or None."""
        pressure = self.sac.placer.corrected_pressure()
        holders = [d for d in m.copies if 0 <= d < self.sac.n_devices]
        others = [d for d in range(self.sac.n_devices)
                  if d not in m.copies]
        pick = self.replication.pick(pressure, holders, others,
                                     self.sac.placer.bytes_used)
        if pick is None:
            return None
        src, dst = pick
        n_pages = len(m.copies[src])
        copy_cost = self.sac.replica_copy_cost_s(n_pages)
        bonus = self._locality_bonus_s(prompt_len, m.paged_tokens)
        if not self.replication.should_fire(pressure[src], pressure[dst],
                                            bonus, copy_cost):
            return None
        if not self.sac.replicate_prefix(list(m.pin_tokens),
                                         m.copies[src], src, dst):
            return None
        self.stats.replicated_pages = self.sac.replicated_pages
        return self.radix.match(toks)

    def _admit_request(self, req: Request) -> Optional[_PrefillJob]:
        """Host-side admission for one popped request: radix match/pin
        (+ replication), pool placement, dedup, dispatch stamp.  No
        compute advances the clock and no fabric write is charged here —
        each mode (monolithic / chunked / disagg lane) pays those on its
        own schedule.  Returns None when the pool is exhausted (pins
        released; the caller requeues at the head)."""
        prompt = req.prompt_tokens[: req.context_len]
        toks = prompt.tolist()
        # radix prefix lookup — PAGE-granular reuse (crediting the
        # raw token walk would count prefix tokens no cached page
        # backs).  The BACKING node's path is pinned immediately so
        # the pool-pressure eviction inside place() cannot free the
        # pages we are about to reuse.
        m = self.radix.match(toks) if self.radix is not None else None
        pins: List[list] = []
        if m is not None and m.hit:
            pins.append(list(m.pin_tokens))
            self.radix.pin(pins[-1])
            if self.replicate_on:
                # the pin above keeps the node alive through the
                # copy; a successful replication re-matches so the
                # placer sees every copy (same node, same pin path)
                m2 = self._maybe_replicate(m, toks, len(prompt))
                if m2 is not None and m2.hit:
                    m = m2
        bonus_s = (self._locality_bonus_s(len(prompt), m.paged_tokens)
                   if pins else 0.0)
        rp = self.sac.place(req.request_id, len(prompt) + req.output_len,
                            affinity=sorted(m.copies) if pins else None,
                            affinity_s=bonus_s)
        if rp is None:
            for p in pins:
                self.radix.release(p)
            return None
        req.dispatch_s = self.clock_s
        req.pool_device = rp.device
        # reuse is only real on a device holding a copy of the
        # cached pages (off-device, the prefix would cross two
        # fabric links — no better than recomputing); radix_affinity
        # placement + replication are what make this coincide
        matched = (m.paged_tokens
                   if pins and rp.device in m.copies else 0)
        if pins and not matched:
            self.radix.release(pins.pop())
        self.stats.radix_hit_tokens += matched
        if matched:
            self.stats.radix_hit_requests += 1
        # page dedup: share the matched copy's pages with this slot
        # instead of holding private duplicates — the slot's own
        # leading pages return to the pool and its booking shrinks.
        # The backing pin (held for the request's lifetime) is what
        # keeps the shared pages resident.
        dedup_n = 0
        if self.dedup_on and matched:
            shared = m.copies[rp.device][: matched
                                         // self.cfg.sac.page_size]
            dedup_n = self.sac.dedup_match(req.request_id, shared)
            if dedup_n:
                self.stats.dedup_shared_pages = \
                    self.sac.dedup_shared_pages
        # replica-aware reads (PR 7): the devices holding a copy of the
        # matched prefix and the fraction of this slot's reads in the
        # prefix region — step() re-picks the least-pressured copy
        # every step (the backing pin keeps every copy resident)
        copies, frac = (), 0.0
        if self.replica_reads_on and matched:
            copies = tuple(sorted(m.copies))
            frac = matched / max(len(prompt), 1)
        return _PrefillJob(req=req, prompt=prompt, matched=matched,
                           pins=pins, rp=rp, dedup_n=dedup_n,
                           copies=copies, frac=frac)

    def _complete_prefill(self, s: int, job: _PrefillJob):
        """Splice a finished prefill into slot ``s`` — the jitted
        prefill ALWAYS recomputes the full prompt in-graph, so the
        radix hit, the chunk schedule, and the handoff route change
        modeled timing and fabric traffic, never decoded tokens."""
        req, prompt, rp = job.req, job.prompt, job.rp
        matched = job.matched
        st, _ = self._prefill_one(self.params, prompt[None, :])
        st = dict(st)
        warm_idx = st.pop("warm_idx", None)
        self._splice_state(s, st, len(prompt))
        page_tokens = (len(prompt) // self.cfg.sac.page_size) \
            * self.cfg.sac.page_size
        keep = 0
        if self.radix is not None and page_tokens and not job.dedup_n:
            # (with dedup, the slot's leading pages ARE the cached
            # node's pages — inserting its own path would register a
            # second owner for them; the backing pin + existing node
            # already serve future matches)
            own = prompt[:page_tokens].tolist()
            # register the request's ACTUAL pool pages (the pre-PR 5
            # index advertised fabricated range(n) ids) — an
            # identical cached prefix keeps the first copy
            keep = self.radix.insert(
                own, rp.device,
                rp.pages[:page_tokens // self.cfg.sac.page_size])
            # pin the request's own aligned path for its lifetime;
            # the matched BACKING path stays pinned too (the reused
            # pages must survive while the request decodes)
            self.radix.pin(own)
            job.pins.append(own)
        self._slot_radix[s] = (job.pins, keep)
        self._slot_prefix[s] = (job.copies, job.frac)
        # prefill-time warm-up: seed the recycled (cold) lane from the
        # radix-reused prefix tail + top-scoring prompt entries
        if self.planner is not None:
            plan = self.planner.warmup_plan(
                None if warm_idx is None else warm_idx[:, 0],
                matched, len(prompt))
            if plan is not None and self.arbiter is not None:
                # warm-up arbitration: the prefill warm burst draws
                # from the same per-device link budget as decode
                # speculation — its hide window is the (radix-
                # shortened) prefill compute this burst rides behind
                w_cap = self.arbiter.grant_warmup(
                    self.profile.prefill_s(len(prompt) - matched),
                    self._last_demand_s, req.pool_device,
                    int(plan.idx.shape[1]))
                plan = cap_warmup(plan, w_cap)
            if plan is not None:
                hot, n_ins = self._warm(
                    self.state["hot_buf"], self.state["kv_pool"],
                    jnp.int32(s), plan.idx, plan.valid)
                self.state["hot_buf"] = hot
                n_ins = int(n_ins)
                if n_ins:
                    # deliberately UNkeyed: warm seeds cannot have
                    # been demand-hit yet, so keying them would book
                    # (n_ins, 0) against the request and tank its
                    # precision right at its first grants — the
                    # cold-start starvation the weighting must avoid
                    self.sac.traffic.record_prefetch(n_ins, 0)
                    self.sac.prefetch_fetch_time(
                        n_ins, device=req.pool_device)
        self.slot_req[s] = req
        self.slot_tokens[s] = [int(prompt[-1])]

    def _requeue_unplaceable(self, req: Request):
        """Pool exhausted even after radix eviction.  The pre-PR 5
        fallback charged device 0 for a booking that never happened
        (its link then carried a phantom request); instead requeue at
        the head (FCFS) and retry once a finishing request frees pages
        — unless nothing is in flight anywhere (no decoding slot, no
        chunked job, no handoff), in which case capacity will never
        appear."""
        self.queue.insert(0, req)
        if (not any(r is not None for r in self.slot_req)
                and not self._prefill_inflight()):
            raise RuntimeError(
                f"request {req.request_id} "
                f"({req.context_len + req.output_len} tokens) can "
                "never be placed: every pool device lacks "
                "capacity even with the radix cache evicted")

    def _fill_slots(self) -> bool:
        """Admission + prefill scheduling for this step, gated on the
        virtual clock vs ``arrival_s`` in every mode.  Returns True
        when any prefill work progressed (slot filled, chunk advanced,
        lane started, or handoff adopted) — step() uses that to decide
        whether an empty batch may jump the clock to the next event.
        Mode dispatch goes through the shared :class:`PrefillSchedule`
        (serving/policy/prefill.py), the same object the replay's
        ``fill()`` reads."""
        self._shed_waiting()
        if self.prefill_schedule.disagg:
            adopted = self._adopt_handoffs()
            started = self._start_prefill_lanes()
            return adopted or started
        if self.prefill_schedule.chunked:
            created = self._create_chunk_jobs()
            advanced = self._advance_chunk_jobs()
            return created or advanced
        # monolithic colocated: the seed path + the arrival gate
        progressed = False
        for s in range(self.slots):
            if self.slot_req[s] is not None:
                continue
            eligible = self._eligible_indices()
            if not eligible:
                break
            req = self.queue.pop(self._pick_queue_index(eligible))
            job = self._admit_request(req)
            if job is None:
                self._requeue_unplaceable(req)
                break
            issued0 = self.stats.traffic.fabric_time_s
            # charge the pool write for the NON-matched tokens only (the
            # matched pages' KV is copied device-locally from the cached
            # prefix, never crossing the fabric), against the request's
            # own pool link — the arbiter's demand signal must see
            # prefill pressure on the device it actually loads
            self.sac.write_back_time(job.effective,
                                     device=req.pool_device,
                                     key=req.request_id)
            self._complete_prefill(s, job)
            # virtual clock: prefill compute — a genuine radix hit skips
            # the matched prefix's recompute, so the modeled prefill (and
            # with it TTFT) shortens; fill-time fabric traffic (pool
            # write + warm-up) hides behind it when overlap is on
            t_prefill = self.profile.prefill_s(job.effective)
            if self.overlap_on:
                exposed = self.sac.traffic.drain_overlap(t_prefill)
            else:
                exposed = self.stats.traffic.fabric_time_s - issued0
            self.clock_s += t_prefill + exposed
            progressed = True
        return progressed

    def _create_chunk_jobs(self) -> bool:
        """Chunked colocated admission: bind an arrived request to each
        free slot as an in-flight job — no compute, no fabric charge
        yet (the chunks pay as they run in _advance_chunk_jobs)."""
        progressed = False
        for s in range(self.slots):
            if self.slot_req[s] is not None or self._jobs[s] is not None:
                continue
            eligible = self._eligible_indices()
            if not eligible:
                break
            req = self.queue.pop(self._pick_queue_index(eligible))
            job = self._admit_request(req)
            if job is None:
                self._requeue_unplaceable(req)
                break
            self._jobs[s] = job
            progressed = True
        return progressed

    def _advance_chunk_jobs(self) -> bool:
        """Advance every in-flight chunked prefill by ONE bounded chunk:
        the chunk's compute plus its pool-write tail advance the clock,
        so a decode step is delayed by one chunk, never a whole prompt.
        A job whose last chunk lands splices and decodes this same step
        — with chunk >= prompt this reduces exactly to the monolithic
        path (same charges, same clock advances, same order), and the
        deferred splice keeps decoded tokens independent of the chunk
        schedule."""
        progressed = False
        for s in range(self.slots):
            job = self._jobs[s]
            if job is None:
                continue
            take = self.prefill_schedule.chunk_take(
                job.effective - job.done_tokens)
            issued0 = self.stats.traffic.fabric_time_s
            if take > 0:
                self.sac.write_back_time(take, device=job.req.pool_device,
                                         key=job.req.request_id)
                job.done_tokens += take
            if job.done_tokens >= job.effective:
                self._jobs[s] = None
                self._complete_prefill(s, job)
            t_chunk = self.profile.prefill_s(take)
            if self.overlap_on:
                exposed = self.sac.traffic.drain_overlap(t_chunk)
            else:
                exposed = self.stats.traffic.fabric_time_s - issued0
            self.clock_s += t_chunk + exposed
            progressed = True
        return progressed

    def _start_prefill_lanes(self) -> bool:
        """The disaggregated prefill engine's loop: assign arrived
        requests to free lanes on the shared wall clock.  The lane pays
        the (radix-shortened) prefill compute and the full pool write
        on the fabric route NOW — prefill writes KV to the pool device
        exactly as the colocated path charges it — and the handoff
        record becomes adoptable by the decode loop at ``ready_s``."""
        progressed = False
        for lane in range(self.prefill_lanes):
            if self._lane_busy[lane] > self.clock_s + 1e-12:
                continue
            eligible = self._eligible_indices()
            if not eligible:
                break
            req = self.queue.pop(self._pick_queue_index(eligible))
            job = self._admit_request(req)
            if job is None:
                self._requeue_unplaceable(req)
                break
            issued0 = self.stats.traffic.fabric_time_s
            self.sac.write_back_time(job.effective,
                                     device=req.pool_device,
                                     key=req.request_id)
            t_prefill = self.profile.prefill_s(job.effective)
            if self.overlap_on:
                exposed = self.sac.traffic.drain_overlap(t_prefill)
            else:
                exposed = self.stats.traffic.fabric_time_s - issued0
            job.ready_s = self.clock_s + t_prefill + exposed
            self._lane_busy[lane] = job.ready_s
            self._handoffs.append(job)
            progressed = True
        return progressed

    def _adopt_handoffs(self) -> bool:
        """Decode-side adoption (disagg): splice the earliest-ready
        handoff into each free slot.  The prefill compute was already
        paid on its lane (``ready_s``); adoption pays only the warm-up
        burst's fabric tail (hidden behind the next decode step when
        overlap is on), so decode TBT never stalls on a prompt."""
        progressed = False
        for s in range(self.slots):
            if self.slot_req[s] is not None:
                continue
            ready = [h for h in self._handoffs
                     if h.ready_s <= self.clock_s + 1e-12]
            if not ready:
                break
            job = min(ready, key=lambda h: (h.ready_s, h.req.request_id))
            self._handoffs.remove(job)
            issued0 = self.stats.traffic.fabric_time_s
            self._complete_prefill(s, job)
            if not self.overlap_on:
                self.clock_s += (self.stats.traffic.fabric_time_s
                                 - issued0)
            progressed = True
        return progressed

    def _splice_state(self, slot: int, st_one: Dict, length: int):
        """Copy a 1-batch prefill state into slot ``slot`` of the engine
        state (padding the sequence axis up to max_ctx).  Dispatch is
        key-aware: pools are [L, B, S, d] (batch axis 1, padded S),
        cache lengths are [B], recurrent states have a unique axis where
        dst == slots and src == 1.  The hot buffer has no prefill
        counterpart — the slot's lane is simply reset (a fresh request
        starts cold; its pool pages are being overwritten) and then
        optionally re-seeded by the warm-up plan."""
        def splice_pool(dst, src):
            pad = dst.shape[2] - src.shape[2]
            if pad:
                padding = [(0, 0)] * src.ndim
                padding[2] = (0, pad)
                src = jnp.pad(src, padding)
            return dst.at[:, slot].set(src[:, 0])

        def splice_rec(dst, src):
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slot
                    src_idx = [slice(None)] * src.ndim
                    src_idx[ax] = 0
                    return dst.at[tuple(idx)].set(src[tuple(src_idx)])
            return dst

        new_state = dict(self.state)
        for key, dst in self.state.items():
            if key == "hot_buf":
                new_state[key] = hisparse.reset_lane(dst, slot)
                continue
            if key in ("buf_hits", "buf_misses", "pf_inserted", "pf_useful"):
                new_state[key] = dst.at[slot].set(0)
                continue
            if key in ("buf_hits_l", "buf_misses_l"):   # [L, B] layouts
                new_state[key] = dst.at[:, slot].set(0)
                continue
            src = st_one[key]
            if key in ("kv_pool", "idx_pool", "self_kv"):
                new_state[key] = splice_pool(dst, src)
            elif key in ("cache_len", "dec_len"):
                new_state[key] = dst.at[slot].set(src[0])
            else:  # rec_* pytrees
                new_state[key] = jax.tree.map(splice_rec, dst, src)
        self.state = new_state

    # -- stepping -----------------------------------------------------------------
    def step(self, now: Optional[float] = None) -> List[Request]:
        """One decode step for all occupied slots; returns finished reqs.

        ``now`` defaults to the engine's virtual clock (advanced by the
        modeled compute + exposed fabric of this step); passing an
        explicit value only overrides the request timestamps."""
        clock0 = self.clock_s       # a slot decoding through this step
                                    # sees the WHOLE step() wall time —
                                    # chunk stalls included — as its gap
        progressed = self._fill_slots()
        if not any(r is not None for r in self.slot_req):
            # no decodable slot.  If admission made no progress either,
            # the engine is idle before the next event (a future arrival
            # or a disagg handoff completing) — jump the virtual clock
            # to it and retry admission, so open-loop gaps cost wall
            # time but never spin the step counter.
            if not progressed:
                nxt = self._next_event_s()
                if nxt is not None and nxt > self.clock_s:
                    self.clock_s = nxt
                    self._fill_slots()
            if not any(r is not None for r in self.slot_req):
                return []
        tokens = jnp.array(
            [(toks[-1] if toks else 0) for toks in self.slot_tokens],
            jnp.int32)
        prev_len = np.asarray(self.state["cache_len"])
        occupied = [s for s in range(self.slots) if self.slot_req[s]]
        t_comp = self.step_compute_s(len(occupied))
        # replica-aware read choice (PR 7): slot -> (read device, prefix
        # read fraction).  Re-evaluated every step from the bottleneck-
        # projected pressure feed — the copy choice is NOT frozen at
        # placement.  With replica_reads off this is (own device, 0.0)
        # and everything below is bit-identical to the flat path.
        reads: Dict[int, tuple] = {}
        pres = (list(self.sac.placer.device_pressure())
                if self.replica_reads_on else None)
        # within-step booking: charge each slot's expected step demand
        # onto its chosen devices as reads are assigned — the pressure
        # feed refreshes only between steps, so without it every reader
        # of a hot prefix herds onto the same least-pressured copy each
        # step (the simulator twin books the same way)
        est_s = (self.cfg.sac.topk * self.sac.entry_bytes
                 / self.sac.fabric.bandwidth_Bps)
        for s in occupied:
            own = self.sac.device_of(self.slot_req[s].request_id)
            copies, frac = self._slot_prefix[s]
            rd = own
            if pres is not None and copies and frac > 0.0:
                cands = sorted(set(copies) | {own})
                rd = min(cands,
                         key=lambda d: (pres[d] if d < len(pres) else 0.0,
                                        d))
            if rd == own:
                frac = 0.0
            else:
                self.stats.replica_redirects += 1
            if pres is not None:
                if rd < len(pres):
                    pres[rd] += frac * est_s
                if own < len(pres):
                    pres[own] += (1.0 - frac) * est_s
            reads[s] = (own, rd, frac)
        if self.arbiter is not None:
            # cross-request budget arbitration: last step's measured
            # per-device demand backlog shapes this step's speculation;
            # with precision weighting on, each slot's measured prefetch
            # precision (per-request TrafficStats attribution) tilts its
            # share of the device budget
            dev_slots: Dict[int, List[int]] = {}
            precision = None
            if self.arbiter.cfg.precision_weighted:
                precision = {}
            for s in occupied:
                req = self.slot_req[s]
                # group under the slot's READ device: a replica-
                # redirected slot's granted fetches flow on the chosen
                # copy's path, so its budget must be consumed there
                dev_slots.setdefault(reads[s][1], []).append(s)
                if precision is not None:
                    precision[s] = self.stats.traffic.request_precision(
                        req.request_id)
            self.last_grants = self.arbiter.grant(
                t_comp, self._last_demand_s, dev_slots,
                precision=precision)
            budgets = np.zeros((self.slots,), np.int32)
            for s, w in self.last_grants.items():
                budgets[s] = w
                self._grant_sum += w
                self._grant_n += 1
            self.state, logits = self._decode(
                self.params, self.state, tokens, jnp.asarray(budgets))
        else:
            self.state, logits = self._decode(self.params, self.state,
                                              tokens)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        self.stats.steps += 1
        # the first decode step closes the PR 7 warm-up seeding window:
        # the tracker's first observe() below includes the warm-up
        # traffic, so leaving the seed on would double-count it
        self.warm_seed.deactivate()

        # fabric accounting per occupied slot
        issued0 = self.stats.traffic.fabric_time_s
        if self.cfg.sac.enabled and self.model.mode == "sac":
            if self.device_buffer:
                # miss-only charging: the jitted step measured per-slot
                # hot-tier residency; only misses cross the fabric
                hits = np.asarray(self.state["buf_hits"])
                misses = np.asarray(self.state["buf_misses"])
                # per-layer split (LayerSizer miss-rate signal)
                self.stats.layer_hits += \
                    np.asarray(self.state["buf_hits_l"])[:, occupied].sum(1)
                self.stats.layer_misses += \
                    np.asarray(self.state["buf_misses_l"])[:, occupied] \
                    .sum(1)
                if self.prefetch:
                    pf_ins = np.asarray(self.state["pf_inserted"])
                    pf_use = np.asarray(self.state["pf_useful"])
                for s in occupied:
                    req = self.slot_req[s]
                    dev, read_dev, frac = reads[s]
                    self.sac.traffic.record_hits(int(hits[s]),
                                                 int(misses[s]))
                    n_miss = int(misses[s])
                    if n_miss:
                        # keyed: the request's own demand share, so the
                        # pressure feed can subtract it at departure.
                        # The prefix-region share of the misses reads
                        # the step's chosen replica copy; the rest stays
                        # on the slot's own device (frac == 0 charges
                        # everything there — the flat path, unchanged).
                        n_pfx = min(int(round(n_miss * frac)), n_miss)
                        if n_pfx:
                            self.sac.sparse_fetch_time(
                                n_pfx, device=read_dev,
                                key=req.request_id)
                        if n_miss - n_pfx:
                            self.sac.sparse_fetch_time(
                                n_miss - n_pfx, device=dev,
                                key=req.request_id)
                    if self.prefetch:
                        # measured speculation outcomes (in-graph pf_*
                        # counters): issued entries cross the fabric as
                        # prefetch traffic; useful ones were demand hits.
                        # Keyed by request so the arbiter's precision
                        # weighting sees per-request precision.  Charged
                        # to the READ device — the same path the grant
                        # that authorized these entries was budgeted on.
                        self.sac.traffic.record_prefetch(
                            int(pf_ins[s]), int(pf_use[s]),
                            key=req.request_id)
                        if int(pf_ins[s]):
                            self.sac.prefetch_fetch_time(int(pf_ins[s]),
                                                         device=read_dev)
            else:
                # cold-read convention: every step is charged the full
                # top-k transfer per layer
                k = min(self.cfg.sac.topk, self.max_ctx)
                n_layers = max(getattr(self.model, "n_kv", 1), 1)
                for s in occupied:
                    req = self.slot_req[s]
                    n = min(k * n_layers, int(prev_len[s]) * n_layers or 1)
                    self.sac.sparse_fetch_time(
                        n, device=self.sac.device_of(req.request_id),
                        key=req.request_id)
        # issued vs exposed: drain the per-device queues against this
        # step's compute window (exposed == issued when overlap is off)
        if self.overlap_on:
            exposed = self.sac.traffic.drain_overlap(t_comp)
        else:
            exposed = self.stats.traffic.fabric_time_s - issued0
        # arbiter feedback: snapshot this step's per-device demand-only
        # issued seconds (total minus prefetch) as next step's pressure
        # (also the pressure_aware placer's live feed) — tracked per
        # REQUEST too, so a departure below subtracts its own share
        self._demand.observe(
            self.stats.traffic,
            [self.slot_req[s].request_id for s in occupied])
        self.sac.note_pressure_update()
        # online LayerSizer re-sizing: every resize_interval steps the
        # measured per-layer miss rates re-apportion the hot tier by
        # re-marking the DISABLED sentinels in place — displaced entries
        # are evicted, resident ones survive, tokens never change.  The
        # sizer consumes the rates of THIS interval (deltas against the
        # last resize's snapshot), not lifetime averages — a lifetime
        # signal goes stale after the first resize or a demand shift and
        # the loop would stop adapting.
        if (self._sizer is not None and self.resize_interval
                and self.stats.steps % self.resize_interval == 0):
            rates = self._interval_miss_rates()
            # hysteresis (cfg.sac.resize_epsilon): when no layer's
            # per-interval miss rate moved by more than epsilon since
            # the last sizer evaluation, skip the run entirely — a
            # stable workload stops churning DISABLED sentinels every
            # interval, while slow drift accumulates against the kept
            # reference until it crosses the epsilon
            eps = float(self.cfg.sac.resize_epsilon)
            if (eps > 0.0 and rates is not None
                    and self._resize_rates_ref is not None
                    and len(rates) == len(self._resize_rates_ref)
                    and max(abs(r - p) for r, p in
                            zip(rates, self._resize_rates_ref)) < eps):
                self.stats.resize_skips += 1
            else:
                new_sizes = self._sizer.sizes(rates)
                self._resize_rates_ref = rates
                if new_sizes != list(self.buffer_sizes):
                    self.stats.resizes += 1
                    self.state = dict(self.state)
                    self.state["hot_buf"] = hisparse.resize_layers(
                        self.state["hot_buf"], new_sizes)
                    self.buffer_sizes = new_sizes
        self.clock_s += t_comp + exposed
        if now is None:
            now = self.clock_s

        finished = []
        for s in occupied:
            req = self.slot_req[s]
            self.slot_tokens[s].append(int(next_tokens[s]))
            req.generated += 1
            if req.first_token_s < 0:
                req.first_token_s = now
            else:
                req.tbt_max_s = max(req.tbt_max_s,
                                    self.clock_s - clock0)
            self.stats.tokens += 1
            if req.generated >= req.output_len:
                req.finish_s = now
                # decoded stream only — slot_tokens[0] is the seeded
                # last prompt token, not a generated one
                req.out_tokens = self.slot_tokens[s][1:]
                finished.append(req)
                dev = self.sac.device_of(req.request_id)
                # radix lifecycle at departure: unpin the request's
                # prefix path, retain the pages the index registered
                # (ownership moves request -> cache), free the rest —
                # sac.release purges anything it frees from the index,
                # so a stale (device, pages) can never be matched
                pins, keep = self._slot_radix[s]
                if self.radix is not None:
                    for p in pins:
                        self.radix.release(p)
                self._slot_radix[s] = ([], 0)
                self._slot_prefix[s] = ((), 0.0)
                kept = self.sac.release(req.request_id, keep_pages=keep)
                if kept and self.cfg.sac.radix_headroom_frac > 0:
                    # pool page pressure: push the LRU tail of the cache
                    # back to the allocator before admissions need it
                    self.sac.evict_to_headroom(
                        self.cfg.sac.radix_headroom_frac)
                # pressure feedback: subtract the departing request's
                # own measured demand share from its link immediately
                # (per-request attribution) instead of letting the
                # placement EMA decay it over the next snapshots
                share = self._demand.depart(req.request_id, dev)
                self.sac.note_departure(dev, share)
                # the per-request prefetch attribution is an arbitration
                # signal, not a report — drop it with the request
                self.stats.traffic.drop_request(req.request_id)
                self.slot_req[s] = None
                self.slot_tokens[s] = []
                # reset this slot's cache length so the next request starts
                # fresh (pool pages are overwritten by the next prefill)
                self.state["cache_len"] = \
                    self.state["cache_len"].at[s].set(0)
        # cumulative, from the SACSystem: includes the evictions place()
        # performed under admission pressure, which a finish-time-only
        # tally would miss
        self.stats.radix_evicted_pages = self.sac.radix_evicted_pages
        return finished

    def run(self, requests: List[Request], *, max_steps: int = 10_000,
            slo_ttft_s: float = 0.0, slo_tbt_s: float = 0.0
            ) -> Dict[str, float]:
        for r in requests:
            self.submit(r)
        done = 0
        while done < len(requests) and self.stats.steps < max_steps:
            finished = self.step()
            done += len(finished)
            if (not finished and not any(self.slot_req)
                    and not self.queue and not self._prefill_inflight()):
                break
        out = summarize(requests, slo_ttft_s=slo_ttft_s,
                        slo_tbt_s=slo_tbt_s)
        out.update(engine_steps=self.stats.steps,
                   engine_tokens=self.stats.tokens,
                   radix_hit_tokens=self.stats.radix_hit_tokens,
                   radix_hit_requests=self.stats.radix_hit_requests,
                   bytes_written=self.stats.traffic.bytes_written,
                   fabric_time_s=self.stats.fabric_time_s,
                   issued_fabric_s=self.stats.issued_fabric_s,
                   exposed_fabric_s=self.stats.exposed_fabric_s,
                   buffer_hits=self.stats.buffer_hits,
                   buffer_misses=self.stats.buffer_misses,
                   buffer_hit_rate=self.stats.hit_rate,
                   prefetched_entries=self.stats.prefetched_entries,
                   prefetch_useful=self.stats.prefetch_useful,
                   prefetch_wasted=self.stats.prefetch_wasted,
                   prefetch_precision=self.stats.prefetch_precision,
                   replicated_pages=self.sac.replicated_pages,
                   dedup_shared_pages=self.sac.dedup_shared_pages,
                   replica_redirects=self.stats.replica_redirects,
                   shed_requests=self.stats.shed_requests,
                   spec_yielded_s=self.stats.traffic.spec_yielded_s,
                   critical_demand_bytes=(
                       self.sac.traffic.stats.critical_demand_bytes),
                   critical_issued_s=(
                       self.sac.traffic.stats.critical_issued_s),
                   pool_bytes_per_req=(self.sac.booked_pages_cum
                                       * self.sac.page_bytes
                                       / max(len(requests), 1)))
        if self.arbiter is not None:
            out["arbiter_width_mean"] = (self._grant_sum / self._grant_n
                                         if self._grant_n else 0.0)
        return out
