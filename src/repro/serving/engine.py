"""Decode engine: continuous batching over the SAC cache — the *real*
JAX serving path (compiled prefill/decode steps + host-side SACSystem
bookkeeping), runnable end-to-end on CPU with reduced configs.

This is the functional counterpart of the simulator: the simulator
answers "what would the cluster do", the engine actually *does* it for
small models — real top-k selection, real pool reads/writes, real radix
prefix reuse, and fabric-time accounting via core.transfer (cold-read
convention: every step is charged the full top-k transfer; the HiSparse
hot-buffer saving is modeled in the simulator, grounded against the
functional buffer in tests/test_hisparse.py::test_hit_rate_grounding).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hisparse
from repro.core.sac import SACSystem
from repro.models.model import build_model
from repro.serving.radix import RadixIndex
from repro.serving.request import Request, summarize
from repro.serving.scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    pool_entries_fetched: int = 0
    buffer_hits: int = 0
    buffer_misses: int = 0
    radix_hit_tokens: int = 0
    fabric_time_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        tot = self.buffer_hits + self.buffer_misses
        return self.buffer_hits / tot if tot else 0.0


class Engine:
    """Fixed-slot continuous batching engine.

    ``slots`` requests decode together in one compiled step; finished
    slots are refilled from the queue (prefill on demand, with radix
    prefix reuse).  The pool state is the serve_state pytree of
    models/transformer.py; per-slot independence is guaranteed by the
    batch dimension.
    """

    def __init__(self, cfg: ModelConfig, *, slots: int = 4,
                 max_ctx: int = 256, backend: str = "cxl",
                 mode: str = "sac", track_buffer: bool = True, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.max_ctx = max_ctx
        self.model = build_model(cfg, mode=mode)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.sac = SACSystem(cfg, backend=backend)
        self.radix = RadixIndex(page_size=cfg.sac.page_size)
        self.stats = EngineStats()

        self._decode = jax.jit(self.model.decode)
        self._prefill_one = jax.jit(
            lambda p, toks: self.model.prefill(p, toks))
        self.state = self.model.init_serve_state(slots, max_ctx)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_tokens: List[List[int]] = [[] for _ in range(slots)]
        self.queue: List[Request] = []

    # -- submission --------------------------------------------------------------
    def submit(self, req: Request):
        assert req.prompt_tokens is not None, "engine needs real tokens"
        assert req.context_len + req.output_len <= self.max_ctx, \
            "request exceeds engine max_ctx"
        self.queue.append(req)

    # -- slot refill -------------------------------------------------------------
    def _fill_slots(self, now: float):
        for s in range(self.slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            req.dispatch_s = now
            prompt = req.prompt_tokens[: req.context_len]
            # radix prefix lookup (page-aligned reuse accounting)
            matched, _ = self.radix.match_prefix(prompt.tolist())
            self.stats.radix_hit_tokens += matched
            rp = self.sac.place(req.request_id, len(prompt) + req.output_len)
            req.pool_device = rp.device if rp else 0
            # prefill this slot (batch of 1), splice into the shared state
            st, _ = self._prefill_one(self.params, prompt[None, :])
            self._splice_state(s, st, len(prompt))
            # charge the pool write (prefill write path)
            self.stats.fabric_time_s += self.sac.write_back_time(len(prompt))
            page_tokens = (len(prompt) // self.cfg.sac.page_size) \
                * self.cfg.sac.page_size
            if page_tokens:
                self.radix.insert(prompt[:page_tokens].tolist(),
                                  req.pool_device,
                                  list(range(page_tokens
                                             // self.cfg.sac.page_size)))
            self.slot_req[s] = req
            self.slot_tokens[s] = [int(prompt[-1])]

    def _splice_state(self, slot: int, st_one: Dict, length: int):
        """Copy a 1-batch prefill state into slot ``slot`` of the engine
        state (padding the sequence axis up to max_ctx).  Dispatch is
        key-aware: pools are [L, B, S, d] (batch axis 1, padded S),
        cache lengths are [B], recurrent states have a unique axis where
        dst == slots and src == 1."""
        def splice_pool(dst, src):
            pad = dst.shape[2] - src.shape[2]
            if pad:
                padding = [(0, 0)] * src.ndim
                padding[2] = (0, pad)
                src = jnp.pad(src, padding)
            return dst.at[:, slot].set(src[:, 0])

        def splice_rec(dst, src):
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slot
                    src_idx = [slice(None)] * src.ndim
                    src_idx[ax] = 0
                    return dst.at[tuple(idx)].set(src[tuple(src_idx)])
            return dst

        new_state = dict(self.state)
        for key, dst in self.state.items():
            src = st_one[key]
            if key in ("kv_pool", "idx_pool", "self_kv"):
                new_state[key] = splice_pool(dst, src)
            elif key in ("cache_len", "dec_len"):
                new_state[key] = dst.at[slot].set(src[0])
            else:  # rec_* pytrees
                new_state[key] = jax.tree.map(splice_rec, dst, src)
        self.state = new_state

    # -- stepping -----------------------------------------------------------------
    def step(self, now: float = 0.0) -> List[Request]:
        """One decode step for all occupied slots; returns finished reqs."""
        self._fill_slots(now)
        if not any(r is not None for r in self.slot_req):
            return []
        tokens = jnp.array(
            [(toks[-1] if toks else 0) for toks in self.slot_tokens],
            jnp.int32)
        prev_len = np.asarray(self.state["cache_len"])
        self.state, logits = self._decode(self.params, self.state, tokens)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        self.stats.steps += 1

        # fabric accounting: each occupied slot fetched k entries per layer
        occupied = [s for s in range(self.slots) if self.slot_req[s]]
        if self.cfg.sac.enabled and self.model.mode == "sac":
            k = min(self.cfg.sac.topk, self.max_ctx)
            n_layers = max(getattr(self.model, "n_kv", 1), 1)
            for s in occupied:
                n = k * n_layers
                self.stats.pool_entries_fetched += n
                self.stats.fabric_time_s += self.sac.sparse_fetch_time(
                    min(n, int(prev_len[s]) * n_layers or 1))

        finished = []
        for s in occupied:
            req = self.slot_req[s]
            self.slot_tokens[s].append(int(next_tokens[s]))
            req.generated += 1
            if req.first_token_s < 0:
                req.first_token_s = now
            self.stats.tokens += 1
            if req.generated >= req.output_len:
                req.finish_s = now
                finished.append(req)
                self.sac.release(req.request_id)
                self.slot_req[s] = None
                self.slot_tokens[s] = []
                # reset this slot's cache length so the next request starts
                # fresh (pool pages are overwritten by the next prefill)
                self.state["cache_len"] = \
                    self.state["cache_len"].at[s].set(0)
        return finished

    def run(self, requests: List[Request], *, max_steps: int = 10_000
            ) -> Dict[str, float]:
        for r in requests:
            self.submit(r)
        t0 = time.time()
        done = 0
        while done < len(requests) and self.stats.steps < max_steps:
            finished = self.step(now=time.time() - t0)
            done += len(finished)
            if not finished and not any(self.slot_req) and not self.queue:
                break
        out = summarize(requests)
        out.update(engine_steps=self.stats.steps,
                   engine_tokens=self.stats.tokens,
                   radix_hit_tokens=self.stats.radix_hit_tokens,
                   fabric_time_s=self.stats.fabric_time_s)
        return out
