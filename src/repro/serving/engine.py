"""Decode engine: continuous batching over the SAC cache — the *real*
JAX serving path (compiled prefill/decode steps + host-side SACSystem
bookkeeping), runnable end-to-end on CPU with reduced configs.

This is the functional counterpart of the simulator: the simulator
answers "what would the cluster do", the engine actually *does* it for
small models — real top-k selection, real pool reads/writes, real radix
prefix reuse, and the real HiSparse hot buffer (core/hisparse.py) wired
into the jitted decode step.  With the buffer enabled (default), every
step's top-k reads go through the in-graph read-through: decoded tokens
are bit-identical to the buffer-off path, but residency is *measured*,
and only misses are charged to the fabric (paper §5.5 miss-only
traffic).  ``EngineStats.buffer_hits/buffer_misses`` are therefore live
numbers, grounded against the simulator's analytic ``hit_rate()`` model
in tests/test_engine_buffer.py.

Placement and traffic accounting go through the shared substrate
(core/placement.py, core/traffic.py): the engine's ``SACSystem`` places
each request's pool pages with the same policy the scheduler and
simulator use, and charges fetch/write traffic to the same
``TrafficStats`` schema the simulator reports.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hisparse
from repro.core.sac import SACSystem
from repro.core.traffic import TrafficStats
from repro.models.model import build_model
from repro.serving.radix import RadixIndex
from repro.serving.request import Request, summarize
from repro.serving.scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class EngineStats:
    """Engine counters; fabric traffic lives in the shared TrafficStats
    schema (the same object the engine's SACSystem accountant charges)."""

    steps: int = 0
    tokens: int = 0
    pool_entries_fetched: int = 0      # entries that crossed the fabric
    radix_hit_tokens: int = 0
    traffic: TrafficStats = dataclasses.field(default_factory=TrafficStats)

    @property
    def buffer_hits(self) -> int:
        return int(self.traffic.buffer_hits)

    @property
    def buffer_misses(self) -> int:
        return int(self.traffic.buffer_misses)

    @property
    def fabric_time_s(self) -> float:
        return self.traffic.fabric_time_s

    @property
    def hit_rate(self) -> float:
        return self.traffic.hit_rate


class Engine:
    """Fixed-slot continuous batching engine.

    ``slots`` requests decode together in one compiled step; finished
    slots are refilled from the queue (prefill on demand, with radix
    prefix reuse).  The pool state is the serve_state pytree of
    models/transformer.py; per-slot independence is guaranteed by the
    batch dimension.

    ``track_buffer`` wires the HiSparse hot buffer into the decode step
    (``device_buffer`` entries per layer per slot, default
    ``cfg.sac.device_buffer_size``); fabric time is then charged on
    measured misses only.  Off, every step is charged the full cold-read
    top-k transfer.
    """

    def __init__(self, cfg: ModelConfig, *, slots: int = 4,
                 max_ctx: int = 256, backend: str = "cxl",
                 mode: str = "sac", track_buffer: bool = True,
                 device_buffer: Optional[int] = None,
                 topk_fn=None, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.max_ctx = max_ctx
        # topk_fn overrides the indexer's top-k selection inside the jitted
        # step (scores, cache_len) -> (idx, valid); used by parity tests to
        # replay controlled top-k traces through the real buffer wiring
        self.model = build_model(cfg, mode=mode, topk_fn=topk_fn)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.sac = SACSystem(cfg, backend=backend)
        self.radix = RadixIndex(page_size=cfg.sac.page_size)
        # the engine's stats share the SACSystem accountant's TrafficStats:
        # every charged fetch/write and recorded hit/miss lands here
        self.stats = EngineStats(traffic=self.sac.traffic.stats)
        self.device_buffer = 0
        if (track_buffer and cfg.sac.enabled and not cfg.enc_dec
                and self.model.mode == "sac"):
            self.device_buffer = (cfg.sac.device_buffer_size
                                  if device_buffer is None else device_buffer)

        self._decode = jax.jit(self.model.decode)
        self._prefill_one = jax.jit(
            lambda p, toks: self.model.prefill(p, toks))
        self.state = self.model.init_serve_state(
            slots, max_ctx, device_buffer=self.device_buffer)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_tokens: List[List[int]] = [[] for _ in range(slots)]
        self.queue: List[Request] = []

    # -- submission --------------------------------------------------------------
    def submit(self, req: Request):
        assert req.prompt_tokens is not None, "engine needs real tokens"
        assert req.context_len + req.output_len <= self.max_ctx, \
            "request exceeds engine max_ctx"
        self.queue.append(req)

    # -- slot refill -------------------------------------------------------------
    def _fill_slots(self, now: float):
        for s in range(self.slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            req.dispatch_s = now
            prompt = req.prompt_tokens[: req.context_len]
            # radix prefix lookup (page-aligned reuse accounting)
            matched, _ = self.radix.match_prefix(prompt.tolist())
            self.stats.radix_hit_tokens += matched
            rp = self.sac.place(req.request_id, len(prompt) + req.output_len)
            req.pool_device = rp.device if rp else 0
            # prefill this slot (batch of 1), splice into the shared state
            st, _ = self._prefill_one(self.params, prompt[None, :])
            self._splice_state(s, st, len(prompt))
            # charge the pool write (prefill write path)
            self.sac.write_back_time(len(prompt))
            page_tokens = (len(prompt) // self.cfg.sac.page_size) \
                * self.cfg.sac.page_size
            if page_tokens:
                self.radix.insert(prompt[:page_tokens].tolist(),
                                  req.pool_device,
                                  list(range(page_tokens
                                             // self.cfg.sac.page_size)))
            self.slot_req[s] = req
            self.slot_tokens[s] = [int(prompt[-1])]

    def _splice_state(self, slot: int, st_one: Dict, length: int):
        """Copy a 1-batch prefill state into slot ``slot`` of the engine
        state (padding the sequence axis up to max_ctx).  Dispatch is
        key-aware: pools are [L, B, S, d] (batch axis 1, padded S),
        cache lengths are [B], recurrent states have a unique axis where
        dst == slots and src == 1.  The hot buffer has no prefill
        counterpart — the slot's lane is simply reset (a fresh request
        starts cold; its pool pages are being overwritten)."""
        def splice_pool(dst, src):
            pad = dst.shape[2] - src.shape[2]
            if pad:
                padding = [(0, 0)] * src.ndim
                padding[2] = (0, pad)
                src = jnp.pad(src, padding)
            return dst.at[:, slot].set(src[:, 0])

        def splice_rec(dst, src):
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slot
                    src_idx = [slice(None)] * src.ndim
                    src_idx[ax] = 0
                    return dst.at[tuple(idx)].set(src[tuple(src_idx)])
            return dst

        new_state = dict(self.state)
        for key, dst in self.state.items():
            if key == "hot_buf":
                new_state[key] = hisparse.reset_lane(dst, slot)
                continue
            if key in ("buf_hits", "buf_misses"):
                new_state[key] = dst.at[slot].set(0)
                continue
            src = st_one[key]
            if key in ("kv_pool", "idx_pool", "self_kv"):
                new_state[key] = splice_pool(dst, src)
            elif key in ("cache_len", "dec_len"):
                new_state[key] = dst.at[slot].set(src[0])
            else:  # rec_* pytrees
                new_state[key] = jax.tree.map(splice_rec, dst, src)
        self.state = new_state

    # -- stepping -----------------------------------------------------------------
    def step(self, now: float = 0.0) -> List[Request]:
        """One decode step for all occupied slots; returns finished reqs."""
        self._fill_slots(now)
        if not any(r is not None for r in self.slot_req):
            return []
        tokens = jnp.array(
            [(toks[-1] if toks else 0) for toks in self.slot_tokens],
            jnp.int32)
        prev_len = np.asarray(self.state["cache_len"])
        self.state, logits = self._decode(self.params, self.state, tokens)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        self.stats.steps += 1

        # fabric accounting per occupied slot
        occupied = [s for s in range(self.slots) if self.slot_req[s]]
        if self.cfg.sac.enabled and self.model.mode == "sac":
            if self.device_buffer:
                # miss-only charging: the jitted step measured per-slot
                # hot-tier residency; only misses cross the fabric
                hits = np.asarray(self.state["buf_hits"])
                misses = np.asarray(self.state["buf_misses"])
                for s in occupied:
                    req = self.slot_req[s]
                    self.sac.traffic.record_hits(int(hits[s]),
                                                 int(misses[s]))
                    n_miss = int(misses[s])
                    self.stats.pool_entries_fetched += n_miss
                    if n_miss:
                        self.sac.sparse_fetch_time(
                            n_miss, device=self.sac.device_of(
                                req.request_id))
            else:
                # cold-read convention: every step is charged the full
                # top-k transfer per layer
                k = min(self.cfg.sac.topk, self.max_ctx)
                n_layers = max(getattr(self.model, "n_kv", 1), 1)
                for s in occupied:
                    req = self.slot_req[s]
                    n = min(k * n_layers, int(prev_len[s]) * n_layers or 1)
                    self.stats.pool_entries_fetched += n
                    self.sac.sparse_fetch_time(
                        n, device=self.sac.device_of(req.request_id))

        finished = []
        for s in occupied:
            req = self.slot_req[s]
            self.slot_tokens[s].append(int(next_tokens[s]))
            req.generated += 1
            if req.first_token_s < 0:
                req.first_token_s = now
            self.stats.tokens += 1
            if req.generated >= req.output_len:
                req.finish_s = now
                finished.append(req)
                self.sac.release(req.request_id)
                self.slot_req[s] = None
                self.slot_tokens[s] = []
                # reset this slot's cache length so the next request starts
                # fresh (pool pages are overwritten by the next prefill)
                self.state["cache_len"] = \
                    self.state["cache_len"].at[s].set(0)
        return finished

    def run(self, requests: List[Request], *, max_steps: int = 10_000
            ) -> Dict[str, float]:
        for r in requests:
            self.submit(r)
        t0 = time.time()
        done = 0
        while done < len(requests) and self.stats.steps < max_steps:
            finished = self.step(now=time.time() - t0)
            done += len(finished)
            if not finished and not any(self.slot_req) and not self.queue:
                break
        out = summarize(requests)
        out.update(engine_steps=self.stats.steps,
                   engine_tokens=self.stats.tokens,
                   radix_hit_tokens=self.stats.radix_hit_tokens,
                   fabric_time_s=self.stats.fabric_time_s,
                   buffer_hits=self.stats.buffer_hits,
                   buffer_misses=self.stats.buffer_misses,
                   buffer_hit_rate=self.stats.hit_rate)
        return out
