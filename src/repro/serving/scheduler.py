"""Request scheduler: admission control + pool-device interleaving.

Implements the paper's §4.3.3 dispatch policy through the shared
placement substrate (core/placement.py): a request's KV lives on ONE
pool device; the placer's round-robin policy spreads requests across
devices so concurrent GPU fetches spread over fabric links.  Admission
respects (a) the concurrency cap, (b) pool capacity (byte-granular,
enforced by the placer), (c) local-memory capacity (the RDMA baseline's
resident-KV constraint), and (d) HBM KV capacity (GPU-only baseline).
The max per-device queue imbalance is bounded by construction
(property-tested in tests/test_placement.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.core.placement import Placer, policy_for_interleave
from repro.serving.policy import (AdmissionPolicy, FCFSAdmission,
                                  RadixAdmission)
from repro.serving.request import Request


@dataclasses.dataclass
class SchedulerConfig:
    concurrency: int = 64
    n_pool_devices: int = 2
    interleave: bool = True
    placement: Optional[str] = None            # override policy by name
    pool_device_bytes: float = 256e9
    local_dram_bytes: float = float("inf")     # RDMA baseline constraint
    hbm_kv_bytes: float = float("inf")         # GPU-only baseline constraint
    bytes_per_token: float = 0.0               # KV bytes/token (all layers)
    topology: Optional[object] = None          # FabricTopology (PR 7): when
                                               # set, the pressure feed is
                                               # per-SEGMENT and the placer
                                               # projects it to per-device
                                               # bottleneck pressure


class Scheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.placer = Placer(
            cfg.n_pool_devices,
            policy=cfg.placement or policy_for_interleave(cfg.interleave),
            capacity_bytes=cfg.pool_device_bytes,
            topology=cfg.topology)
        self.local_bytes = 0.0
        self.hbm_bytes = 0.0
        self._affinity_fn = None
        self._admit_fn = None
        # admission policy (serving/policy/admission.py): the shared
        # arrival gate + queue ordering + shedding object — the same
        # classes the engine and the analytic replay construct
        self.admission: AdmissionPolicy = FCFSAdmission()
        # requests dropped by load shedding (EDF): removed from the
        # queue before admission, never dispatched
        self.shed_log: List[Request] = []
        # PR 6 dedup accounting: per-request booked bytes returned early
        # (refcount-shared with the cache) and the cumulative bytes ever
        # booked net of those shrinks — the simulator's pool-bytes-per-
        # request numerator, mirroring SACSystem.booked_pages_cum
        self._shrunk: Dict[int, float] = {}
        self.booked_bytes_cum = 0.0

    def set_pressure_fn(self, fn) -> None:
        """Attach the live per-device link-pressure feed consumed by the
        ``pressure_aware`` placement policy (core/placement.py) — the
        simulator wires its per-step analytic demand seconds in here, the
        same signal the engine feeds its own placer."""
        self.placer.set_pressure_fn(fn)

    def note_pressure_update(self) -> None:
        """Mark the pressure feed re-measured (once per simulated step)."""
        self.placer.note_pressure_update()

    def set_affinity_fn(self, fn) -> None:
        """Attach the radix-affinity resolver consumed at admission:
        ``fn(req) -> Optional[(device, saved_seconds)]`` — the device
        holding the request's cached prefix and the prefill/write
        seconds reuse there would save (the ``radix_affinity`` placement
        input, core/placement.py).  The simulator wires its analytic
        prefix cache in here; the engine threads its real RadixIndex
        match through ``SACSystem.place`` directly."""
        self._affinity_fn = fn

    def set_admit_fn(self, fn) -> None:
        """Callback invoked right after EACH successful placement inside
        ``try_admit`` (before the next request is placed).  The
        simulator's analytic radix twin registers a new prefix group
        here, so requests later in the same admission wave can already
        hit it — matching the engine, whose slot fills interleave
        insert with placement."""
        self._admit_fn = fn

    def set_admission_policy(self, policy: AdmissionPolicy) -> None:
        """Install the shared admission policy consumed by
        ``try_admit`` (arrival gate, queue ordering, load shedding) —
        the identical object family the engine wires into its
        ``_fill_slots``, so parity holds at the class level."""
        self.admission = policy

    def set_reuse_fn(self, fn) -> None:
        """Attach the radix-admission scorer ``fn(req) -> float`` (the
        request's expected prefix reuse, e.g. its page-granular match
        length against the current tree).  When set, ``try_admit``
        stable-sorts the wait queue by descending score each wave —
        requests sharing a hot prefix land together; ties keep FCFS
        order.  None restores pure FCFS.  Back-compat wrapper over
        :meth:`set_admission_policy`."""
        self.admission = (FCFSAdmission() if fn is None
                          else RadixAdmission(fn))

    def shrink_booking(self, req: Request, n_bytes: float) -> float:
        """Return part of an ACTIVE request's booking early (PR 6 page
        dedup twin: the matched prefix's bytes are refcount-shared with
        the cache, not privately held).  Shrinks the placer booking and
        the local/HBM tallies now, and remembers the amount so
        ``finish`` doesn't subtract it a second time.  Returns the
        bytes actually shrunk."""
        if req.request_id not in self.active or n_bytes <= 0:
            return 0.0
        got, _ = self.placer.shrink(req.request_id, n_bytes=n_bytes)
        if got:
            self._shrunk[req.request_id] = \
                self._shrunk.get(req.request_id, 0.0) + got
            self.local_bytes = max(0.0, self.local_bytes - got)
            self.hbm_bytes = max(0.0, self.hbm_bytes - got)
            self.booked_bytes_cum -= got
        return got

    def note_departure(self, device: int, seconds: float) -> None:
        """Forward a finished request's measured demand share to the
        placer's pressure-keyed policies (core/placement.py)."""
        if 0 <= device < self.cfg.n_pool_devices:
            self.placer.note_departure(device, seconds)

    # -- queueing --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _kv_bytes(self, req: Request) -> float:
        return (req.context_len + req.output_len) * self.cfg.bytes_per_token

    def try_admit(self, now_s: float) -> List[Request]:
        """Admit queued requests while resources allow, in the order the
        shared admission policy dictates (FCFS by default, descending
        expected reuse under radix admission, earliest deadline under
        EDF — the stable sort means the policy can only ever PROMOTE,
        never starve FCFS ties).  EDF load shedding drops the arrived
        backlog beyond ``shed_queue_depth`` onto ``shed_log`` first."""
        admitted = []
        drop = self.admission.shed(list(self.queue), now_s)
        if drop:
            q = list(self.queue)
            for i in reversed(drop):
                self.shed_log.append(q.pop(i))
            self.queue = deque(q)
        if len(self.queue) > 1:
            self.queue = deque(self.admission.order(list(self.queue)))
        while self.queue and len(self.active) < self.cfg.concurrency:
            req = self.queue[0]
            if not self.admission.arrived(req, now_s):
                # the arrival gate (PR 8) now lives ONCE in the shared
                # policy: simulate() only submits arrived requests, but
                # a caller driving try_admit directly must never see a
                # dispatch before arrival — the open-loop bug the
                # engine's _fill_slots had
                break
            need = self._kv_bytes(req)
            if self.local_bytes + need > self.cfg.local_dram_bytes:
                break                      # RDMA local-memory wall (P2)
            if self.hbm_bytes + need > self.cfg.hbm_kv_bytes:
                break                      # HBM capacity wall (fig 12)
            hint = (self._affinity_fn(req) if self._affinity_fn is not None
                    else None)
            aff_dev, aff_s = hint if hint is not None else (None, 0.0)
            dev = self.placer.place(req.request_id, n_bytes=need,
                                    affinity=aff_dev, affinity_s=aff_s)
            if dev is None:
                break                      # pool exhausted
            self.queue.popleft()
            req.pool_device = dev
            req.dispatch_s = now_s
            self.local_bytes += need
            self.hbm_bytes += need
            self.booked_bytes_cum += need
            self.active[req.request_id] = req
            admitted.append(req)
            if self._admit_fn is not None:
                self._admit_fn(req)
        return admitted

    def finish(self, req: Request) -> None:
        """Idempotent: a double finish (or a finish of a never-admitted
        request) must not decrement the byte accounting below truth or
        double-release the placer — guard on the active-table pop (the
        pre-PR 5 version unconditionally subtracted, so one duplicate
        finish corrupted ``local_bytes``/``hbm_bytes`` forever)."""
        if self.active.pop(req.request_id, None) is None:
            return
        # a dedup-shrunk booking already returned part of its bytes
        # (shrink_booking); subtracting the full need again would drive
        # the tallies below truth — the PR 6 half of the idempotence fix
        need = self._kv_bytes(req) - self._shrunk.pop(req.request_id, 0.0)
        self.placer.release(req.request_id)
        self.local_bytes = max(0.0, self.local_bytes - need)
        self.hbm_bytes = max(0.0, self.hbm_bytes - need)

    # -- introspection ----------------------------------------------------------
    @property
    def device_bytes(self) -> List[float]:
        return list(self.placer.bytes_used)

    def device_loads(self) -> List[int]:
        return self.placer.device_loads()

    def max_imbalance(self) -> int:
        return self.placer.max_imbalance()
