"""Request scheduler: admission control + pool-device interleaving.

Implements the paper's §4.3.3 dispatch policy: a request's KV lives on
ONE pool device; the scheduler round-robins requests across devices so
concurrent GPU fetches spread over fabric links.  Admission respects
(a) the concurrency cap, (b) pool capacity, (c) local-memory capacity
(the RDMA baseline's resident-KV constraint), and (d) HBM KV capacity
(GPU-only baseline).  The max per-device queue imbalance is bounded by
construction (property-tested).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.serving.request import Request


@dataclasses.dataclass
class SchedulerConfig:
    concurrency: int = 64
    n_pool_devices: int = 2
    interleave: bool = True
    pool_device_bytes: float = 256e9
    local_dram_bytes: float = float("inf")     # RDMA baseline constraint
    hbm_kv_bytes: float = float("inf")         # GPU-only baseline constraint
    bytes_per_token: float = 0.0               # KV bytes/token (all layers)


class Scheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.device_bytes = [0.0] * cfg.n_pool_devices
        self.local_bytes = 0.0
        self.hbm_bytes = 0.0
        self._rr = 0

    # -- queueing --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _kv_bytes(self, req: Request) -> float:
        return (req.context_len + req.output_len) * self.cfg.bytes_per_token

    def _pick_device(self, need: float) -> Optional[int]:
        n = self.cfg.n_pool_devices
        order = ([(self._rr + i) % n for i in range(n)]
                 if self.cfg.interleave else list(range(n)))
        for dev in order:
            if self.device_bytes[dev] + need <= self.cfg.pool_device_bytes:
                return dev
        return None

    def try_admit(self, now_s: float) -> List[Request]:
        """Admit queued requests while resources allow (FCFS)."""
        admitted = []
        while self.queue and len(self.active) < self.cfg.concurrency:
            req = self.queue[0]
            need = self._kv_bytes(req)
            if self.local_bytes + need > self.cfg.local_dram_bytes:
                break                      # RDMA local-memory wall (P2)
            if self.hbm_bytes + need > self.cfg.hbm_kv_bytes:
                break                      # HBM capacity wall (fig 12)
            dev = self._pick_device(need)
            if dev is None:
                break                      # pool exhausted
            self.queue.popleft()
            req.pool_device = dev
            req.dispatch_s = now_s
            self.device_bytes[dev] += need
            self.local_bytes += need
            self.hbm_bytes += need
            self.active[req.request_id] = req
            if self.cfg.interleave:
                self._rr = (dev + 1) % self.cfg.n_pool_devices
            admitted.append(req)
        return admitted

    def finish(self, req: Request) -> None:
        self.active.pop(req.request_id, None)
        need = self._kv_bytes(req)
        self.device_bytes[req.pool_device] -= need
        self.local_bytes -= need
        self.hbm_bytes -= need

    # -- introspection ----------------------------------------------------------
    def device_loads(self) -> List[int]:
        loads = [0] * self.cfg.n_pool_devices
        for r in self.active.values():
            loads[r.pool_device] += 1
        return loads

    def max_imbalance(self) -> int:
        loads = self.device_loads()
        return max(loads) - min(loads) if loads else 0
