"""ShareGPT-like synthetic request traces (paper §5: 512 requests sampled
from ShareGPT, context 16K-128K, output fixed per experiment)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    request_id: int
    arrival_s: float
    context_len: int
    output_len: int
    prompt_tokens: Optional[np.ndarray] = None   # only for the real engine
    # -- shared-prefix workload annotation (radix prefix cache) --
    # requests in the same group share their first prefix_len prompt
    # tokens; the simulator's analytic radix twin keys its cache on the
    # group id, the engine sees the real shared tokens
    prefix_group: Optional[int] = None
    prefix_len: int = 0
    # -- filled by the runtime --
    dispatch_s: float = -1.0
    first_token_s: float = -1.0
    finish_s: float = -1.0
    pool_device: int = -1
    generated: int = 0

    @property
    def ttft_s(self) -> float:
        """Dispatch-to-first-token (the paper's fixed-concurrency TTFT:
        closed-loop slot wait is not the backend's latency)."""
        start = self.dispatch_s if self.dispatch_s >= 0 else self.arrival_s
        return self.first_token_s - start

    @property
    def ttft_arrival_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def tbt_s(self) -> float:
        if self.generated <= 1:
            return 0.0
        return (self.finish_s - self.first_token_s) / (self.generated - 1)


def sharegpt_trace(n_requests: int, *, context_len: int, output_len: int,
                   seed: int = 0, arrival_rate: float = float("inf"),
                   ctx_jitter: float = 0.1,
                   vocab: int = 0) -> List[Request]:
    """Deterministic trace: contexts jittered +-ctx_jitter around the sweep
    point (ShareGPT lengths vary), arrivals poisson (inf rate = all at 0)."""
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    for i in range(n_requests):
        if np.isfinite(arrival_rate):
            t += rng.exponential(1.0 / arrival_rate)
        ctx = int(context_len * (1 + ctx_jitter * (rng.random() * 2 - 1)))
        out = max(1, int(output_len))
        prompt = (rng.integers(0, vocab, size=ctx).astype(np.int32)
                  if vocab else None)
        reqs.append(Request(i, t, max(ctx, 16), out, prompt))
    return reqs


def shared_prefix_trace(n_requests: int, *, prefix_len: int,
                        suffix_len: int, output_len: int,
                        reuse_p: float = 0.7, seed: int = 0,
                        arrival_rate: float = float("inf"),
                        vocab: int = 0) -> List[Request]:
    """Shared-prefix workload (the radix prefix cache's regime: system
    prompts, few-shot templates, multi-turn history).

    Each request reuses an existing prefix group with probability
    ``reuse_p`` (uniform over live groups) or founds a new one; its
    prompt is the group's ``prefix_len`` shared tokens plus a private
    ``suffix_len``-token tail.  With ``vocab`` set, real token arrays
    are generated so the ENGINE's radix tree sees literal sharing; the
    simulator's analytic twin keys on ``prefix_group`` alone."""
    rng = np.random.default_rng(seed)
    prefixes: List[Optional[np.ndarray]] = []
    reqs = []
    t = 0.0
    for i in range(n_requests):
        if np.isfinite(arrival_rate):
            t += rng.exponential(1.0 / arrival_rate)
        if prefixes and rng.random() < reuse_p:
            g = int(rng.integers(len(prefixes)))
        else:
            g = len(prefixes)
            prefixes.append(
                rng.integers(0, vocab, size=prefix_len).astype(np.int32)
                if vocab else None)
        prompt = None
        if vocab:
            tail = rng.integers(0, vocab, size=suffix_len).astype(np.int32)
            prompt = np.concatenate([prefixes[g], tail])
        reqs.append(Request(i, t, prefix_len + suffix_len,
                            max(1, int(output_len)), prompt,
                            prefix_group=g, prefix_len=prefix_len))
    return reqs


def summarize(reqs: List[Request]) -> dict:
    done = [r for r in reqs if r.finish_s >= 0]
    if not done:
        return {"throughput_tok_s": 0.0, "ttft_mean_s": 0.0, "tbt_mean_s": 0.0}
    total_tokens = sum(r.generated for r in done)
    span = max(r.finish_s for r in done) - min(r.arrival_s for r in done)
    ttfts = np.array([r.ttft_s for r in done])
    tbts = np.array([r.tbt_s for r in done if r.generated > 1])
    return {
        "n_done": len(done),
        "throughput_tok_s": total_tokens / max(span, 1e-9),
        "throughput_req_s": len(done) / max(span, 1e-9),
        "ttft_mean_s": float(ttfts.mean()),
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p99_s": float(np.percentile(ttfts, 99)),
        "tbt_mean_s": float(tbts.mean()) if len(tbts) else 0.0,
        "tbt_p50_s": float(np.percentile(tbts, 50)) if len(tbts) else 0.0,
        "tbt_p99_s": float(np.percentile(tbts, 99)) if len(tbts) else 0.0,
    }
