"""ShareGPT-like synthetic request traces (paper §5: 512 requests sampled
from ShareGPT, context 16K-128K, output fixed per experiment)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    request_id: int
    arrival_s: float
    context_len: int
    output_len: int
    prompt_tokens: Optional[np.ndarray] = None   # only for the real engine
    # -- shared-prefix workload annotation (radix prefix cache) --
    # requests in the same group share their first prefix_len prompt
    # tokens; the simulator's analytic radix twin keys its cache on the
    # group id, the engine sees the real shared tokens
    prefix_group: Optional[int] = None
    prefix_len: int = 0
    # -- filled by the runtime --
    dispatch_s: float = -1.0
    first_token_s: float = -1.0
    finish_s: float = -1.0
    pool_device: int = -1
    generated: int = 0
    # worst single inter-token gap (s) — the metric chunked prefill
    # exists to bound: a monolithic prefill splicing into the batch
    # stalls every decoding request for a whole prompt's compute, which
    # per-request MEAN TBT averages away
    tbt_max_s: float = 0.0
    # the engine flushes the slot's decoded token stream here at finish
    # (seed token + decoded ids) — the bit-identity property tests
    # compare these across chunk schedules and disaggregation modes
    out_tokens: Optional[List[int]] = None

    @property
    def ttft_s(self) -> float:
        """Dispatch-to-first-token (the paper's fixed-concurrency TTFT:
        closed-loop slot wait is not the backend's latency)."""
        start = self.dispatch_s if self.dispatch_s >= 0 else self.arrival_s
        return self.first_token_s - start

    @property
    def ttft_arrival_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def tbt_s(self) -> float:
        if self.generated <= 1:
            return 0.0
        return (self.finish_s - self.first_token_s) / (self.generated - 1)


def sharegpt_trace(n_requests: int, *, context_len: int, output_len: int,
                   seed: int = 0, arrival_rate: float = float("inf"),
                   ctx_jitter: float = 0.1,
                   vocab: int = 0) -> List[Request]:
    """Deterministic trace: contexts jittered +-ctx_jitter around the sweep
    point (ShareGPT lengths vary), arrivals poisson (inf rate = all at 0)."""
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    for i in range(n_requests):
        if np.isfinite(arrival_rate):
            t += rng.exponential(1.0 / arrival_rate)
        ctx = int(context_len * (1 + ctx_jitter * (rng.random() * 2 - 1)))
        ctx = max(ctx, 16)      # clamp BEFORE generating the prompt so
        out = max(1, int(output_len))   # len(prompt) == context_len always
        prompt = (rng.integers(0, vocab, size=ctx).astype(np.int32)
                  if vocab else None)
        reqs.append(Request(i, t, ctx, out, prompt))
    return reqs


def shared_prefix_trace(n_requests: int, *, prefix_len: int,
                        suffix_len: int, output_len: int,
                        reuse_p: float = 0.7, seed: int = 0,
                        arrival_rate: float = float("inf"),
                        vocab: int = 0) -> List[Request]:
    """Shared-prefix workload (the radix prefix cache's regime: system
    prompts, few-shot templates, multi-turn history).

    Each request reuses an existing prefix group with probability
    ``reuse_p`` (uniform over live groups) or founds a new one; its
    prompt is the group's ``prefix_len`` shared tokens plus a private
    ``suffix_len``-token tail.  With ``vocab`` set, real token arrays
    are generated so the ENGINE's radix tree sees literal sharing; the
    simulator's analytic twin keys on ``prefix_group`` alone."""
    rng = np.random.default_rng(seed)
    prefixes: List[Optional[np.ndarray]] = []
    reqs = []
    t = 0.0
    for i in range(n_requests):
        if np.isfinite(arrival_rate):
            t += rng.exponential(1.0 / arrival_rate)
        if prefixes and rng.random() < reuse_p:
            g = int(rng.integers(len(prefixes)))
        else:
            g = len(prefixes)
            prefixes.append(
                rng.integers(0, vocab, size=prefix_len).astype(np.int32)
                if vocab else None)
        prompt = None
        if vocab:
            tail = rng.integers(0, vocab, size=suffix_len).astype(np.int32)
            prompt = np.concatenate([prefixes[g], tail])
        reqs.append(Request(i, t, prefix_len + suffix_len,
                            max(1, int(output_len)), prompt,
                            prefix_group=g, prefix_len=prefix_len))
    return reqs


SUMMARY_KEYS = (
    "n_done", "throughput_tok_s", "throughput_req_s",
    "ttft_mean_s", "ttft_p50_s", "ttft_p99_s",
    "ttft_arrival_mean_s", "ttft_arrival_p50_s", "ttft_arrival_p99_s",
    "tbt_mean_s", "tbt_p50_s", "tbt_p99_s",
    "tbt_max_p50_s", "tbt_max_p99_s",
    "slo_ttft_attainment", "slo_tbt_attainment",
)


def diurnal_trace(n_requests: int, *, prefix_len: int, suffix_len: int,
                  output_len: int, base_rate: float, seed: int = 0,
                  reuse_p: float = 0.7, n_tenants: int = 1,
                  period_s: float = 120.0, diurnal_amp: float = 0.5,
                  burst_p: float = 0.0, burst_size: int = 8,
                  ctx_tail_alpha: float = 0.0, max_ctx_mult: float = 8.0,
                  vocab: int = 0) -> List[Request]:
    """Open-loop production workload generator (PR 8): the shared-prefix
    trace extended with the arrival/length structure a serving system is
    actually judged on.

      - **diurnal arrivals**: instantaneous rate = ``base_rate * (1 +
        diurnal_amp * sin(2*pi*t/period_s))`` — sampled by thinning, so
        peaks genuinely pack requests closer than troughs.
      - **bursts**: with probability ``burst_p`` per arrival, a clump of
        ``burst_size`` requests lands at (nearly) the same instant — the
        regime where chunked prefill vs monolithic prefill separates.
      - **heavy-tailed contexts**: ``ctx_tail_alpha > 0`` multiplies the
        suffix by a Pareto(alpha) draw capped at ``max_ctx_mult`` — a few
        long-context stragglers amid many short requests.
      - **multi-tenant prefix groups**: each request belongs to one of
        ``n_tenants`` tenants; prefix reuse only happens *within* a
        tenant (tenants never share radix prefixes).

    Deterministic per seed.  With ``vocab`` set, real token arrays are
    generated (engine mode); otherwise the analytic twin keys on
    ``prefix_group``."""
    rng = np.random.default_rng(seed)
    peak = base_rate * (1.0 + abs(diurnal_amp))
    tenant_prefixes: List[List[Optional[np.ndarray]]] = [
        [] for _ in range(max(1, n_tenants))]
    group_of: dict = {}     # (tenant, local_g) -> global group id
    reqs: List[Request] = []
    t = 0.0
    pending_burst = 0
    while len(reqs) < n_requests:
        if pending_burst > 0:
            pending_burst -= 1
            t += 1e-4       # burst members land ~together
        else:
            # thinning: candidate arrivals at the peak rate, accepted
            # with probability rate(t)/peak -> inhomogeneous poisson
            while True:
                t += rng.exponential(1.0 / peak)
                rate = base_rate * (1.0 + diurnal_amp
                                    * np.sin(2 * np.pi * t / period_s))
                if rng.random() * peak < max(rate, 0.0):
                    break
            if burst_p > 0.0 and rng.random() < burst_p:
                pending_burst = max(0, int(burst_size) - 1)
        tenant = int(rng.integers(len(tenant_prefixes)))
        prefixes = tenant_prefixes[tenant]
        if prefixes and rng.random() < reuse_p:
            local_g = int(rng.integers(len(prefixes)))
        else:
            local_g = len(prefixes)
            prefixes.append(
                rng.integers(0, vocab, size=prefix_len).astype(np.int32)
                if vocab else None)
            group_of[(tenant, local_g)] = len(group_of)
        g = group_of[(tenant, local_g)]
        sfx = suffix_len
        if ctx_tail_alpha > 0.0:
            mult = min(1.0 + rng.pareto(ctx_tail_alpha), max_ctx_mult)
            sfx = max(1, int(suffix_len * mult))
        prompt = None
        if vocab:
            tail = rng.integers(0, vocab, size=sfx).astype(np.int32)
            prompt = np.concatenate([prefixes[local_g], tail])
        reqs.append(Request(len(reqs), t, prefix_len + sfx,
                            max(1, int(output_len)), prompt,
                            prefix_group=g, prefix_len=prefix_len))
    return reqs


def summarize(reqs: List[Request], *, slo_ttft_s: float = 0.0,
              slo_tbt_s: float = 0.0) -> dict:
    """Full summary over finished requests.  ALWAYS returns the complete
    ``SUMMARY_KEYS`` set (zeros when nothing finished) so sweep/gate
    consumers can index percentiles on empty cells without KeyError.

    TTFT is reported both dispatch-anchored (``ttft_*`` — the paper's
    fixed-concurrency metric) and arrival-anchored (``ttft_arrival_*``
    — the honest open-loop metric that includes queueing delay).  With
    ``slo_ttft_s``/``slo_tbt_s`` > 0 the SLO-attainment fractions are
    the share of finished requests meeting the target (arrival-anchored
    TTFT; per-request mean TBT)."""
    done = [r for r in reqs if r.finish_s >= 0]
    if not done:
        return {k: 0.0 for k in SUMMARY_KEYS}
    total_tokens = sum(r.generated for r in done)
    span = max(r.finish_s for r in done) - min(r.arrival_s for r in done)
    ttfts = np.array([r.ttft_s for r in done])
    ttfts_arr = np.array([r.ttft_arrival_s for r in done])
    tbts = np.array([r.tbt_s for r in done if r.generated > 1])
    tbts_max = np.array([r.tbt_max_s for r in done if r.generated > 1])
    slo_ttft = (float(np.mean(ttfts_arr <= slo_ttft_s))
                if slo_ttft_s > 0 else 0.0)
    slo_tbt = (float(np.mean(tbts <= slo_tbt_s))
               if slo_tbt_s > 0 and len(tbts) else 0.0)
    return {
        "n_done": len(done),
        "throughput_tok_s": total_tokens / max(span, 1e-9),
        "throughput_req_s": len(done) / max(span, 1e-9),
        "ttft_mean_s": float(ttfts.mean()),
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p99_s": float(np.percentile(ttfts, 99)),
        "ttft_arrival_mean_s": float(ttfts_arr.mean()),
        "ttft_arrival_p50_s": float(np.percentile(ttfts_arr, 50)),
        "ttft_arrival_p99_s": float(np.percentile(ttfts_arr, 99)),
        "tbt_mean_s": float(tbts.mean()) if len(tbts) else 0.0,
        "tbt_p50_s": float(np.percentile(tbts, 50)) if len(tbts) else 0.0,
        "tbt_p99_s": float(np.percentile(tbts, 99)) if len(tbts) else 0.0,
        "tbt_max_p50_s": (float(np.percentile(tbts_max, 50))
                          if len(tbts_max) else 0.0),
        "tbt_max_p99_s": (float(np.percentile(tbts_max, 99))
                          if len(tbts_max) else 0.0),
        "slo_ttft_attainment": slo_ttft,
        "slo_tbt_attainment": slo_tbt,
    }
