"""Fabric budget arbiter: the control loop between the fetch pipeline's
mechanisms (serving/prefetch.py) and a multi-tenant serving story.

PR 2 let every request speculate at the full ``prefetch_width`` no matter
how loaded its pool link was — exactly the regime where speculative
fetching degrades: once a device's issued seconds outgrow the pipeline's
hide window, every extra prefetched entry lands on the step critical
path.  Two host-side policies close that loop:

  - :class:`BudgetArbiter` — each step, read per-device link pressure
    (the per-step deltas of ``TrafficStats.device_demand_s()``: issued
    seconds minus the speculative share) and grant every request a
    speculative entry budget: requests on saturated links shrink toward
    ``min_width``, requests on idle links keep full ``max_width``.
    Grants obey, per device: ``sum(width * n_layers) * per_entry_s <=
    link_budget_frac * hide_window - demand_s`` (when that headroom is
    positive; property-tested in tests/test_arbiter.py).
  - :class:`LayerSizer` — apportion the hot tier's total slot budget
    (``device_buffer_size * n_layers``) across layers by miss pressure
    instead of uniformly: windowed layers can never select more than
    ``window`` distinct positions, so their slots are capped and the
    surplus goes to the full-attention layers that actually churn.

Both consume the engine and the simulator identically — the simulator
evaluates ``grant`` analytically on its modeled per-device demand, so
engine↔simulator stay comparable (tests/test_parity_suite.py).  Neither
ever changes decoded tokens: arbitration caps *speculation* (warm
inserts) and *buffer slots* (residency), never demand reads — the pool
stays authoritative.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Mapping, Optional, Sequence

from repro.core.transfer import FabricModel, PipelineModel


@dataclasses.dataclass(frozen=True)
class ArbiterConfig:
    """Budget-arbitration knobs (mirrored by ``SACConfig``)."""

    max_width: int                   # = cfg.sac.prefetch_width
    min_width: int = 0               # floor granted even when saturated
    link_budget_frac: float = 1.0    # fraction of the pipeline hide window
                                     # speculation may fill per device


class BudgetArbiter:
    """Cross-request speculative-prefetch budget arbitration.

    One instance per serving engine (or simulated cluster).  ``grant``
    is a pure function of the step's compute window and the previous
    step's measured per-device demand seconds — a feedback control loop:
    pressure observed at step t shapes speculation issued at step t+1.
    """

    def __init__(self, cfg: ArbiterConfig, *, entry_s: float,
                 n_layers: int, pipeline: PipelineModel):
        assert entry_s > 0, "per-entry fabric seconds must be positive"
        self.cfg = cfg
        self.entry_s = float(entry_s)
        self.n_layers = max(int(n_layers), 1)
        self.pipeline = pipeline

    @classmethod
    def from_fabric(cls, cfg: ArbiterConfig, fabric: FabricModel,
                    entry_bytes: int, *, n_layers: int,
                    pipeline: PipelineModel) -> "BudgetArbiter":
        """Engine-side constructor: amortized per-entry cost from the
        calibrated fabric model, over a nominal full-width burst."""
        nominal = max(cfg.max_width * max(n_layers, 1), 1)
        entry_s = fabric.per_entry_seconds(entry_bytes,
                                           nominal_batch=nominal)
        return cls(cfg, entry_s=entry_s, n_layers=n_layers,
                   pipeline=pipeline)

    # -- budget arithmetic -------------------------------------------------
    def link_budget_s(self, compute_s: float) -> float:
        """Per-device link seconds speculation may fill this step."""
        return (max(self.cfg.link_budget_frac, 0.0)
                * self.pipeline.hide_window_s(compute_s))

    def device_entry_budget(self, compute_s: float, demand_s: float
                            ) -> float:
        """Speculative entries that fit a device's remaining headroom
        after the measured demand backlog is accounted for."""
        headroom = self.link_budget_s(compute_s) - max(demand_s, 0.0)
        return max(headroom, 0.0) / self.entry_s

    def grant(self, compute_s: float, demand_s: Sequence[float],
              device_requests: Mapping[int, Sequence[Hashable]]
              ) -> Dict[Hashable, int]:
        """Allocate per-request speculative widths for one step.

        compute_s: the step's modeled compute window; demand_s: per-device
        demand seconds observed last step (``TrafficStats.device_demand_s``
        deltas, or the simulator's analytic miss seconds);
        device_requests: device -> request keys decoding on that device.

        Returns request -> granted width (entries per layer per step),
        clamped to ``[min(min_width, max_width), max_width]``; with
        ``min_width == 0`` the per-device sum respects the link budget:
        ``sum(w_r) * n_layers * entry_s <= max(headroom, 0)``.
        """
        grants: Dict[Hashable, int] = {}
        floor = min(self.cfg.min_width, self.cfg.max_width)
        for dev, rids in device_requests.items():
            if not rids:
                continue
            d = (demand_s[dev % len(demand_s)] if len(demand_s) else 0.0)
            entries = self.device_entry_budget(compute_s, d)
            per_req = int(entries // (len(rids) * self.n_layers))
            w = max(min(per_req, self.cfg.max_width), max(floor, 0))
            for rid in rids:
                grants[rid] = w
        return grants


# ---------------------------------------------------------------------------
# per-layer hot-tier sizing
# ---------------------------------------------------------------------------


class LayerSizer:
    """Apportion ``total_slots`` hot-tier entries across pool layers.

    Weights come from measured per-layer miss rates when available (the
    engine's ``buf_misses_l`` counters), else from a structural prior:
    a windowed layer's decode mask only ever selects from its trailing
    ``window`` positions, so it is weighted (and hard-capped) by
    ``min(window, topk)`` while full-attention layers carry weight
    ``topk``.  Sizes always sum exactly to ``total_slots`` and every
    layer keeps at least ``min_slots`` (capacity permitting) so the
    layered buffer layout stays valid.
    """

    def __init__(self, n_layers: int, total_slots: int, *,
                 layer_windows: Optional[Sequence[int]] = None,
                 topk: int = 0, min_slots: int = 1):
        self.n_layers = max(int(n_layers), 1)
        self.total_slots = max(int(total_slots), self.n_layers)
        wins = list(layer_windows or [])
        self.layer_windows = (wins + [0] * self.n_layers)[:self.n_layers]
        self.topk = max(int(topk), 1)
        self.min_slots = max(int(min_slots), 1)

    def caps(self) -> List[int]:
        """Per-layer ceilings: a windowed layer never benefits from more
        resident slots than distinct selectable positions.  The caps are
        honored while the budget fits under them; when ``total_slots``
        exceeds their sum (every layer windowed and over-provisioned),
        ``sizes`` spreads the surplus past the caps — the total is the
        engine↔simulator comparability contract and always wins."""
        return [min(w, self.total_slots) if w > 0 else self.total_slots
                for w in self.layer_windows]

    def weights(self, miss_rates: Optional[Sequence[float]] = None
                ) -> List[float]:
        if miss_rates is not None:
            rates = (list(miss_rates) + [0.0] * self.n_layers)
            return [max(float(r), 0.0) + 1e-9
                    for r in rates[:self.n_layers]]
        return [float(min(w, self.topk)) if w > 0 else float(self.topk)
                for w in self.layer_windows]

    def sizes(self, miss_rates: Optional[Sequence[float]] = None
              ) -> List[int]:
        n, total = self.n_layers, self.total_slots
        caps = self.caps()
        w = self.weights(miss_rates)
        base = min(self.min_slots, total // n)
        sizes = [min(max(base, 1), caps[l]) for l in range(n)]
        remaining = total - sum(sizes)
        # proportional fill under caps; iterate because capped layers
        # return their unused share to the pool
        while remaining > 0:
            active = [l for l in range(n) if sizes[l] < caps[l]]
            if not active:
                break
            tw = sum(w[l] for l in active)
            if tw <= 0:
                w = [1.0] * n
                continue
            shares = [(l, remaining * w[l] / tw) for l in active]
            progressed = 0
            for l, s in shares:
                add = min(int(s), caps[l] - sizes[l])
                sizes[l] += add
                progressed += add
            remaining -= progressed
            if progressed == 0:
                # fractional shares all rounded to zero: hand out single
                # slots by descending weight until the budget is spent
                for l, _ in sorted(shares, key=lambda t: -w[t[0]]):
                    if remaining <= 0:
                        break
                    if sizes[l] < caps[l]:
                        sizes[l] += 1
                        remaining -= 1
        if remaining > 0:
            # every layer capped but budget left: keep the sum invariant
            # (the total is the comparability contract) by spreading the
            # surplus round-robin past the caps
            for i in range(remaining):
                sizes[i % n] += 1
        return sizes
