"""Fabric budget arbiter: the control loop between the fetch pipeline's
mechanisms (serving/prefetch.py) and a multi-tenant serving story.

PR 2 let every request speculate at the full ``prefetch_width`` no matter
how loaded its pool link was — exactly the regime where speculative
fetching degrades: once a device's issued seconds outgrow the pipeline's
hide window, every extra prefetched entry lands on the step critical
path.  Two host-side policies close that loop:

  - :class:`BudgetArbiter` — each step, read per-device link pressure
    (the per-step deltas of ``TrafficStats.device_demand_s()``: issued
    seconds minus the speculative share) and grant every request a
    speculative entry budget: requests on saturated links shrink toward
    ``min_width``, requests on idle links keep full ``max_width``.
    Grants obey, per device: ``sum(width * n_layers) * per_entry_s <=
    link_budget_frac * hide_window - demand_s`` (when that headroom is
    positive; property-tested in tests/test_arbiter.py).
  - :class:`LayerSizer` — apportion the hot tier's total slot budget
    (``device_buffer_size * n_layers``) across layers by miss pressure
    instead of uniformly: windowed layers can never select more than
    ``window`` distinct positions, so their slots are capped and the
    surplus goes to the full-attention layers that actually churn.

Both consume the engine and the simulator identically — the simulator
evaluates ``grant`` analytically on its modeled per-device demand, so
engine↔simulator stay comparable (tests/test_parity_suite.py).  Neither
ever changes decoded tokens: arbitration caps *speculation* (warm
inserts) and *buffer slots* (residency), never demand reads — the pool
stays authoritative.

PR 4 closes the remaining loops: grants split a device's budget by
per-request measured prefetch precision (``precision_weighted``,
``TrafficStats.request_pf``) with the floor-division remainder
distributed largest-share-first instead of discarded; prefill warm-up
bursts draw from the same link budget (``grant_warmup``); and the
LayerSizer re-apportions ONLINE from measured miss rates
(``max_slots`` hard-caps at the static allocation width,
``hisparse.resize_layers`` realizes the new layout in place).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, Iterable, List, Mapping, Optional, \
    Sequence

from repro.core.fabric import FabricTopology
from repro.core.traffic import TrafficStats
from repro.core.transfer import FabricModel, PipelineModel


class DemandTracker:
    """Per-step demand deltas, per LINK and per REQUEST, with departure
    subtraction — the conditioning stage of the arbiter's (and the
    pressure-aware placer's) feedback signal.

    The raw counters (``TrafficStats.device_demand_s`` and the PR 5
    ``request_demand_s``) are cumulative; the control loops want *this
    step's* demand.  Before PR 5 the engine kept only the per-device
    deltas, so when a request finished, its share lingered in the link's
    signal until the policy EMA decayed it — several steps of placement
    and grants against load that had already left.  The tracker keeps
    the per-request split too, so ``depart`` can subtract a finishing
    request's own last-step share from its link immediately.

    Two feeding modes, one ``depart``:

      - :meth:`observe` (engine): snapshot cumulative stats each step;
      - :meth:`set_step` (simulator): the analytic per-step seconds are
        computed directly, no cumulative counters needed.

    Downstream, ``last_demand_s`` is not read raw by the placers: both
    layers wrap the tracker in the shared
    :class:`repro.serving.policy.PressureFeed` (PR 10), which overlays
    the warm-up pressure seed while its window is open and hands the
    result to ``Placer.set_pressure_fn`` and the arbiter alike.

    With a :class:`~repro.core.fabric.FabricTopology` attached (PR 7) the
    tracked slot space is the fabric's SEGMENTS, not devices: ``observe``
    reads ``TrafficStats.segment_demand_s()``, ``note_transfer`` books a
    device's transfer on every segment of its path, and ``depart``
    subtracts the request's share along its device's route (clamped at
    zero — exact under the flat star, a safe under-estimate when shared
    trunk segments carry other requests' traffic too).  The flat-star
    topology degenerates to the per-device behavior bit-for-bit.
    """

    def __init__(self, n_devices: int,
                 topology: Optional[FabricTopology] = None):
        self.n_devices = max(int(n_devices), 1)
        self.topology = topology
        self.n_slots = (topology.n_segments if topology is not None
                        else self.n_devices)
        self.last_demand_s: List[float] = [0.0] * self.n_slots
        self._dev_mark: List[float] = [0.0] * self.n_slots
        self._req_mark: Dict[Hashable, float] = {}
        self._req_last: Dict[Hashable, float] = {}
        self._pending: List[float] = [0.0] * self.n_slots

    def _route(self, device: int) -> Sequence[int]:
        """Slots a device's traffic lands on: its fabric path, or just
        its own slot when no topology is attached."""
        if self.topology is None:
            return (device,) if 0 <= device < self.n_slots else ()
        if not 0 <= device < self.topology.n_devices:
            return ()
        return self.topology.route(device)

    def note_transfer(self, device: int, seconds: float) -> None:
        """Attribute UNkeyed cache-owned traffic (a hot-prefix replica
        copy, PR 6) to a link's next step signal.  SIMULATOR-ONLY
        companion to ``set_step``: the engine's ``observe`` path reads
        cumulative counters that already include replica copies, so
        calling this there would double-count.  The seconds fold into
        the next ``set_step`` and, being unkeyed, no departure ever
        subtracts them."""
        if seconds <= 0:
            return
        if self.topology is not None:
            for sid, c in self.topology.segment_charge(device,
                                                       float(seconds)):
                self._pending[sid] += c
        elif 0 <= device < self.n_slots:
            self._pending[device] += float(seconds)

    def observe(self, stats: TrafficStats, keys: Iterable[Hashable]
                ) -> List[float]:
        """Engine mode: fold this step's cumulative counters into fresh
        per-link and per-request deltas.  ``keys`` are the requests
        live this step (their attribution is snapshotted; others keep
        their last known share for a late ``depart``)."""
        cur = (stats.segment_demand_s() if self.topology is not None
               else stats.device_demand_s())
        cur = (list(cur) + [0.0] * self.n_slots)[:self.n_slots]
        self.last_demand_s = [c - m for c, m in zip(cur, self._dev_mark)]
        self._dev_mark = cur
        for k in keys:
            cum = stats.request_demand_s.get(k, 0.0)
            self._req_last[k] = cum - self._req_mark.get(k, 0.0)
            self._req_mark[k] = cum
        return list(self.last_demand_s)

    def set_step(self, demand_s: Sequence[float],
                 request_shares: Optional[Mapping[Hashable, float]] = None
                 ) -> List[float]:
        """Simulator mode: this step's per-link demand seconds (per
        SEGMENT when a topology is attached, per device otherwise; and
        optionally each request's own share of them) were computed
        analytically — install them directly."""
        d = [max(float(x), 0.0) for x in demand_s]
        d = (d + [0.0] * self.n_slots)[:self.n_slots]
        if any(self._pending):
            d = [x + p for x, p in zip(d, self._pending)]
            self._pending = [0.0] * self.n_slots
        self.last_demand_s = d
        if request_shares is not None:
            for k, s in request_shares.items():
                self._req_last[k] = float(s)
        return list(self.last_demand_s)

    def depart(self, key: Hashable, device: int) -> float:
        """A request finished: drop its attribution and subtract its own
        last-step demand share from its link's (every segment on its
        route's) live signal.  Returns the share subtracted (0 for
        unknown keys/devices)."""
        share = self._req_last.pop(key, 0.0)
        self._req_mark.pop(key, None)
        if share <= 0:
            return 0.0
        slots = self._route(device)
        if not slots:
            return 0.0
        for s in slots:
            self.last_demand_s[s] = max(0.0, self.last_demand_s[s] - share)
        return share


@dataclasses.dataclass(frozen=True)
class ArbiterConfig:
    """Budget-arbitration knobs (mirrored by ``SACConfig``)."""

    max_width: int                   # = cfg.sac.prefetch_width
    min_width: int = 0               # floor granted even when saturated
    link_budget_frac: float = 1.0    # fraction of the pipeline hide window
                                     # speculation may fill per device
    precision_weighted: bool = False  # split each device's entry budget
                                      # across requests in proportion to
                                      # their measured prefetch precision
                                      # instead of uniformly


def _hand_out_units(budget: int, order: Sequence[int], out: List[int],
                    cap: Sequence[int]) -> int:
    """Hand out integer units one at a time in fixed ``order``, cycling,
    until the budget or every per-index ``cap`` is exhausted.  Mutates
    ``out``; returns the undistributable remainder.  Shared by the grant
    remainder distribution (:func:`_apportion`) and the LayerSizer's
    past-caps surplus spread — one algorithm, one set of edge cases."""
    while budget > 0:
        progressed = False
        for i in order:
            if budget <= 0:
                break
            if out[i] < cap[i]:
                out[i] += 1
                budget -= 1
                progressed = True
        if not progressed:
            break
    return budget


def _apportion(total_w: int, cap: int, weights: Sequence[float]
               ) -> List[int]:
    """Split ``total_w`` integer width units across requests.

    Each request's ideal share is proportional to its weight; shares are
    floored, then the remainder is handed out one unit at a time —
    largest fractional share first (ties to the larger weight, then the
    lower index), cycling until the budget or the per-request ``cap`` is
    exhausted.  Guarantees ``sum(out) <= total_w`` and every entry
    ``<= cap`` — the floor-division remainder the PR 3 grant silently
    discarded is spent instead of dropped.
    """
    n = len(weights)
    tw = sum(weights)
    if tw <= 0:
        weights = [1.0] * n
        tw = float(n)
    ideal = [total_w * w / tw for w in weights]
    out = [min(int(s), cap) for s in ideal]
    left = min(total_w, n * cap) - sum(out)
    order = sorted(range(n),
                   key=lambda i: (-(ideal[i] - int(ideal[i])),
                                  -weights[i], i))
    _hand_out_units(left, order, out, [cap] * n)
    return out


class BudgetArbiter:
    """Cross-request speculative-prefetch budget arbitration.

    One instance per serving engine (or simulated cluster).  ``grant``
    is a pure function of the step's compute window and the previous
    step's measured per-device demand seconds — a feedback control loop:
    pressure observed at step t shapes speculation issued at step t+1.
    """

    def __init__(self, cfg: ArbiterConfig, *, entry_s: float,
                 n_layers: int, pipeline: PipelineModel,
                 topology: Optional[FabricTopology] = None):
        assert entry_s > 0, "per-entry fabric seconds must be positive"
        self.cfg = cfg
        self.entry_s = float(entry_s)
        self.n_layers = max(int(n_layers), 1)
        self.pipeline = pipeline
        # with a fabric topology, grants are per-PATH: a device's budget
        # is the headroom of the most-loaded segment on its route, and
        # spec seconds granted at one device are charged against every
        # segment of its path before the next device is considered — two
        # devices behind one saturated trunk can no longer each claim the
        # trunk's full residue (None = flat per-device budgets, the
        # pre-PR 7 behavior, which the flat star matches exactly)
        self.topology = topology

    @classmethod
    def from_fabric(cls, cfg: ArbiterConfig, fabric: FabricModel,
                    entry_bytes: int, *, n_layers: int,
                    pipeline: PipelineModel,
                    topology: Optional[FabricTopology] = None
                    ) -> "BudgetArbiter":
        """Engine-side constructor: amortized per-entry cost from the
        calibrated fabric model, over a nominal full-width burst."""
        nominal = max(cfg.max_width * max(n_layers, 1), 1)
        entry_s = fabric.per_entry_seconds(entry_bytes,
                                           nominal_batch=nominal)
        return cls(cfg, entry_s=entry_s, n_layers=n_layers,
                   pipeline=pipeline, topology=topology)

    # -- budget arithmetic -------------------------------------------------
    def link_budget_s(self, compute_s: float) -> float:
        """Per-device link seconds speculation may fill this step."""
        return (max(self.cfg.link_budget_frac, 0.0)
                * self.pipeline.hide_window_s(compute_s))

    def device_entry_budget(self, compute_s: float, demand_s: float
                            ) -> float:
        """Speculative entries that fit a device's remaining headroom
        after the measured demand backlog is accounted for."""
        headroom = self.link_budget_s(compute_s) - max(demand_s, 0.0)
        return max(headroom, 0.0) / self.entry_s

    def _device_demand(self, demand_s: Sequence[float], dev: int,
                       extra: Optional[Mapping[int, float]] = None
                       ) -> float:
        """Validated per-device demand lookup.  The pre-PR 4 ``dev %
        len(demand_s)`` convention silently aliased an out-of-range id
        onto the WRONG link's budget; the arbiter is control logic, so a
        bad id is a programming error and raises.

        With a topology attached, ``demand_s`` is per-SEGMENT and the
        returned figure is the BOTTLENECK on the device's path — the
        most-loaded segment between host and device (plus any
        ``extra`` spec seconds already granted there this step).
        Occupancy seconds are directly comparable across segments
        (``Segment.charge`` already folds in bandwidth_scale), so path
        headroom = window - max-over-path.
        """
        if not len(demand_s):
            return 0.0
        if self.topology is not None:
            if not 0 <= dev < self.topology.n_devices:
                raise ValueError(
                    f"device {dev} out of range "
                    f"[0, {self.topology.n_devices}) — placement and "
                    "the fabric topology disagree on the device space")
            vals = (list(demand_s)
                    + [0.0] * self.topology.n_segments)
            return max(vals[s] + (extra.get(s, 0.0) if extra else 0.0)
                       for s in self.topology.route(dev))
        if not 0 <= dev < len(demand_s):
            raise ValueError(
                f"device {dev} out of range [0, {len(demand_s)}) — "
                "placement and traffic accounting disagree on the "
                "device space")
        return demand_s[dev] + (extra.get(dev, 0.0) if extra else 0.0)

    def grant(self, compute_s: float, demand_s: Sequence[float],
              device_requests: Mapping[int, Sequence[Hashable]],
              precision: Optional[Mapping[Hashable, float]] = None
              ) -> Dict[Hashable, int]:
        """Allocate per-request speculative widths for one step.

        compute_s: the step's modeled compute window; demand_s: per-device
        demand seconds observed last step (``TrafficStats.device_demand_s``
        deltas, or the simulator's analytic miss seconds);
        device_requests: device -> request keys decoding on that device;
        precision: request -> measured prefetch precision (the
        ``TrafficStats.request_precision`` attribution) — consumed only
        when ``cfg.precision_weighted`` is on, in which case a device's
        entry budget is split in proportion to each request's precision
        (precise speculators keep width, imprecise ones shrink) instead
        of uniformly.

        Returns request -> granted width (entries per layer per step),
        clamped to ``[min(min_width, max_width), max_width]``; with
        ``min_width == 0`` the per-device sum respects the link budget:
        ``sum(w_r) * n_layers * entry_s <= max(headroom, 0)``.  The
        device's whole width budget is spent (largest-share-first
        remainder distribution) rather than floor-divided away.
        """
        grants: Dict[Hashable, int] = {}
        floor = max(min(self.cfg.min_width, self.cfg.max_width), 0)
        weighted = self.cfg.precision_weighted and precision is not None
        # spec seconds already granted per segment this step (per-path
        # budgets only; empty interaction under flat star — each device
        # owns its single segment and appears once)
        granted_seg: Dict[int, float] = {}
        for dev, rids in device_requests.items():
            if not rids:
                continue
            d = self._device_demand(demand_s, dev, granted_seg)
            entries = self.device_entry_budget(compute_s, d)
            total_w = int(entries // self.n_layers)
            if weighted:
                # epsilon keeps a zero-precision request eligible for
                # remainder units instead of degenerate 0-weight shares
                weights = [max(float(precision.get(r, 1.0)), 0.0) + 1e-3
                           for r in rids]
            else:
                weights = [1.0] * len(rids)
            widths = _apportion(total_w, self.cfg.max_width, weights)
            spec_s = 0.0
            for rid, w in zip(rids, widths):
                grants[rid] = max(w, floor)
                spec_s += grants[rid] * self.n_layers * self.entry_s
            if self.topology is not None and spec_s > 0:
                for sid in self.topology.route(dev):
                    granted_seg[sid] = granted_seg.get(sid, 0.0) + spec_s
        return grants

    def grant_warmup(self, compute_s: float, demand_s: Sequence[float],
                     device: int, width: int) -> int:
        """Cap one request's prefill warm-up burst by its link headroom.

        Warm bursts ride behind the prefill compute window exactly like
        speculation rides behind decode, so they draw from the same
        per-device budget: ``width`` (the planned warm entries per layer)
        shrinks to what fits ``device_entry_budget`` over ``n_layers``
        layers, never below ``min(min_width, width)`` — a saturated link
        still seeds a floor-sized warm set (pure traffic shaping: the
        first decode step just misses more, it never decodes differently).
        """
        if width <= 0:
            return 0
        d = self._device_demand(demand_s, device)
        cap = int(self.device_entry_budget(compute_s, d)
                  // self.n_layers)
        floor = min(max(self.cfg.min_width, 0), width)
        return min(width, max(cap, floor))


# ---------------------------------------------------------------------------
# per-layer hot-tier sizing
# ---------------------------------------------------------------------------


def resize_allocation_width(sizes: Sequence[int],
                            device_buffer: int) -> int:
    """Static allocation width for an online-resizable layered buffer:
    2x headroom over the widest initial layer (and over the uniform
    per-layer share) so re-sizing can grow layers, capped at the total.
    ONE formula shared by the engine's allocation and the simulator's
    analytic twin — their LayerSizer ``max_slots`` hard caps must agree
    or the analytic re-sized hit rates drift from the engine's."""
    total = sum(sizes)
    return min(total, 2 * max(max(sizes), device_buffer))


class LayerSizer:
    """Apportion ``total_slots`` hot-tier entries across pool layers.

    Weights come from measured per-layer miss rates when available (the
    engine's ``buf_misses_l`` counters), else from a structural prior:
    a windowed layer's decode mask only ever selects from its trailing
    ``window`` positions, so it is weighted (and hard-capped) by
    ``min(window, topk)`` while full-attention layers carry weight
    ``topk``.  Sizes always sum exactly to ``total_slots`` and every
    layer keeps at least ``min_slots`` (capacity permitting) so the
    layered buffer layout stays valid.
    """

    def __init__(self, n_layers: int, total_slots: int, *,
                 layer_windows: Optional[Sequence[int]] = None,
                 topk: int = 0, min_slots: int = 1,
                 max_slots: Optional[int] = None):
        self.n_layers = max(int(n_layers), 1)
        self.total_slots = max(int(total_slots), self.n_layers)
        wins = list(layer_windows or [])
        self.layer_windows = (wins + [0] * self.n_layers)[:self.n_layers]
        self.topk = max(int(topk), 1)
        self.min_slots = max(int(min_slots), 1)
        # hard per-layer ceiling: the static allocation width of an
        # already-built layered buffer (online re-sizing can never grow a
        # layer past it).  Feasibility: the initial layout satisfies
        # n * max(sizes) >= sum(sizes) == total, so a ceiling taken from
        # that layout always fits the whole budget.
        self.max_slots = None if max_slots is None else max(int(max_slots), 1)
        if self.max_slots is not None:
            assert self.total_slots <= self.n_layers * self.max_slots, \
                (self.total_slots, self.n_layers, self.max_slots)

    def _hard_cap(self) -> int:
        return (self.max_slots if self.max_slots is not None
                else self.total_slots)

    def caps(self) -> List[int]:
        """Per-layer ceilings: a windowed layer never benefits from more
        resident slots than distinct selectable positions.  The caps are
        honored while the budget fits under them; when ``total_slots``
        exceeds their sum (every layer windowed and over-provisioned),
        ``sizes`` spreads the surplus past the window caps — the total is
        the engine↔simulator comparability contract and always wins —
        though never past ``max_slots`` (an allocation width is physical,
        not advisory)."""
        hard = self._hard_cap()
        return [min(w, hard) if w > 0 else hard
                for w in self.layer_windows]

    def weights(self, miss_rates: Optional[Sequence[float]] = None
                ) -> List[float]:
        if miss_rates is not None:
            rates = (list(miss_rates) + [0.0] * self.n_layers)
            return [max(float(r), 0.0) + 1e-9
                    for r in rates[:self.n_layers]]
        return [float(min(w, self.topk)) if w > 0 else float(self.topk)
                for w in self.layer_windows]

    def sizes(self, miss_rates: Optional[Sequence[float]] = None
              ) -> List[int]:
        n, total = self.n_layers, self.total_slots
        caps = self.caps()
        w = self.weights(miss_rates)
        base = min(self.min_slots, total // n)
        sizes = [min(max(base, 1), caps[l]) for l in range(n)]
        remaining = total - sum(sizes)
        # proportional fill under caps; iterate because capped layers
        # return their unused share to the pool
        while remaining > 0:
            active = [l for l in range(n) if sizes[l] < caps[l]]
            if not active:
                break
            tw = sum(w[l] for l in active)
            if tw <= 0:
                w = [1.0] * n
                continue
            shares = [(l, remaining * w[l] / tw) for l in active]
            progressed = 0
            for l, s in shares:
                add = min(int(s), caps[l] - sizes[l])
                sizes[l] += add
                progressed += add
            remaining -= progressed
            if progressed == 0:
                # fractional shares all rounded to zero: hand out single
                # slots by descending weight until the budget is spent
                for l, _ in sorted(shares, key=lambda t: -w[t[0]]):
                    if remaining <= 0:
                        break
                    if sizes[l] < caps[l]:
                        sizes[l] += 1
                        remaining -= 1
        if remaining > 0:
            # every layer at its window cap but budget left: keep the sum
            # invariant (the total is the comparability contract) by
            # spreading the surplus past the window caps — rotating in
            # DESCENDING weight order (a fixed layer-0 start would hand
            # the heaviest-missing layers nothing extra and bias early
            # layers every call), and never past the hard allocation cap
            hard = self._hard_cap()
            order = sorted(range(n), key=lambda l: (-w[l], l))
            remaining = _hand_out_units(remaining, order, sizes,
                                        [hard] * n)
            assert remaining == 0, \
                "total_slots exceeds n_layers * max_slots"
        return sizes
