"""The radix locality bonus — seconds a same-device prefix hit saves —
shared by ``Engine._locality_bonus_s`` and the simulator's ``_bonus_s``.

The FORMULA is the policy: matched tokens save their marginal prefill
compute (``prefill_s(n) - prefill_s(n - matched)``) plus their skipped
pool write.  Each layer binds its own cost callables — the engine's
write cost is the fabric's bulk-transfer time over its real entry
bytes, the simulator's is the analytic striped-pool write bandwidth —
so the two sides keep their native units while the decision (what
counts as the bonus, and that ``matched <= 0`` is worth exactly 0)
cannot drift apart again.  The bonus is the ``affinity_s`` weight the
``radix_affinity`` placement policy (core/placement.py) holds against
live link pressure, and the benefit side of the replication trigger.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# no SACConfig knob is routed through this module; the tuple exists so
# the sacheck twin-coverage pass can treat every policy module uniformly
CONSUMED_KNOBS = ()


@dataclasses.dataclass(frozen=True)
class LocalityBonus:
    """``prefill_s(tokens) -> seconds`` and ``write_s(tokens) ->
    seconds`` are bound by the consumer; the call is the shared
    formula."""

    prefill_s: Callable[[int], float]
    write_s: Callable[[int], float]

    def __call__(self, prompt_len: int, matched: int) -> float:
        if matched <= 0:
            return 0.0
        return (self.prefill_s(prompt_len)
                - self.prefill_s(prompt_len - matched)
                + self.write_s(matched))
