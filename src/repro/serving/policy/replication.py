"""Hot-prefix replication trigger (PR 6) — the shared decision behind
``Engine._maybe_replicate`` and the simulator's twin of the same name.

The policy answers two questions and nothing else: WHICH links a copy
should flow between (:meth:`ReplicationPolicy.pick`) and WHETHER the
copy pays for itself (:meth:`ReplicationPolicy.should_fire`).  Both
layers keep their own side effects — the engine moves real pool pages
through ``SACSystem.replicate_prefix``, the simulator charges analytic
copy traffic — but the trigger arithmetic lives once, here:

  - source = the least-pressured copy-holding link (the cheapest link
    the prefix can already be read from);
  - destination = the least-pressured copy-free link, ties broken on
    booked bytes then device id (a bare min() would funnel every
    group's first copy onto device 0 at cold start);
  - fire only when the reuse benefit itself covers the one-time copy
    cost, the source link is at least as pressured as the destination
    (never copy toward a hotter link), and the source's per-step
    backlog amortizes the copy within ``horizon_steps`` decode steps
    (a lightly-loaded fabric must not replicate for nothing).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

# SACConfig knobs routed exclusively through this policy object
# (sacheck twin-coverage: no same-named SimConfig twin required)
CONSUMED_KNOBS = ("replicate_horizon_steps",)


@dataclasses.dataclass(frozen=True)
class ReplicationPolicy:
    """Pure trigger: consumers pass pressure/booking views in, get the
    (src, dst) pair and the fire/hold verdict out."""

    horizon_steps: int = 64

    def pick(self, pressure: Sequence[float], holders: List[int],
             others: List[int], bytes_used: Sequence[float]
             ) -> Optional[Tuple[int, int]]:
        """(source, destination) devices for a prospective copy, or
        None when no copy is possible (every link already holds one,
        or none does)."""
        if not holders or not others:
            return None
        src = min(holders, key=lambda d: pressure[d])
        dst = min(others, key=lambda d: (pressure[d], bytes_used[d], d))
        return src, dst

    def should_fire(self, p_src: float, p_dst: float, bonus_s: float,
                    copy_cost_s: float) -> bool:
        """True when the copy pays for itself within the horizon."""
        horizon = max(int(self.horizon_steps), 1)
        return not (bonus_s < copy_cost_s or p_src < p_dst
                    or p_src * horizon <= copy_cost_s)
