"""Prefill schedule selection (PR 8): monolithic vs chunked vs
disaggregated — the shared decision behind the engine's
``_fill_slots`` dispatch, the simulator's ``colocated_prefill`` /
``round1`` branches, and the replay's ``fill()``.

The knob precedence and the chunk arithmetic are the policy; the job
state machines (splice timing, lane clocks, handoff adoption) stay
with each consumer, because they ARE the execution substrate being
timed.  Disaggregation wins over chunking — disagg lanes never block
decode, so a chunk size is meaningless there (the engine has always
ignored it) — and ``chunk_take`` floors nothing: a non-positive chunk
size means "the whole remainder in one piece", which is exactly the
monolithic schedule, so the chunked path with a huge chunk reduces
bit-identically to the monolithic one.
"""
from __future__ import annotations

import dataclasses

# SACConfig knobs routed exclusively through this policy object
# (sacheck twin-coverage: the simulator consumes the SAME schedule
# object, so no same-named SimConfig twin is required)
CONSUMED_KNOBS = ("prefill_chunk_tokens", "disagg_prefill",
                  "prefill_lanes")

MONOLITHIC = "monolithic"
CHUNKED = "chunked"
DISAGG = "disagg"


@dataclasses.dataclass(frozen=True)
class PrefillSchedule:
    """One prefill schedule: ``mode`` plus the knobs that mode reads."""

    mode: str = MONOLITHIC
    chunk_tokens: int = 0
    lanes: int = 1

    @staticmethod
    def from_knobs(disagg: bool, chunk_tokens: int,
                   lanes: int) -> "PrefillSchedule":
        """Knob precedence shared by every consumer: disaggregation
        wins, then chunking, else monolithic."""
        if disagg:
            return PrefillSchedule(DISAGG, 0, max(1, int(lanes)))
        if int(chunk_tokens) > 0:
            return PrefillSchedule(CHUNKED, int(chunk_tokens), 1)
        return PrefillSchedule(MONOLITHIC, 0, 1)

    @property
    def disagg(self) -> bool:
        return self.mode == DISAGG

    @property
    def chunked(self) -> bool:
        return self.mode == CHUNKED

    def chunk_take(self, left: int) -> int:
        """Tokens the next chunk advances given ``left`` remaining —
        the whole remainder when chunking is off."""
        return left if self.chunk_tokens <= 0 else min(self.chunk_tokens,
                                                       left)
