"""Admission control: the arrival gate, queue ordering, and load
shedding — ONE implementation for the engine, the simulator's
``Scheduler``, and the analytic replay.

Before this package the decision lived three times: the engine's
``_eligible_indices``/``_pick_queue_index`` pair, the scheduler's
defensive arrival gate + ``set_reuse_fn`` wave sort, and the replay's
``eligible()``.  Each policy object below is pure — it reads a queue
and a clock and returns indices; popping, placement, and accounting
stay with the caller — so all three layers consume the identical code
and parity tests can assert object identity instead of float
agreement.

Policies:

  - :class:`FCFSAdmission` — submission order (the default);
  - :class:`RadixAdmission` — longest page-granular prefix match
    first, FCFS tie-break (PR 6 radix-aware admission);
  - :class:`EDFAdmission` — earliest deadline (``arrival_s +
    slo_ttft_s``) first, with optional load shedding when the arrived
    backlog exceeds ``shed_queue_depth`` (the PR 8 residue item:
    SLO-aware admission, landed once here for all three consumers).

Admission choice changes timing and traffic only — never decoded
tokens (property-tested in tests/test_policy.py): prefill always
recomputes the full prompt in-graph, so the order requests enter
slots cannot alter any request's own stream.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

# the ONE arrival-gate epsilon (engine clock, scheduler gate, replay):
# a request is eligible when arrival_s <= clock_s + ARRIVAL_EPS
ARRIVAL_EPS = 1e-12

# SACConfig knobs routed exclusively through this module (read by
# sacheck's twin-coverage pass: a knob consumed here needs no
# same-named SimConfig twin — this IS the shared implementation)
CONSUMED_KNOBS = ("admission", "shed_queue_depth", "slo_ttft_s",
                  "radix_admission")


def arrived(req, clock_s: float) -> bool:
    """The single source of truth for the arrival gate (PR 8): no
    request may be dispatched before its ``arrival_s`` on the caller's
    clock, open-loop traces included."""
    return req.arrival_s <= clock_s + ARRIVAL_EPS


class AdmissionPolicy:
    """Base policy: FCFS semantics, no shedding.  Subclasses override
    ``sort_key`` (and optionally ``shed``); ``eligible``/``select``/
    ``order`` are shared plumbing.

    ``select`` picks ONE index among the arrived requests (the
    engine's per-slot pop); ``order`` re-orders a whole wait queue
    (the scheduler's admission wave).  Both derive from the same
    ``sort_key``, so a policy cannot drift between its two call
    sites."""

    name = "fcfs"

    def sort_key(self, req, pos: int, score: float) -> Tuple:
        return (pos,)

    # -- scoring (radix reuse); the base policy ignores scores --------
    def score(self, req) -> float:
        return 0.0

    def needs_scores(self) -> bool:
        return False

    # -- the three verbs ----------------------------------------------
    def eligible(self, queue: Sequence, clock_s: float) -> List[int]:
        """Indices of ARRIVED requests, in queue order."""
        return [i for i, r in enumerate(queue) if arrived(r, clock_s)]

    def arrived(self, req, clock_s: float) -> bool:
        return arrived(req, clock_s)

    def select(self, queue: Sequence, eligible: List[int]) -> int:
        """The queue index to admit next among ``eligible``.  Ties
        break FCFS (lowest queue position) by construction of every
        ``sort_key``; a trivial choice short-circuits so no scorer
        runs when the answer cannot depend on it."""
        if len(eligible) <= 1 or not self.needs_scores():
            return eligible[0]
        return min(eligible,
                   key=lambda i: self.sort_key(queue[i], i,
                                               self.score(queue[i])))

    def order(self, queue: Sequence) -> List:
        """The whole wait queue re-ordered for an admission wave
        (stable: equal keys keep submission order)."""
        if len(queue) <= 1:
            return list(queue)
        ordered = sorted(enumerate(queue),
                         key=lambda p: self.sort_key(p[1], p[0],
                                                     self.score(p[1])))
        return [r for _, r in ordered]

    def shed(self, queue: Sequence, clock_s: float) -> List[int]:
        """Queue indices to drop before admission (load shedding).
        The base policies never shed."""
        return []


class FCFSAdmission(AdmissionPolicy):
    """Strict submission order — the pre-PR 6 default."""

    name = "fcfs"

    def order(self, queue: Sequence) -> List:
        return list(queue)


class RadixAdmission(AdmissionPolicy):
    """Longest page-granular prefix match against the current radix
    tree goes first; FCFS breaks ties (PR 6).  ``score_fn`` is bound
    by the consumer — the engine wires its real ``RadixIndex.match``,
    the simulator its analytic prefix-cache lookup — so the ORDERING
    decision is shared while the score source stays layer-native."""

    name = "radix"

    def __init__(self, score_fn: Optional[Callable] = None):
        self.score_fn = score_fn

    def sort_key(self, req, pos: int, score: float) -> Tuple:
        return (-score, pos)

    def score(self, req) -> float:
        return float(self.score_fn(req)) if self.score_fn is not None \
            else 0.0

    def needs_scores(self) -> bool:
        return self.score_fn is not None


class EDFAdmission(AdmissionPolicy):
    """Earliest-deadline-first against the TTFT SLO (the PR 8 residue
    item).  A request's deadline is ``arrival_s + slo_ttft_s``; with a
    uniform SLO this re-orders by arrival time (which differs from
    FCFS whenever requeues or out-of-order submission perturb queue
    positions) and, more importantly, gives shedding a principled
    victim order.

    ``shed_queue_depth > 0`` turns on load shedding: whenever more
    than that many ARRIVED requests are waiting, the arrived backlog
    beyond the ``shed_queue_depth`` earliest-deadline requests is
    dropped (deterministically — latest deadlines first).  Shed
    requests never decode; they simply leave the queue, so a saturated
    system keeps its admitted requests' deadlines reachable instead of
    missing everyone's."""

    name = "edf"

    def __init__(self, slo_ttft_s: float = 0.0,
                 shed_queue_depth: int = 0):
        self.slo_ttft_s = float(slo_ttft_s)
        self.shed_queue_depth = int(shed_queue_depth)

    def deadline(self, req) -> float:
        return req.arrival_s + self.slo_ttft_s

    def sort_key(self, req, pos: int, score: float) -> Tuple:
        return (self.deadline(req), pos)

    def needs_scores(self) -> bool:
        # deadlines come from the request itself, but select() must
        # still rank (not just take eligible[0])
        return True

    def score(self, req) -> float:
        return 0.0

    def shed(self, queue: Sequence, clock_s: float) -> List[int]:
        if self.shed_queue_depth <= 0:
            return []
        waiting = [i for i, r in enumerate(queue)
                   if arrived(r, clock_s)]
        if len(waiting) <= self.shed_queue_depth:
            return []
        keep = sorted(waiting,
                      key=lambda i: (self.deadline(queue[i]), i))
        return sorted(keep[self.shed_queue_depth:])


def make_admission(name: Optional[str], *, radix_admission: bool = False,
                   slo_ttft_s: float = 0.0, shed_queue_depth: int = 0,
                   score_fn: Optional[Callable] = None,
                   has_radix: bool = True) -> AdmissionPolicy:
    """The one factory all three consumers construct through.

    ``name=None`` keeps the legacy mapping: ``radix`` when the PR 6
    ``radix_admission`` knob is on (and a radix cache exists to score
    against), else ``fcfs``.  ``radix`` without a cache degrades to
    FCFS — the same gating the engine's ``admission_on`` always had.
    """
    if name is None:
        name = "radix" if radix_admission else "fcfs"
    if name == "radix" and (not has_radix or score_fn is None):
        name = "fcfs"
    if name == "fcfs":
        return FCFSAdmission()
    if name == "radix":
        return RadixAdmission(score_fn)
    if name == "edf":
        return EDFAdmission(slo_ttft_s=slo_ttft_s,
                            shed_queue_depth=shed_queue_depth)
    raise ValueError(f"unknown admission policy {name!r} "
                     "(expected fcfs | radix | edf)")
