"""Warm-up pressure seeding (PR 7) and the placement pressure feed.

The problem both layers solve identically: before the FIRST decode
step the demand tracker has never observed, so the pressure feed is
silent exactly while wave-1 admissions are herding onto a hot prefix's
owner.  The fix — add the BOOKED prefill-write demand to the feed
during that window only — used to live twice: the engine's
``_last_demand_s`` property and the simulator's ``_pressure()``
closure over a ``warm_seed`` list and a ``_seed_on`` cell.

:class:`WarmupPressureSeed` is the shared window + accumulator;
:class:`PressureFeed` is the shared callable handed to
``set_pressure_fn`` on both sides (``Placer`` and ``BudgetArbiter``
read it), so the parity suite can assert the engine's and the
simulator's placers consume the same feed CLASS rather than re-deriving
float agreement.  The engine deactivates the seed right after its
first decode step's counter increment; the simulator right after its
first decode-step block — the same instant on each layer's own clock.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

# SACConfig knobs routed exclusively through this policy object
CONSUMED_KNOBS = ("warmup_pressure_seed",)


class WarmupPressureSeed:
    """The warm-up-only seeding window.

    ``note_admission`` accumulates booked seconds per control-plane
    slot (the simulator's analytic path); ``apply`` overlays either
    that accumulator or a caller-supplied booked snapshot (the
    engine's measured ``TrafficStats.segment_demand_s``) onto the base
    feed.  Inactive, ``apply`` returns the base list UNCHANGED (same
    object — consumers rely on the zero-copy fast path)."""

    def __init__(self, enabled: bool, n_slots: int):
        self.enabled = bool(enabled)
        self.active = self.enabled
        self.extra: List[float] = [0.0] * n_slots

    def note_admission(self, slots: Sequence[int], seconds: float) -> None:
        """Book one admission's prefill-write seconds along its route
        (no-op outside the seeding window)."""
        if not self.active:
            return
        for s in slots:
            self.extra[s] += seconds

    def deactivate(self) -> None:
        """The first decode step ends warm seeding (idempotent)."""
        self.active = False

    def apply(self, base: List[float],
              booked: Optional[Sequence[float]] = None) -> List[float]:
        if not self.active:
            return base
        overlay = self.extra if booked is None else booked
        return [b + x for b, x in zip(base, overlay)]


class PressureFeed:
    """The live per-segment pressure signal: last step's tracked demand
    seconds plus the warm-up seed while its window is open.  This is
    the ONE object wired into ``set_pressure_fn`` by the engine's
    ``SACSystem`` and the simulator's ``Scheduler`` alike."""

    def __init__(self, tracker, seed: WarmupPressureSeed,
                 booked_fn: Optional[Callable[[], Sequence[float]]] = None):
        self.tracker = tracker
        self.seed = seed
        self.booked_fn = booked_fn

    def __call__(self) -> List[float]:
        base = self.tracker.last_demand_s
        if not self.seed.active:
            return base
        booked = self.booked_fn() if self.booked_fn is not None else None
        return self.seed.apply(base, booked)
