"""The serving control plane: ONE implementation of each policy
decision the engine/simulator/replay trio used to twin by hand.

Every module here holds a pure, side-effect-explicit policy object
consumed by all three serving layers (``serving/engine.py``,
``simulate()`` and ``replay_engine_timeline`` in
``serving/simulator.py``).  Parity between the layers is therefore a
matter of object identity — the parity suite asserts the three resolve
to the *same class* (or the same instance) instead of re-proving float
agreement between re-implementations:

  - :mod:`.admission` — arrival gate, queue ordering (FCFS / radix /
    EDF) and load shedding;
  - :mod:`.replication` — the hot-prefix replication trigger;
  - :mod:`.locality` — the radix locality bonus (affinity seconds);
  - :mod:`.seeding` — warm-up pressure seeding + the pressure feed;
  - :mod:`.prefill` — prefill schedule selection (monolithic /
    chunked / disaggregated).

Each module declares the ``SACConfig`` knobs it consumes in a
module-level ``CONSUMED_KNOBS`` tuple; sacheck's twin-coverage pass
reads those to exempt policy-routed knobs from the same-named
``SimConfig`` twin requirement (the policy object IS the shared
implementation, so a hand-written twin would be the exact duplication
this package removes).
"""
from repro.serving.policy.admission import (ARRIVAL_EPS, AdmissionPolicy,
                                            EDFAdmission, FCFSAdmission,
                                            RadixAdmission, arrived,
                                            make_admission)
from repro.serving.policy.locality import LocalityBonus
from repro.serving.policy.prefill import PrefillSchedule
from repro.serving.policy.replication import ReplicationPolicy
from repro.serving.policy.seeding import PressureFeed, WarmupPressureSeed

__all__ = [
    "ARRIVAL_EPS", "AdmissionPolicy", "FCFSAdmission", "RadixAdmission",
    "EDFAdmission", "arrived", "make_admission", "LocalityBonus",
    "PrefillSchedule", "ReplicationPolicy", "PressureFeed",
    "WarmupPressureSeed",
]
