"""Collective helpers + HLO collective-traffic analysis.

``collective_bytes`` parses compiled/lowered HLO text and sums the operand
bytes of every communication op — the §Roofline collective term (the
spec's ``cost_analysis`` does not report collective traffic, so we derive
it from the IR).

Collectives inside ``lax.scan`` bodies appear *once* in the HLO but run
once per iteration, so the parser is computation-aware: it finds every
``while`` op, recovers the static trip count from the loop condition's
compare-against-constant, and multiplies the body's collective traffic by
it (recursively, for nested scans).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,2048,576]' -> byte count (tuples: sum of parseable parts)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    body: List[str] = []
    depth = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{"):
                cur = "ENTRY" if m.group(1) else m.group(2)
                body = []
                depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            comps[cur] = body
            cur = None
            continue
        body.append(s)
    if cur is not None:
        comps[cur] = body
    return comps


def _line_collective(s: str) -> Optional[Tuple[str, int]]:
    for op in COLLECTIVE_OPS:
        if re.search(rf"\b{op}(-start)?\(", s):
            lhs = s.split("=", 1)
            if len(lhs) != 2:
                return (op, 0)
            shape_part = lhs[1].strip().split(op)[0]
            return (op, _shape_bytes(shape_part))
    return None


def _trip_count(cond_lines: List[str]) -> int:
    """Static trip count heuristic: largest compare-constant in the cond."""
    consts = [int(m.group(1)) for line in cond_lines
              for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else 1


def _analyze(comp: str, comps: Dict[str, List[str]], per_kind, counts,
             mult: int, _seen=None):
    if comp not in comps:
        return
    for s in comps[comp]:
        hit = _line_collective(s)
        if hit:
            per_kind[hit[0]] += hit[1] * mult
            counts[hit[0]] += mult
            continue
        m = _WHILE_RE.search(s)
        if m:
            cond, body = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, []))
            _analyze(body, comps, per_kind, counts, mult * trips)


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Total per-device collective traffic (result-shape bytes x executions).

    Returns (total_bytes, per-op-kind breakdown).  Result-shape bytes per
    execution is the per-device traffic convention for the roofline's
    collective term.
    """
    comps = _split_computations(hlo_text)
    per_kind: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    entry = "ENTRY" if "ENTRY" in comps else (next(iter(comps)) if comps else "")
    _analyze(entry, comps, per_kind, counts, 1)
    return sum(per_kind.values()), dict(per_kind)


def collective_count(hlo_text: str) -> Dict[str, int]:
    """Executed collective-op counts (trip-count aware)."""
    comps = _split_computations(hlo_text)
    per_kind: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    entry = "ENTRY" if "ENTRY" in comps else (next(iter(comps)) if comps else "")
    _analyze(entry, comps, per_kind, counts, 1)
    return dict(counts)
