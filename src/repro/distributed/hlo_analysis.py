"""Trip-count-aware HLO cost analysis (the §Roofline source).

XLA's ``compiled.cost_analysis()`` visits ``while`` bodies ONCE, so a
61-layer ``lax.scan`` reports 1/61 of the real FLOPs.  This module parses
the *scheduled, optimized* HLO text (``compiled.as_text()``) where every
top-level op is one executed kernel, recovers each loop's static trip
count from its condition's compare-constant, and accumulates:

  - **flops**: 2 * prod(result_dims) * prod(contracting_dims) per ``dot``
    (including dots inside fusion bodies), x trips.  Vector/elementwise
    FLOPs are ignored (sub-1% for transformer graphs).
  - **bytes**: per top-level kernel, result bytes + operand bytes — the
    post-fusion HBM traffic model (each fusion reads its inputs and
    writes its outputs exactly once), x trips.
  - **collective_bytes**: result-shape bytes of every communication op,
    x trips (per-device traffic convention).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "add-dependency", "custom-call"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_LINE_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_WHILE_RE = re.compile(
    r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class _Comp:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[Tuple[str, str, str, str]] = []  # name,shape,op,args
        self.symtable: Dict[str, str] = {}


def _split(hlo_text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    depth = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{"):
                cur = _Comp("ENTRY" if m.group(1) else m.group(2))
                depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            comps[cur.name] = cur
            cur = None
            continue
        lm = _LINE_RE.match(s)
        if lm:
            name, shape, op, args = lm.groups()
            cur.lines.append((name, shape, op, args))
            cur.symtable[name] = shape
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _trip_count(comp: Optional[_Comp]) -> int:
    """Static trip count heuristic: the largest integer constant in the
    loop condition (lax.scan conds are ``lt(counter, N)``)."""
    if comp is None:
        return 1
    consts = []
    for _, _, op, args in comp.lines:
        if op == "constant":
            m = re.match(r"(\d+)\)", args)
            if m:
                consts.append(int(m.group(1)))
        consts += [int(m.group(1)) for m in _CONST_RE.finditer(args)]
    return max(consts) if consts else 1


def _dot_flops(shape: str, args: str, symtable: Dict[str, str]) -> int:
    res_dims = _shape_dims(shape)
    if not res_dims:
        return 0
    n_out = 1
    for d in res_dims[0][1]:
        n_out *= d
    cm = _CDIM_RE.search(args)
    contracted = 1
    if cm:
        ops = _OPERAND_RE.findall(args)
        if ops and ops[0] in symtable:
            lhs_dims = _shape_dims(symtable[ops[0]])
            if lhs_dims:
                for di in (cm.group(1).split(",") if cm.group(1) else []):
                    d = int(di)
                    if d < len(lhs_dims[0][1]):
                        contracted *= lhs_dims[0][1][d]
    return 2 * n_out * contracted


def _fusion_param_reads(comp: _Comp) -> Dict[int, int]:
    """Per-parameter bytes actually read inside a fusion body.

    A body parameter consumed ONLY by dynamic-slice / gather / slice ops
    is charged at the sum of those result sizes (a windowed read of a
    loop-invariant buffer); anything else reads the parameter fully
    (signalled by absence from the returned map).
    """
    param_names: Dict[str, int] = {}
    for name, shape, op, args in comp.lines:
        if op == "parameter":
            m = re.match(r"(\d+)\)", args)
            if m:
                param_names[name] = int(m.group(1))
    reads: Dict[int, int] = {}
    for pname, pidx in param_names.items():
        sliced_bytes = 0
        only_sliced = True
        used = False
        for name, shape, op, args in comp.lines:
            if op == "parameter":
                continue
            if re.search(rf"%{re.escape(pname)}\b", args):
                used = True
                if op in ("dynamic-slice", "gather", "slice"):
                    sliced_bytes += _shape_bytes(shape)
                else:
                    only_sliced = False
                    break
        if used and only_sliced:
            reads[pidx] = sliced_bytes
    return reads


def _fusion_dot_flops(comp: _Comp, comps: Dict[str, _Comp], seen=None) -> int:
    """dot flops inside a fusion body (recursive through nested calls)."""
    seen = seen or set()
    if comp.name in seen:
        return 0
    seen.add(comp.name)
    total = 0
    for name, shape, op, args in comp.lines:
        if op == "dot":
            total += _dot_flops(shape, args, comp.symtable)
        cm = _CALLS_RE.search(args)
        if cm and cm.group(1) in comps:
            total += _fusion_dot_flops(comps[cm.group(1)], comps, seen)
    return total


def _analyze(comp: _Comp, comps: Dict[str, _Comp], acc: Dict, mult: int):
    for name, shape, op, args in comp.lines:
        if op == "while":
            m = _WHILE_RE.search(args)
            if m:
                trips = _trip_count(comps.get(m.group(1)))
                body = comps.get(m.group(2))
                if body is not None:
                    _analyze(body, comps, acc, mult * trips)
            continue
        is_coll = None
        for c in COLLECTIVE_OPS:
            if op == c or op == c + "-start":
                is_coll = c
                break
        if is_coll:
            b = _shape_bytes(shape)
            acc["collective_bytes"] += b * mult
            acc["collective_breakdown"][is_coll] += b * mult
            acc["collective_counts"][is_coll] += mult
            acc["bytes"] += b * mult
            continue
        if op in _FREE_OPS or op.endswith("-done"):
            continue
        # kernel traffic: result + actually-read operand bytes.  Sliced
        # reads of big (often loop-invariant) buffers are charged at the
        # slice size, not the buffer size.
        if op in ("dynamic-slice", "gather", "slice"):
            b = 2 * _shape_bytes(shape)
        elif op in ("dynamic-update-slice", "scatter"):
            opnds = [_shape_bytes(comp.symtable.get(o, ""))
                     for o in _OPERAND_RE.findall(args)]
            upd = min([o for o in opnds if o > 0], default=_shape_bytes(shape))
            b = 2 * upd
        elif op == "fusion":
            cm = _CALLS_RE.search(args)
            body = comps.get(cm.group(1)) if cm else None
            opnds = _OPERAND_RE.findall(args.split(", calls=")[0])
            b = _shape_bytes(shape)
            reads = _fusion_param_reads(body) if body is not None else {}
            for i, opnd in enumerate(opnds):
                full = _shape_bytes(comp.symtable.get(opnd, ""))
                b += min(reads.get(i, full), full) if i in reads else full
        else:
            b = _shape_bytes(shape)
            for opnd in _OPERAND_RE.findall(args.split(", calls=")[0]):
                b += _shape_bytes(comp.symtable.get(opnd, ""))
        acc["bytes"] += b * mult
        if op == "dot":
            acc["flops"] += _dot_flops(shape, args, comp.symtable) * mult
        elif op == "fusion":
            cm = _CALLS_RE.search(args)
            if cm and cm.group(1) in comps:
                acc["flops"] += _fusion_dot_flops(comps[cm.group(1)],
                                                  comps) * mult


def hlo_metrics(hlo_text: str) -> Dict:
    """Trip-aware {flops, bytes, collective_bytes, breakdown, counts}."""
    comps = _split(hlo_text)
    acc = {"flops": 0, "bytes": 0, "collective_bytes": 0,
           "collective_breakdown": defaultdict(int),
           "collective_counts": defaultdict(int)}
    entry = comps.get("ENTRY") or (next(iter(comps.values())) if comps else None)
    if entry is not None:
        _analyze(entry, comps, acc, 1)
    acc["collective_breakdown"] = dict(acc["collective_breakdown"])
    acc["collective_counts"] = dict(acc["collective_counts"])
    return acc
