"""Elastic re-meshing + straggler mitigation.

**Elastic re-mesh**: on device loss (or scale-up), pick the largest
well-formed ``(data, model)`` grid from the surviving devices, rebuild
shardings from the same logical rules, and ``device_put`` the
checkpointed state onto the new mesh.  Because checkpoints are plain
host arrays + logical-dim specs, restore onto *any* mesh shape works —
that is the whole fault-tolerance story: atomic snapshots (training/
checkpoint.py) + mesh-agnostic restore (here).

**Straggler mitigation**: ``SkipSlowReducer`` models the skip-slow-host
gradient trick — hosts that miss the step deadline are dropped from the
all-reduce and the gradient is rescaled by the number of contributors
(at-least-K semantics).  The serving-side analogue (per-link queue
bounding via pool interleaving) lives in serving/scheduler.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import params_shardings


def viable_mesh_shape(n_devices: int, *, model_pref: int = 16,
                      min_model: int = 1) -> Tuple[int, int]:
    """Largest (data, model) grid usable with ``n_devices`` devices.

    Keeps the model axis as close to ``model_pref`` as divisibility
    allows (TP degree is a property of the model fit, DP absorbs loss).
    May idle a remainder of devices (returned grid uses <= n_devices).
    """
    best = (1, 1)
    for model in range(min(model_pref, n_devices), min_model - 1, -1):
        data = n_devices // model
        if data * model > best[0] * best[1]:
            best = (data, model)
        if model <= model_pref and data >= 1:
            return (data, model)
    return best


def remesh(n_devices: int, *, axis_names=("data", "model"),
           devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices or jax.devices())[:n_devices]
    shape = viable_mesh_shape(len(devices))
    used = shape[0] * shape[1]
    arr = np.array(devices[:used]).reshape(shape)
    return Mesh(arr, axis_names)


def reshard_tree(tree: Any, specs_tree: Any, mesh: Mesh, rules=None) -> Any:
    """Host arrays + ParamSpec tree -> device arrays on the new mesh."""
    shardings = params_shardings(specs_tree, mesh, rules=rules)
    return jax.tree.map(lambda a, sh: jax.device_put(np.asarray(a), sh),
                        tree, shardings)


@dataclasses.dataclass
class StepReport:
    step: int
    contributors: int
    total_hosts: int
    skipped: List[int]


class SkipSlowReducer:
    """At-least-K gradient aggregation across hosts.

    Hosts report (host_id, grad, arrival_time); contributions arriving
    after ``deadline`` x median are dropped and the mean is rescaled.
    Pure-host logic (the cross-host reduce itself is jax psum in real
    deployment); deterministic and unit-testable.
    """

    def __init__(self, n_hosts: int, *, deadline_factor: float = 2.0,
                 min_quorum_frac: float = 0.75):
        self.n_hosts = n_hosts
        self.deadline_factor = deadline_factor
        self.min_quorum = max(1, int(np.ceil(min_quorum_frac * n_hosts)))

    def aggregate(self, step: int,
                  contributions: Dict[int, Tuple[Any, float]]
                  ) -> Tuple[Any, StepReport]:
        """contributions: host_id -> (grad_tree, arrival_time_s)."""
        if not contributions:
            raise ValueError("no gradient contributions")
        times = sorted(t for _, t in contributions.values())
        med = times[len(times) // 2]
        deadline = med * self.deadline_factor + 1e-9
        keep = {h: g for h, (g, t) in contributions.items() if t <= deadline}
        if len(keep) < self.min_quorum:          # never drop below quorum
            order = sorted(contributions.items(), key=lambda kv: kv[1][1])
            keep = {h: g for h, (g, _) in order[: self.min_quorum]}
        grads = list(keep.values())
        n = len(grads)
        summed = jax.tree.map(lambda *xs: sum(xs) / n, *grads)
        skipped = sorted(set(contributions) - set(keep))
        return summed, StepReport(step, n, self.n_hosts, skipped)
