"""Logical-axis -> mesh PartitionSpec rules.

Every parameter / activation in the model zoo declares *logical dims*
(e.g. ``("D", "F")`` for an MLP weight, ``("L", "E", "D", "F")`` for stacked
MoE experts).  This module maps those names onto the physical mesh axes
(``pod``/``data``/``model``) with divisibility checks, greedy conflict
resolution (one mesh axis may appear at most once per tensor) and a
context-managed rule table so serving and training can use different
layouts without touching model code.

The defaults implement:
  - TP over ``model`` for heads / d_ff / experts / vocab,
  - FSDP over ``data`` for the d_model rows (ZeRO-style param+opt sharding),
  - batch over ``(pod, data)``,
  - KV-pool sequence axis over ``model`` (the pooled-HBM capacity axis),
  - sequence-parallel residual stream over ``model`` during training.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# rule table: logical dim -> ordered mesh-axis preference
# ---------------------------------------------------------------------------

# Axis name conventions used across the model zoo:
#   B   batch                      S   sequence (activations)
#   SP  pool sequence (KV pool)    D   d_model (rows)
#   H   attention heads (fused)    KV  kv heads (fused)
#   F   ffn hidden                 E   experts
#   V   vocab                      L   stacked layer axis (never sharded)
#   C   latent / small dims        Hm  ssm heads
#   K   top-k axis (never sharded)

TRAIN_RULES: Dict[str, Tuple[str, ...]] = {
    "B": ("pod", "data"),
    "S": ("model",),          # sequence-parallel residual stream
    "Sq": (),                 # sequence axis inside attention (heads take TP)
    "SP": ("model",),
    "D": ("data",),           # FSDP rows (ZeRO param+opt sharding)
    "DE": ("data",),          # expert-weight rows (always capacity-sharded)
    "H": ("model",),
    "Hq": ("model",),         # head axis of attention activations
    "KV": ("model",),
    "F": ("model",),
    "E": ("model", "data"),
    "V": ("model",),
    "Hm": ("model",),
    "G": (),                  # small/replicated dims (norm gammas, head_dim)
    "L": (),                  # stacked-layer axes are never sharded
    "C": (),                  # latent / low-rank dims
    "K": (),                  # top-k axis
}

SERVE_RULES: Dict[str, Tuple[str, ...]] = {
    "B": ("pod", "data"),     # DP attention: each request on one data shard
    "S": ("model",),
    "Sq": (),
    "SP": ("model",),         # pool pages spread over the pooled-HBM axis
    "D": (),                  # NO row-sharding at serve: FSDP rows force a
                              # per-layer weight all-gather in decode
                              # (§Perf iteration A1); TP over model suffices
    "DE": ("data",),          # expert rows stay sharded (capacity: MoE
                              # weights are the TB-scale tensors)
    "H": ("model",),
    "Hq": ("model",),
    "KV": ("model",),
    "F": ("model",),
    "E": ("model", "data"),
    "V": ("model",),
    "Hm": ("model",),
    "G": (),
    "L": (),
    "C": (),
    "K": (),
}

_state = threading.local()


def _rules() -> Dict[str, Tuple[str, ...]]:
    return getattr(_state, "rules", TRAIN_RULES)


def _mesh() -> Optional[Mesh]:
    m = getattr(_state, "mesh", None)
    if m is not None:
        return m
    # fall back to the ambient mesh if one is active (API added in
    # jax 0.5; older versions have no ambient-mesh concept -> no mesh)
    get_env = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_env is None:
        return None
    env = get_env()
    return env if env and env.shape_tuple else None


@contextlib.contextmanager
def use_rules(rules: Dict[str, Tuple[str, ...]], mesh: Optional[Mesh] = None):
    old_r = getattr(_state, "rules", None)
    old_m = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        if old_r is None:
            del _state.rules
        else:
            _state.rules = old_r
        _state.mesh = old_m


# ---------------------------------------------------------------------------
# spec derivation
# ---------------------------------------------------------------------------


def spec_for(dims: Sequence[str], shape: Sequence[int],
             mesh: Optional[Mesh] = None,
             rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> P:
    """Derive a PartitionSpec for logical ``dims`` of ``shape``.

    Greedy: walk dims left to right; give each dim the first mesh axis from
    its preference list that (a) is present in the mesh, (b) is still unused
    in this tensor, and (c) divides the dim size.  Multi-axis entries (e.g.
    batch over ("pod", "data")) are taken as a group when every member
    divides cumulatively.
    """
    mesh = mesh or _mesh()
    rules = rules or _rules()
    if mesh is None:
        return P(*([None] * len(dims)))
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if isinstance(mesh, Mesh) else dict(mesh.shape_tuple)
    used: set = set()
    out: List[Optional[Tuple[str, ...]]] = []
    for dim, size in zip(dims, shape):
        prefs = rules.get(dim, ())
        picked: List[str] = []
        rem = size
        for ax in prefs:
            if ax not in axis_sizes or ax in used:
                continue
            n = axis_sizes[ax]
            if rem % n == 0:
                picked.append(ax)
                used.add(ax)
                rem //= n
        out.append(tuple(picked) if picked else None)
    return P(*out)


def named_sharding(mesh: Mesh, dims: Sequence[str], shape: Sequence[int],
                   rules: Optional[Dict[str, Tuple[str, ...]]] = None
                   ) -> NamedSharding:
    return NamedSharding(mesh, spec_for(dims, shape, mesh=mesh, rules=rules))


def constrain(x, dims: Sequence[str]):
    """with_sharding_constraint from logical dims (no-op without a mesh)."""
    mesh = _mesh()
    if mesh is None:
        return x
    spec = spec_for(dims, x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec) if isinstance(mesh, Mesh) else spec)


def params_shardings(specs_tree, mesh: Mesh, rules=None):
    """ParamSpec pytree -> NamedSharding pytree (same structure)."""
    from repro.models.layers import ParamSpec

    def one(s: ParamSpec):
        return named_sharding(mesh, s.dims, s.shape, rules=rules)

    return jax.tree.map(one, specs_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))
