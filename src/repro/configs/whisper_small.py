"""Whisper-small — enc-dec, conv frontend stubbed (precomputed frame embeds)
[arXiv:2212.04356]. 12 encoder + 12 decoder layers."""
from repro.configs.base import ModelConfig, SACConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, enc_dec=True, n_enc_layers=12,
    # SAC applies to cross-attention KV (encoder side is the long side)
    sac=SACConfig(enabled=True),
)
