"""xLSTM-125M — sLSTM + mLSTM blocks, attention-free [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig, SACConfig
import dataclasses

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    xlstm=True,
    # SAC inapplicable: attention-free (DESIGN.md §Arch-applicability)
    sac=SACConfig(enabled=False),
)
