"""Config system for the SAC framework.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
``ShapeConfig`` describes the assigned input shapes (train_4k / prefill_32k /
decode_32k / long_500k).  ``SACConfig`` carries the paper's technique knobs
(lightning indexer dims, top-k, HiSparse device buffer, pool backend).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# SAC (the paper's technique) configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SACConfig:
    """DeepSeek-Sparse-Attention + SAC disaggregated-cache knobs."""

    enabled: bool = True
    topk: int = 2048                 # DSA default top-k (paper §2.1)
    d_idx: int = 64                  # lightning indexer head dim
    n_idx_heads: int = 4             # lightning indexer heads
    device_buffer_size: int = 6144   # HiSparse hot-buffer entries/request (paper §5.5)
    page_size: int = 16              # tokens per pool page
    pool_backend: str = "pooled_hbm"  # pooled_hbm | host_dram
    interleave: bool = True          # CXL-device interleaving (paper §4.3.3)
    overlap_fetch: bool = False      # beyond-paper: double-buffered fetch
    kv_quant: Optional[str] = None   # beyond-paper: None | "int8" pool quantization

    # --- fetch pipeline (serving/prefetch.py) ---
    prefetch_width: int = 512        # speculative entries/layer/step beyond
                                     # top-k (ranks [k, k+w) of the indexer
                                     # scores warm the hot tier for step t+1)
    warmup_entries: int = 1024       # prefill warm-up: top-scoring prompt
                                     # entries seeded per layer per request
    warmup_radix: int = 512          # prefill warm-up: trailing tokens of the
                                     # radix-reused prefix seeded per layer
    pipeline_depth: int = 2          # double-buffered fetch queues/device
    overlap_frac: float = 0.85       # fraction of step compute a queued
                                     # fetch can hide behind

    # --- fabric budget arbiter (serving/arbiter.py) ---
    arbiter: bool = False            # cross-request prefetch budget
                                     # arbitration (per-device link pressure
                                     # shrinks speculative widths)
    link_budget_frac: float = 1.0    # fraction of the pipeline hide window
                                     # speculation may fill per device
    min_prefetch_width: int = 0      # granted-width floor under saturation
    score_margin: float = 1.0        # score-threshold speculation: tail
                                     # entries within margin*(s_max - s_k)
                                     # of the k-th demand score qualify;
                                     # < 0 = pure rank window [k, k+w)
    layer_sizing: str = "uniform"    # hot-tier slot apportioning across
                                     # layers: "uniform" | "windowed"
                                     # (LayerSizer prior: windowed layers
                                     # capped at their selectable window)

    # --- PR 4: the closed control loop ---
    placement: Optional[str] = None  # pool placement policy override
                                     # (core/placement.py): None = the
                                     # interleave default; "pressure_aware"
                                     # lands new requests on the least-
                                     # pressured fabric link
    precision_weighted: bool = False  # arbiter grants split per-request by
                                      # measured prefetch precision instead
                                      # of uniformly (serving/arbiter.py)
    resize_interval: int = 0         # decode steps between online LayerSizer
                                     # re-apportionings of the hot tier from
                                     # measured per-layer miss rates (0=off)
    resize_epsilon: float = 0.0      # resize hysteresis: skip the online
                                     # re-apportioning when no layer's
                                     # per-interval miss rate moved by more
                                     # than this since the last sizer
                                     # EVALUATION (skipped intervals keep
                                     # the reference, so slow drift
                                     # accumulates until it crosses the
                                     # epsilon; 0 = re-evaluate every
                                     # interval, the PR 4 behavior)

    # --- PR 5: radix prefix cache lifecycle (serving/radix.py) ---
    radix_headroom_frac: float = 0.05
                                     # pool free-page fraction per device
                                     # below which request finish evicts
                                     # LRU cached prefixes (0 = only evict
                                     # when placement actually fails)

    # --- PR 6: hot-prefix replication / page dedup / radix admission ---
    replicate_prefixes: bool = False  # copy hot cached prefixes to the
                                      # least-pressured pool device when
                                      # the owning link's pressure gap
                                      # pays back the one-time copy cost
    replicate_horizon_steps: int = 64  # decode steps over which a
                                       # replica's per-step pressure
                                       # relief must amortize its copy
                                       # cost before replication fires
    dedup_pages: bool = False         # refcount-share matched prefix
                                      # pages between the radix cache and
                                      # live slots instead of holding
                                      # private pool copies
    radix_admission: bool = False     # admit waiting requests by expected
                                      # prefix reuse (match length) rather
                                      # than FCFS

    # --- PR 7: CXL fabric topology (core/fabric.py) ---
    topology: Optional[str] = None   # fabric spec: None = flat star (one
                                     # dedicated host port per device;
                                     # bit-identical to the pre-PR 7 flat
                                     # per-device accounting), or
                                     # "tree:NxS" / "multi_switch:NxS" /
                                     # "mesh:NxP" — traffic is then
                                     # charged per link SEGMENT and
                                     # placement/grants read bottleneck-
                                     # segment pressure along each path
    warmup_pressure_seed: bool = False  # seed the placement pressure feed
                                     # from BOOKED demand during the
                                     # window before the first decode
                                     # step only (wave-1 admissions herd
                                     # onto the prefix owner while the
                                     # feed is still silent; always-on
                                     # seeding regresses under dedup —
                                     # see benchmarks/locality_sweep.py)
    replica_reads: bool = False      # re-pick the least-pressured replica
                                     # of a request's cached prefix every
                                     # STEP (bottleneck-segment pressure)
                                     # instead of freezing the copy
                                     # choice at placement time

    # --- PR 8: continuous batching + disaggregated prefill ---
    prefill_chunk_tokens: int = 0    # > 0: splice a prompt in over
                                     # ceil(ctx/chunk) bounded chunks
                                     # interleaved with decode steps
                                     # instead of stalling the batch in
                                     # _fill_slots (0 = monolithic).
                                     # Scheduling-only: decoded tokens
                                     # are bit-identical to monolithic
    disagg_prefill: bool = False     # disaggregated mode: prefill runs
                                     # on separate lanes (its own loop on
                                     # the shared wall clock), writes KV
                                     # to the pool device, and decode
                                     # adopts the slot via a handoff
                                     # record once prefill completes
    prefill_lanes: int = 2           # concurrent prefill lanes of the
                                     # disaggregated prefill engine

    # --- PR 10: shared admission policy (serving/policy/admission.py) ---
    admission: Optional[str] = None  # queue-ordering policy: None keeps
                                     # the legacy mapping (radix when
                                     # radix_admission is on, else fcfs);
                                     # "fcfs" | "radix" | "edf"
    slo_ttft_s: float = 0.0          # TTFT SLO target (seconds): EDF
                                     # admission orders by arrival_s +
                                     # slo_ttft_s; also the default
                                     # attainment target reported by
                                     # summarize()
    shed_queue_depth: int = 0        # > 0 (EDF only): drop the arrived
                                     # backlog beyond this many earliest-
                                     # deadline waiting requests — shed
                                     # requests never decode, keeping
                                     # admitted deadlines reachable under
                                     # saturation


# ---------------------------------------------------------------------------
# Model architecture configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm | mla
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None   # default: d_model // n_heads
    qkv_bias: bool = False           # qwen2
    rope_theta: float = 1e6

    # --- MoE ---
    n_experts: int = 0
    topk_experts: int = 0

    # --- sliding window / local:global attention ---
    sliding_window: int = 0          # 0 = full attention (mixtral: 4096)
    local_global_ratio: int = 0      # gemma3: 5 local per 1 global
    local_window: int = 1024         # window for "local" layers

    # --- SSM / recurrent ---
    ssm_state: int = 0               # zamba2 Mamba2 state size
    shared_attn_every: int = 0       # zamba2: shared attention block period
    xlstm: bool = False              # xlstm: sLSTM+mLSTM blocks, no attention

    # --- encoder-decoder (whisper) ---
    enc_dec: bool = False
    n_enc_layers: int = 0

    # --- MLA (deepseek) ---
    mla: bool = False
    kv_lora_rank: int = 512          # latent KV dim
    qk_rope_dim: int = 64
    q_lora_rank: int = 1536

    # --- early fusion VLM (chameleon) ---
    vlm: bool = False

    sac: SACConfig = dataclasses.field(default_factory=SACConfig)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def has_attention(self) -> bool:
        return not self.xlstm

    @property
    def kv_bytes_per_token_layer(self) -> int:
        """bf16 KV bytes per token per attention layer."""
        if self.mla:
            return 2 * (self.kv_lora_rank + self.qk_rope_dim)
        return 2 * 2 * self.n_kv_heads * self.hd

    @property
    def n_attn_layers(self) -> int:
        if self.xlstm:
            return 0
        if self.shared_attn_every:
            return self.n_layers // self.shared_attn_every
        if self.enc_dec:
            return self.n_layers  # decoder self+cross handled separately
        return self.n_layers

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        emb = v * d
        if self.xlstm:
            per = 8 * d * d  # qkv/if gates + proj, rough
            return emb + self.n_layers * per
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.mla:
            attn = (d * self.q_lora_rank + self.q_lora_rank * nh * (hd + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * nh * (hd + hd) + nh * hd * d)
        if self.n_experts:
            mlp = self.n_experts * 3 * d * f
        else:
            mlp = 3 * d * f
        per_layer = attn + mlp
        if self.ssm_state:  # mamba2 layers are ~6 d^2
            per_layer = 6 * d * d
            n_shared = self.n_layers // max(self.shared_attn_every, 1) if self.shared_attn_every else 0
            return emb + self.n_layers * per_layer + n_shared * (attn + 3 * d * f)
        total_layers = self.n_layers + (self.n_enc_layers if self.enc_dec else 0)
        return emb + total_layers * per_layer

    def active_param_count(self) -> int:
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count() - self.n_experts * 3 * d * f * self.n_layers
        return dense + self.topk_experts * 3 * d * f * self.n_layers

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests.

        Layer counts respect each family's structural period: xlstm stacks
        groups of 4 (3 mLSTM + 1 sLSTM); gemma-style local:global keeps one
        super-block (reduced to 1 local + 1 global); zamba keeps two
        supers + a tail layer to exercise every segment kind.
        """
        if self.xlstm:
            n_layers = 4
        elif self.local_global_ratio:
            n_layers = 2                      # one (1 local + 1 global) super
        elif self.shared_attn_every:
            n_layers = 5                      # 2 supers of 2 + 1 tail
        else:
            n_layers = min(self.n_layers, 2)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            n_enc_layers=min(self.n_enc_layers, 2) if self.enc_dec else 0,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            topk_experts=min(self.topk_experts, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            local_global_ratio=1 if self.local_global_ratio else 0,
            kv_lora_rank=32, qk_rope_dim=16, q_lora_rank=48,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            local_window=32,
            sac=dataclasses.replace(self.sac, topk=16, d_idx=8, n_idx_heads=2,
                                    device_buffer_size=32, page_size=4,
                                    prefetch_width=8, warmup_entries=8,
                                    warmup_radix=8),
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    grad_accum: int = 1              # microbatches inside train_step


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
