"""Gemma3-12B — 5:1 local:global attention, 128k ctx [hf:google/gemma-3]."""
from repro.configs.base import ModelConfig, SACConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262144, local_global_ratio=5, local_window=1024,
    sac=SACConfig(enabled=True),
)
