"""DeepSeek-V3.2 (the paper's own model) — MLA + DeepSeek Sparse Attention.
61 layers, d=7168, 128 heads, latent KV 512 + 64 RoPE dims, indexer top-k 2048.
MoE reduced bookkeeping: V3.2 has 256 experts top-8 (first 3 layers dense)."""
from repro.configs.base import ModelConfig, SACConfig

CONFIG = ModelConfig(
    name="deepseek-v32", family="mla",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab=129280, head_dim=128,
    mla=True, kv_lora_rank=512, qk_rope_dim=64, q_lora_rank=1536,
    n_experts=256, topk_experts=8,
    sac=SACConfig(enabled=True, topk=2048, d_idx=128, n_idx_heads=64,
                  device_buffer_size=6144),
)
