"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import ModelConfig, SACConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME

from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.qwen2_1_5b import CONFIG as _qwen2
from repro.configs.minicpm_2b import CONFIG as _minicpm
from repro.configs.granite_34b import CONFIG as _granite
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.deepseek_v32_sac import CONFIG as _deepseek

ARCHS = {c.name: c for c in [
    _xlstm, _dbrx, _mixtral, _whisper, _zamba2, _gemma3,
    _qwen2, _minicpm, _granite, _chameleon, _deepseek,
]}

ASSIGNED = [c.name for c in [
    _xlstm, _dbrx, _mixtral, _whisper, _zamba2, _gemma3,
    _qwen2, _minicpm, _granite, _chameleon,
]]


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[key]
