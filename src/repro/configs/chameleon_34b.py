"""Chameleon-34B — early-fusion VLM, VQ image tokens in vocab [arXiv:2405.09818].
Modality frontend is a stub: input_specs provides token ids (text+image tokens
share the embedding table, as in early fusion)."""
from repro.configs.base import ModelConfig, SACConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, vlm=True,
    sac=SACConfig(enabled=True),
)
