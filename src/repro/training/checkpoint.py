"""Fault-tolerant checkpointing: atomic, manifest-versioned, resharding
restore.

Layout::

    <dir>/step_000123.tmp-<nonce>/   (written fully, then atomically renamed)
    <dir>/step_000123/
        manifest.json   {step, leaf names/shapes/dtypes, checksums, extras}
        arr_000.npy ... (one file per pytree leaf)

Restore picks the newest *complete* manifest (half-written snapshots are
never visible under their final name — rename is the commit point), then
``device_put``s each leaf with the *target* sharding: restoring onto a
different mesh (elastic re-scale, node loss) works out of the box.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import uuid
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any,
         extras: Optional[Dict[str, Any]] = None) -> str:
    """Write an atomic snapshot; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp)
    leaves, treedef = _leaf_paths(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "extras": extras or {}, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        name = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, name), arr)
        with open(os.path.join(tmp, name), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "sha": digest})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
    return final


def _validate(path: str) -> Optional[Dict]:
    mf = os.path.join(path, "manifest.json")
    if not os.path.exists(mf):
        return None
    try:
        with open(mf) as f:
            manifest = json.load(f)
        for leaf in manifest["leaves"]:
            p = os.path.join(path, leaf["name"])
            with open(p, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest()[:16] != leaf["sha"]:
                    return None
        return manifest
    except Exception:
        return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and ".tmp" not in d)
    return steps[-1] if steps else None


def restore(directory: str, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``like``; optionally reshard.

    Walks snapshots newest-first, skipping corrupt ones (torn writes /
    failed nodes) — restart always finds the newest *consistent* state.
    """
    candidates = ([step] if step is not None else
                  sorted((int(d.split("_")[1]) for d in os.listdir(directory)
                          if d.startswith("step_") and ".tmp" not in d),
                         reverse=True))
    for s in candidates:
        path = os.path.join(directory, f"step_{s:09d}")
        manifest = _validate(path)
        if manifest is None:
            continue
        leaves, treedef = _leaf_paths(like)
        arrs = []
        ok = len(manifest["leaves"]) == len(leaves)
        for i, (leaf, meta) in enumerate(zip(leaves, manifest["leaves"])):
            arr = np.load(os.path.join(path, meta["name"]))
            if arr.dtype.kind == "V":    # bf16 etc. round-trip as raw void
                import ml_dtypes  # noqa: F401  (registers np.dtype names)
                arr = arr.view(np.dtype(meta["dtype"]))
            if list(arr.shape) != list(np.shape(leaf)):
                ok = False
                break
            arrs.append(arr)
        if not ok:
            continue
        tree = jax.tree.unflatten(treedef, arrs)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, sh: jax.device_put(a, sh), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, s, manifest.get("extras", {})
    raise FileNotFoundError(f"no valid checkpoint in {directory}")


def prune(directory: str, keep: int = 3):
    """Keep the newest ``keep`` snapshots (never the one being written)."""
    if not os.path.isdir(directory):
        return
    steps = sorted((d for d in os.listdir(directory)
                    if d.startswith("step_") and ".tmp" not in d))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
