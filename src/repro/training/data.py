"""Deterministic synthetic LM data pipeline.

Every batch is a pure function of (seed, step) — restart-safe: the
checkpoint stores the data cursor (step), restore resumes the exact
stream.  Sharded generation: each host materializes only its slice
(single-host here, but the index math is per-shard).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _rng(seed: int, step: int, shard: int = 0) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def synthetic_batch(cfg: ModelConfig, batch: int, seq_len: int, *,
                    seed: int = 0, step: int = 0, shard: int = 0,
                    n_shards: int = 1) -> Dict[str, np.ndarray]:
    """Markov-ish token stream (zipfian unigram + local repeats) so the
    model has actual structure to learn; labels are next-token."""
    rng = _rng(seed, step, shard)
    b = batch // n_shards
    if cfg.enc_dec:
        from repro.models.encdec import MAX_DEC
        frames = rng.standard_normal((b, seq_len, cfg.d_model),
                                     dtype=np.float32) * 0.02
        toks = _token_stream(rng, b, MAX_DEC + 1, cfg.vocab)
        return {"frames": frames, "tokens": toks[:, :-1],
                "labels": toks[:, 1:]}
    toks = _token_stream(rng, b, seq_len + 1, cfg.vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def _token_stream(rng, b: int, n: int, vocab: int) -> np.ndarray:
    # zipf over a capped alphabet + 25% copy-previous structure
    alpha = min(vocab, 4096)
    base = rng.zipf(1.3, size=(b, n)) % alpha
    copy = rng.random((b, n)) < 0.25
    toks = base.astype(np.int64)
    toks[:, 1:] = np.where(copy[:, 1:], toks[:, :-1], toks[:, 1:])
    return toks.astype(np.int32)


def batch_iterator(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                   start_step: int = 0, batch_override: int = 0,
                   seq_override: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    while True:
        yield synthetic_batch(cfg, B, S, seed=seed, step=step)
        step += 1
