"""AdamW with cosine / WSD (warmup-stable-decay, minicpm) schedules.

Functional: opt state is a pytree shaped like params (sharded identically
-> ZeRO-style via the FSDP rows rule).  All moments are f32 regardless of
param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    schedule: str = "cosine"        # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    stable_frac: float = 0.8        # WSD: fraction of post-warmup at peak lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    total = max(cfg.total_steps, 1)
    if cfg.schedule == "const":
        frac = jnp.float32(1.0)
    elif cfg.schedule == "wsd":
        # warmup -> stable plateau -> linear decay to min_lr (MiniCPM §4)
        stable_end = cfg.warmup_steps + cfg.stable_frac * (
            total - cfg.warmup_steps)
        decay = (step - stable_end) / jnp.maximum(total - stable_end, 1)
        frac = jnp.where(step <= stable_end, 1.0,
                         1.0 - (1.0 - cfg.min_lr_frac) * jnp.clip(decay, 0, 1))
    else:  # cosine
        prog = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(total - cfg.warmup_steps, 1), 0, 1)
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * frac


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, opt_state, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
