"""jit'd training step: grad accumulation (scan over microbatches),
remat'd forward, grad clip + AdamW, metrics.

``make_train_step(model, opt_cfg, grad_accum)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable
for pjit with the TRAIN_RULES shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import OptConfig, adamw_update

AUX_COEF = 0.01  # MoE load-balance loss weight


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits [B,S,V] f32, labels [B,S] int32 -> mean loss (one-hot dot:
    no gather over the vocab-sharded axis)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(logz - label_logit)


def make_loss_fn(model) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        if cfg.enc_dec:
            logits, aux = model.forward(
                params, {"frames": batch["frames"], "tokens": batch["tokens"]})
        else:
            logits, aux = model.forward(params, batch["tokens"])
        loss = cross_entropy(logits, batch["labels"])
        return loss + AUX_COEF * aux, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(model, opt_cfg: OptConfig, grad_accum: int = 1
                    ) -> Callable:
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            # reshape [B, ...] -> [accum, B/accum, ...]; scan accumulates
            def split(x):
                return x.reshape(grad_accum, x.shape[0] // grad_accum,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (_, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + m["loss"], aux_acc + m["aux"]), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                body, (g0, jnp.float32(0), jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = {"loss": loss_sum / grad_accum,
                       "aux": aux_sum / grad_accum}

        params, opt_state, stats = adamw_update(params, grads, opt_state,
                                                opt_cfg)
        metrics.update(stats)
        return params, opt_state, metrics

    return train_step


def train_loop(model, params, opt_state, batches, opt_cfg: OptConfig,
               *, steps: int, grad_accum: int = 1,
               checkpoint_fn: Callable = None, checkpoint_every: int = 0,
               log_every: int = 10) -> Tuple[Any, Any, list]:
    """Host loop: iterate batches, call the jit'd step, checkpoint."""
    step_fn = jax.jit(make_train_step(model, opt_cfg, grad_accum),
                      donate_argnums=(0, 1))
    history = []
    for i in range(steps):
        batch = next(batches)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            history.append({k: float(v) for k, v in metrics.items()})
        if checkpoint_fn and checkpoint_every and (i + 1) % checkpoint_every == 0:
            checkpoint_fn(params, opt_state, i + 1)
    return params, opt_state, history
