"""Whisper-style encoder-decoder with SAC on the cross-attention KV.

The conv frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings [B, S_enc, D] (per the assignment).  The encoder is full
bidirectional attention; the decoder is causal self-attention (small,
<= 448 positions) + cross-attention over the encoder output.

SAC applies to the **cross-attention KV** — the encoder side is the long
side (32K frames): prefill encodes and writes per-decoder-layer cross-KV
entries + indexer keys into the pool; decode fetches only the top-k
encoder positions per layer (DESIGN.md §5).  Decoder self-KV stays local
(dense, tiny).  Cross-attention uses no RoPE (positions=0 makes the
rotation the identity).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import sac as sac_core
from repro.core.pool import FetchFn, local_fetch, pool_write
from repro.distributed.sharding import constrain
from repro.models import dsa
from repro.models.layers import (DTYPE, ParamSpec, attn_param_specs,
                                 blocked_causal_attention,
                                 dense_attention_block, init_params,
                                 mlp_block, mlp_param_specs, repeat_kv,
                                 rms_norm, spec_shapes)
from repro.models.transformer import _stack, _norm

MAX_DEC = 448  # whisper decoder context


def bidir_attention(q, k, v, *, chunk: int = 1024):
    """Non-causal blocked attention (encoder / cross).  q: [B,Sq,H,hd];
    k,v: [B,Sk,H,hd] — online softmax over KV chunks; Sq may differ."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    n_chunks = max(Sk // chunk, 1)
    c = Sk // n_chunks
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    kc = kf.reshape(B, H, n_chunks, c, hd).transpose(2, 0, 1, 3, 4)
    vc = vf.reshape(B, H, n_chunks, c, hd).transpose(2, 0, 1, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vj)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, H, Sq), -1e30, jnp.float32),
            jnp.zeros((B, H, Sq), jnp.float32),
            jnp.zeros((B, H, Sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


class EncDecLM:
    """Whisper-small.  Modality frontend stubbed to frame embeddings."""

    def __init__(self, cfg: ModelConfig, fetch_fn: FetchFn = local_fetch,
                 mode: str = "sac", topk_fn=None, remat: bool = True):
        self.cfg = cfg
        self.fetch_fn = fetch_fn
        self.mode = mode if cfg.sac.enabled else "dense"
        self.topk_fn = topk_fn
        self.remat = remat
        self.n_kv = cfg.n_layers          # cross-KV pool layers
        self.kv_dim = dsa.gqa_entry_dim(cfg)
        self.specs = self._build_specs()

    # -- specs ---------------------------------------------------------------
    def _enc_layer_specs(self):
        cfg = self.cfg
        return {"ln1": _norm(cfg), "ln2": _norm(cfg),
                "attn": attn_param_specs(cfg), "mlp": mlp_param_specs(cfg)}

    def _dec_layer_specs(self):
        cfg = self.cfg
        p = {"ln1": _norm(cfg), "ln2": _norm(cfg), "ln3": _norm(cfg),
             "self_attn": attn_param_specs(cfg),
             "cross_attn": attn_param_specs(cfg),
             "mlp": mlp_param_specs(cfg)}
        if cfg.sac.enabled:
            p["idx"] = dsa.indexer_param_specs(cfg)
        return p

    def _build_specs(self):
        cfg = self.cfg
        return {
            "embed": ParamSpec((cfg.vocab, cfg.d_model), ("V", "D")),
            "enc": _stack(self._enc_layer_specs(), cfg.n_enc_layers),
            "dec": _stack(self._dec_layer_specs(), cfg.n_layers),
            "final_norm": _norm(cfg),
            "lm_head": ParamSpec((cfg.d_model, cfg.vocab), ("D", "V")),
        }

    def init(self, key):
        return init_params(self.specs, key)

    def param_shapes(self):
        return spec_shapes(self.specs)

    # -- encoder ----------------------------------------------------------------
    def encode(self, params, frames):
        """frames [B, S_enc, D] (stubbed frontend output) -> [B, S_enc, D]."""
        cfg = self.cfg
        x = constrain(frames.astype(DTYPE), ("B", "S", "D"))

        def body(x, p):
            xn = rms_norm(x, p["ln1"])
            B, S, _ = xn.shape
            nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            q = (xn @ p["attn"]["wq"]).reshape(B, S, nh, hd)
            k = (xn @ p["attn"]["wk"]).reshape(B, S, nkv, hd)
            v = (xn @ p["attn"]["wv"]).reshape(B, S, nkv, hd)
            n_rep = nh // nkv
            out = bidir_attention(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep))
            x = x + out.reshape(B, S, nh * hd) @ p["attn"]["wo"]
            x = constrain(x, ("B", "S", "D"))
            x = x + mlp_block(p["mlp"], rms_norm(x, p["ln2"]))
            return constrain(x, ("B", "S", "D")), None

        if self.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc"])
        return x

    # -- cross-KV entries ---------------------------------------------------------
    def _cross_entry(self, p_dec, enc_out):
        """Per-layer cross KV entry from encoder output (no RoPE)."""
        cfg = self.cfg
        zero_pos = jnp.zeros(enc_out.shape[:-1], jnp.int32)
        return dsa.gqa_kv_entry(p_dec["cross_attn"], enc_out, cfg, zero_pos)

    # -- training forward -----------------------------------------------------------
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """batch {frames [B,S,D], tokens [B,S_dec]} -> (logits, aux)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, Sd = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(DTYPE)
        x = constrain(x, ("B", "S", "D"))
        positions = jnp.arange(Sd, dtype=jnp.int32)[None, :].repeat(B, 0)
        nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        n_rep = nh // nkv

        def body(x, p):
            # causal self-attn
            h, _ = dense_attention_block(p["self_attn"], rms_norm(x, p["ln1"]),
                                         cfg, positions)
            x = x + h
            # full cross-attn
            xn = rms_norm(x, p["ln2"])
            q = (xn @ p["cross_attn"]["wq"]).reshape(B, Sd, nh, hd)
            k = (enc_out @ p["cross_attn"]["wk"]).reshape(
                B, -1, nkv, hd)
            v = (enc_out @ p["cross_attn"]["wv"]).reshape(
                B, -1, nkv, hd)
            out = bidir_attention(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep))
            x = x + out.reshape(B, Sd, nh * hd) @ p["cross_attn"]["wo"]
            x = x + mlp_block(p["mlp"], rms_norm(x, p["ln3"]))
            return constrain(x, ("B", "S", "D")), None

        if self.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec"])
        x = rms_norm(x, params["final_norm"])
        logits = (x @ params["lm_head"]).astype(jnp.float32)
        return constrain(logits, ("B", "S", "V")), jnp.float32(0)

    # -- prefill: encode + populate the cross-KV pool ------------------------------
    def prefill(self, params, frames, lengths=None):
        cfg = self.cfg
        B, S_enc, _ = frames.shape
        if lengths is None:
            lengths = jnp.full((B,), S_enc, jnp.int32)
        enc_out = self.encode(params, frames)

        def collect(_, p):
            entry = self._cross_entry(p, enc_out)
            ikey = (dsa.indexer_keys(p["idx"], enc_out)
                    if cfg.sac.enabled else jnp.zeros((), DTYPE))
            return 0, (entry, ikey)

        _, (entries, ikeys) = jax.lax.scan(collect, 0, params["dec"])
        state = self._empty_state(B, S_enc)
        state["kv_pool"] = constrain(entries.astype(DTYPE),
                                     ("L", "B", "SP", "G"))
        if cfg.sac.enabled and self.mode == "sac":
            state["idx_pool"] = constrain(ikeys.astype(DTYPE),
                                          ("L", "B", "SP", "G"))
        state["cache_len"] = lengths
        # decoder starts empty; BOS handled by the engine
        logits = jnp.zeros((B, cfg.vocab), jnp.float32)
        return state, logits

    # -- decode: self-attn (local dense) + SAC cross-attn ----------------------------
    def decode(self, params, state, tokens):
        cfg = self.cfg
        B = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0).astype(DTYPE)
        x = constrain(x, ("B", "D"))
        dec_len = state["dec_len"]
        cache_len = state["cache_len"]           # encoder lengths
        ctx_pos = dec_len                        # decoder position

        kv_pool, idx_pool = state["kv_pool"], state.get("idx_pool")
        self_kv = state["self_kv"]               # [L, B, MAX_DEC, d]
        zero_pos = jnp.zeros((B,), jnp.int32)

        def body(x, xs):
            p, kv_l, ik_l, skv_l = xs
            # 1) causal self-attention over the decoder cache
            xn = rms_norm(x, p["ln1"])
            own = dsa.gqa_kv_entry(p["self_attn"], xn, cfg, ctx_pos)
            delta = sac_core.dense_attend(p["self_attn"], xn, cfg, skv_l,
                                          dec_len, ctx_pos, own)
            x = x + delta
            # 2) SAC cross-attention over the encoder pool
            xn = rms_norm(x, p["ln2"])
            cross_own = jnp.zeros((B, self.kv_dim), DTYPE)  # no new enc entry
            if self.mode == "sac":
                scores = dsa.indexer_scores(p["idx"], xn, ik_l, cfg)
                idx, valid = dsa.topk_select(scores, cache_len, cfg.sac.topk)
                fetched = self.fetch_fn(kv_l, idx)
                delta = dsa.gqa_sparse_decode(p["cross_attn"], xn, cfg,
                                              fetched, valid, zero_pos)
            else:
                S = kv_l.shape[1]
                valid = (jnp.arange(S, dtype=jnp.int32)[None, :]
                         < cache_len[:, None])
                delta = dsa.gqa_sparse_decode(p["cross_attn"], xn, cfg,
                                              kv_l, valid, zero_pos)
            x = x + delta
            # 3) MLP
            x = x + mlp_block(p["mlp"], rms_norm(x, p["ln3"])[:, None, :])[:, 0]
            return constrain(x, ("B", "D")), own

        ik_xs = idx_pool if idx_pool is not None else None
        x, self_entries = jax.lax.scan(
            body, x, (params["dec"], kv_pool, ik_xs, self_kv))
        state = dict(state)
        state["self_kv"] = pool_write(self_kv, self_entries, dec_len)
        state["dec_len"] = dec_len + 1
        x = rms_norm(x, params["final_norm"])
        logits = (x @ params["lm_head"]).astype(jnp.float32)
        return state, constrain(logits, ("B", "V"))

    # -- state ----------------------------------------------------------------------
    def _empty_state(self, batch: int, seq_len: int) -> Dict:
        cfg = self.cfg
        state: Dict[str, Any] = {
            "cache_len": jnp.zeros((batch,), jnp.int32),
            "dec_len": jnp.zeros((batch,), jnp.int32),
            "self_kv": jnp.zeros((cfg.n_layers, batch, MAX_DEC, self.kv_dim),
                                 DTYPE),
            "kv_pool": jnp.zeros((self.n_kv, batch, seq_len, self.kv_dim),
                                 DTYPE),
        }
        if cfg.sac.enabled and self.mode == "sac":
            state["idx_pool"] = jnp.zeros(
                (self.n_kv, batch, seq_len, cfg.sac.d_idx), DTYPE)
        return state

    def serve_state_shapes(self, batch: int, seq_len: int,
                           device_buffer: int = 0) -> Dict:
        # device_buffer ignored: the decoder's cross-attention reads the
        # whole (fixed) encoder pool — there is no top-k fetch to buffer
        z = self._empty_state  # reuse shapes via eval_shape (no allocation)
        return jax.eval_shape(lambda: z(batch, seq_len))

    def init_serve_state(self, batch: int, seq_len: int,
                         device_buffer: int = 0) -> Dict:
        return self._empty_state(batch, seq_len)
