"""build_model(cfg) -> unified model facade + input_specs for every cell.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input of the (architecture x shape) cell — the
dry-run lowers against these with zero allocation.  Modality frontends
are stubs per the assignment: whisper takes precomputed frame embeddings,
chameleon takes fused token ids (text + VQ image tokens share the
embedding table).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.pool import FetchFn, local_fetch
from repro.models.encdec import EncDecLM, MAX_DEC
from repro.models.layers import DTYPE
from repro.models.transformer import TransformerLM


def build_model(cfg: ModelConfig, fetch_fn: FetchFn = local_fetch,
                mode: str = "sac", topk_fn: Optional[Callable] = None,
                remat: bool = True, opts: Optional[dict] = None):
    """mode: "sac" (top-k fetch decode) | "dense" (full-prefetch decode).

    opts (perf variants, see EXPERIMENTS.md §Perf):
      moe_groups: int   — per-shard MoE dispatch groups (B1)
      pool_closure: bool — closure-captured pools in decode scan (C1)
    """
    if cfg.enc_dec:
        return EncDecLM(cfg, fetch_fn=fetch_fn, mode=mode, topk_fn=topk_fn,
                        remat=remat)
    return TransformerLM(cfg, fetch_fn=fetch_fn, mode=mode, topk_fn=topk_fn,
                         remat=remat, opts=opts)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.enc_dec:
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), DTYPE),
            "tokens": jax.ShapeDtypeStruct((B, MAX_DEC), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, MAX_DEC), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.enc_dec:
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), DTYPE)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_input_specs(model, shape: ShapeConfig,
                       device_buffer: int = 0) -> Dict[str, Any]:
    """``device_buffer`` > 0 adds the HiSparse hot-tier state (per-layer
    ``hot_buf`` + measured ``buf_hits``/``buf_misses``) to the decode
    specs — the serve_state layout the engine runs with (miss-only
    fabric charging, serving/engine.py)."""
    B, S = shape.global_batch, shape.seq_len
    return {
        "state": model.serve_state_shapes(B, S,
                                          device_buffer=device_buffer),
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model=None
                ) -> Dict[str, Any]:
    """All inputs for the cell's compiled step (excluding params)."""
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    if shape.kind == "decode":
        assert model is not None, "decode specs need the built model"
        return decode_input_specs(model, shape)
    raise ValueError(shape.kind)


def cell_is_supported(cfg: ModelConfig, shape: ShapeConfig, mode: str = "sac"
                      ) -> Optional[str]:
    """None if the (arch, shape, mode) cell runs; else a skip reason.

    The skip set implements DESIGN.md §5:
      - whisper long_500k: the 500K-frame *encode* is quadratic prefill;
      - pure full-attention archs run long_500k only in SAC mode (dense
        decode over 524288 entries is the O(L) full-attention read the
        paper's technique removes — and its pool wouldn't fit one chip).
    """
    if shape.name == "long_500k":
        if cfg.enc_dec:
            return "500K-frame encoder prefill is quadratic (DESIGN.md §5)"
        if mode == "dense" and cfg.has_attention and not cfg.ssm_state:
            return "dense 500k decode excluded: full-attention baseline is " \
                   "what SAC replaces (DESIGN.md §5)"
    return None
