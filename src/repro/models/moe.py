"""Token-choice top-k MoE with capacity-based dispatch (GShard-style).

FLOP-honest: expert compute is E x C x (3 d f) with C = topk*T/E*cap_factor,
not the E/topk-times-inflated dense-dispatch einsum.

``groups`` (§Perf iteration B1): with groups=1 the dispatch cumsum runs
over ALL tokens — a global scatter-add whose [E, C, D] buffer GSPMD can
only realize with an all-reduce over the batch shards (TB-scale traffic
per MoE train step).  With groups = number of batch shards, tokens are
dispatched within their own group ([G, E, C/G, D], G on the batch axes),
every scatter stays shard-local, and the only cross-shard traffic left is
the expert-weight gather (ZeRO) + output reduce.  Per-group capacity is
the standard deployment policy (each DP rank bounds its own expert load —
this is also what bounds straggler skew from hot experts).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import ParamSpec


def moe_param_specs(cfg) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("G", "E")),
        "w_gate": ParamSpec((e, d, f), ("E", "DE", "F")),
        "w_up": ParamSpec((e, d, f), ("E", "DE", "F")),
        "w_down": ParamSpec((e, f, d), ("E", "F", "DE")),
    }


def _dispatch_one(xt, probs, E: int, K: int, C: int):
    """Capacity dispatch for one token group.

    xt: [T, D]; probs: [T, E] -> (dispatched [E*C+1, D], slot [T*K],
    weight [T*K], aux)."""
    T, D = xt.shape
    gate_vals, expert_ids = jax.lax.top_k(probs, K)              # [T, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    # load-balancing aux loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((E,)).at[expert_ids.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    flat_e = expert_ids.reshape(-1)                              # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(T * K), flat_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)         # sentinel

    dispatched = jnp.zeros((E * C + 1, D), xt.dtype)
    dispatched = dispatched.at[slot].add(
        jnp.repeat(xt, K, axis=0) * keep[:, None].astype(xt.dtype))
    w = (gate_vals.reshape(-1) * keep.astype(jnp.float32))
    return dispatched, slot, w, aux


def moe_block(p, x, cfg, *, cap_factor: float = 1.25, groups: int = 1):
    """x: [B, S, D] -> ([B, S, D], aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.topk_experts
    T = B * S
    groups = max(1, min(groups, T))
    while T % groups:
        groups //= 2
    Tg = T // groups
    C = max(int(K * Tg * cap_factor / E), 1)

    xt = x.reshape(groups, Tg, D)
    xt = constrain(xt, ("B", "Sq", "G"))
    logits = (xt @ p["router"]).astype(jnp.float32)              # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)

    dispatched, slot, w, aux = jax.vmap(
        lambda a, b: _dispatch_one(a, b, E, K, C))(xt, probs)
    ex = dispatched[:, : E * C].reshape(groups, E, C, D)
    ex = constrain(ex, ("B", "E", "K", "G"))

    h = jnp.einsum("gecd,edf->gecf", ex, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", ex, p["w_up"])
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])         # [G,E,C,D]
    out_e = constrain(out_e, ("B", "E", "K", "G"))

    flat_out = jnp.concatenate(
        [out_e.reshape(groups, E * C, D),
         jnp.zeros((groups, 1, D), out_e.dtype)], axis=1)
    gathered = jnp.take_along_axis(flat_out, slot[..., None], axis=1)
    combined = (gathered * w[..., None].astype(x.dtype)
                ).reshape(groups, Tg, K, D).sum(2)
    return combined.reshape(B, S, D), aux.mean()


def moe_decode(p, x, cfg, *, groups: int = 1):
    """Decode-time MoE for a single token per request (S=1)."""
    out, _ = moe_block(p, x[:, None, :], cfg, cap_factor=2.0, groups=groups)
    return out[:, 0, :]
