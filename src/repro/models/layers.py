"""Shared model layers: norms, RoPE, SwiGLU, GQA attention (full / windowed /
chunked-causal), and the module-free parameter system used across the zoo.

Parameters are plain pytrees of jnp arrays.  Every leaf is declared through
``ParamSpec`` carrying *logical dims* (e.g. ``("L", "D", "F")``) from which
``distributed/sharding.py`` derives PartitionSpecs with divisibility checks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# module-free parameter system
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dims: Tuple[str, ...]            # logical dim names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones
    scale: float = 1.0
    dtype: Any = DTYPE

    def materialize(self, key) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)


def init_params(specs, key):
    """Materialize a pytree of ParamSpec into arrays with split keys."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [l.materialize(k) for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def spec_shapes(specs):
    """ParamSpec pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# norms / activations / rope
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd] or [..., S, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, hd/2]
    if x.ndim == ang.ndim + 1:                                  # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attn_param_specs(cfg, prefix_scale=1.0) -> Dict[str, ParamSpec]:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": ParamSpec((d, nh * hd), ("D", "H")),
        "wk": ParamSpec((d, nkv * hd), ("D", "KV")),
        "wv": ParamSpec((d, nkv * hd), ("D", "KV")),
        "wo": ParamSpec((nh * hd, d), ("H", "D"), scale=prefix_scale),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((nh * hd,), ("H",), init="zeros")
        p["bk"] = ParamSpec((nkv * hd,), ("KV",), init="zeros")
        p["bv"] = ParamSpec((nkv * hd,), ("KV",), init="zeros")
    return p


def qkv_proj(p, x, cfg, positions):
    """x: [B, S, D] -> q [B, S, nh, hd], k/v [B, S, nkv, hd] with RoPE."""
    B, S, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, nh, hd)
    k = k.reshape(B, S, nkv, hd)
    v = v.reshape(B, S, nkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def blocked_causal_attention(q, k, v, *, chunk: int = 1024,
                             window: int = 0) -> jnp.ndarray:
    """Memory-bounded causal attention via lax.scan over KV chunks
    (online softmax).  q,k,v: [B, S, H, hd] (k/v already head-repeated).
    ``window`` > 0 enables sliding-window masking.
    """
    B, S, H, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    n_chunks = max(S // chunk, 1)
    chunk = S // n_chunks
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)   # [B,H,S,hd]
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    kc = kf.reshape(B, H, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = vf.reshape(B, H, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    q_pos = jnp.arange(S)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        k_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj)                # [B,H,S,chunk]
        mask = q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vj)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, H, S), -1e30, jnp.float32),
            jnp.zeros((B, H, S), jnp.float32),
            jnp.zeros((B, H, S, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)             # [B,S,H,hd]


def dense_attention_block(p, x, cfg, positions, *, window: int = 0):
    """Full training/prefill attention for one layer. x: [B, S, D]."""
    B, S, _ = x.shape
    q, k, v = qkv_proj(p, x, cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    out = blocked_causal_attention(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep),
                                   window=window)
    return out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"], (k, v)


def decode_attention(q, k_cache, v_cache, length_mask):
    """Single-token decode attention over an explicit KV set.
    q: [B, nh, hd]; k/v_cache: [B, T, nkv, hd]; length_mask: [B, T] bool."""
    B, T, nkv, hd = k_cache.shape
    nh = q.shape[1]
    n_rep = nh // nkv
    scale = 1.0 / np.sqrt(hd)
    qf = q.astype(jnp.float32).reshape(B, nkv, n_rep, hd) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bgrd,btgd->bgrt", qf, kf)
    s = jnp.where(length_mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrt,btgd->bgrd", p, vf)
    return out.reshape(B, nh, hd).astype(k_cache.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_param_specs(cfg) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("D", "F")),
        "w_up": ParamSpec((d, f), ("D", "F")),
        "w_down": ParamSpec((f, d), ("F", "D")),
    }


def mlp_block(p, x):
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
