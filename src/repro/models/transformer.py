"""Decoder-only LM assembly for every assigned architecture.

A model is a list of **segments**, each scanned with ``lax.scan`` over
stacked parameters (keeping HLO size independent of depth):

  - ``dense``        one (attn + MLP) layer per iteration
  - ``moe``          one (attn + MoE) layer per iteration (dbrx / mixtral)
  - ``mla_moe``      one (MLA attn + MoE) layer (deepseek-v32)
  - ``lg_super``     gemma3 super-block: 5 local-window layers + 1 global
  - ``zamba_super``  zamba2 super-block: 6 Mamba2 layers + tied shared-attn
  - ``mamba_tail``   trailing plain Mamba2 layers (zamba2: 81 = 13*6 + 3)
  - ``xlstm_super``  xLSTM super-block: 3 mLSTM + 1 sLSTM

Three entry points per model (all pure functions of (params, state, in)):
  ``forward``  — full-sequence causal LM (training), dense attention;
  ``prefill``  — forward + emit the SAC pool (KV entries + indexer keys);
  ``decode``   — one token per request over the pool: indexer -> top-k ->
                 fetch (injected ``fetch_fn``: the SAC read path) -> sparse
                 attention -> write-back of the new entry.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hisparse
from repro.core import sac as sac_core
from repro.core.pool import FetchFn, local_fetch, pool_write
from repro.distributed.sharding import constrain
from repro.models import dsa, moe, ssm
from repro.models.layers import (DTYPE, ParamSpec, attn_param_specs,
                                 blocked_causal_attention,
                                 dense_attention_block, init_params,
                                 mlp_block, mlp_param_specs, rms_norm,
                                 spec_shapes)


# ---------------------------------------------------------------------------
# segment descriptors
# ---------------------------------------------------------------------------


_OPTS = threading.local()


def _opt(name: str, default=None):
    return getattr(_OPTS, "d", {}).get(name, default)


@contextlib.contextmanager
def _use_opts(d: Dict):
    old = getattr(_OPTS, "d", None)
    _OPTS.d = d or {}
    try:
        yield
    finally:
        _OPTS.d = old or {}


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    n: int                 # scan length
    kv_per_iter: int       # pool (attention) layers per iteration
    window: int = 0        # sliding window for this segment's attn layers


def build_segments(cfg: ModelConfig) -> List[Segment]:
    if cfg.xlstm:
        assert cfg.n_layers % 4 == 0, "xlstm stacks groups of 3 mLSTM + 1 sLSTM"
        return [Segment("xlstm_super", cfg.n_layers // 4, 0)]
    if cfg.ssm_state:  # zamba2 hybrid
        period = cfg.shared_attn_every
        n_super = cfg.n_layers // period
        tail = cfg.n_layers - n_super * period
        segs = [Segment("zamba_super", n_super, 1)]
        if tail:
            segs.append(Segment("mamba_tail", tail, 0))
        return segs
    if cfg.local_global_ratio:  # gemma3
        period = cfg.local_global_ratio + 1
        assert cfg.n_layers % period == 0
        return [Segment("lg_super", cfg.n_layers // period, period,
                        window=cfg.local_window)]
    if cfg.mla:
        return [Segment("mla_moe" if cfg.n_experts else "mla_dense",
                        cfg.n_layers, 1)]
    if cfg.n_experts:
        return [Segment("moe", cfg.n_layers, 1, window=cfg.sliding_window)]
    return [Segment("dense", cfg.n_layers, 1, window=cfg.sliding_window)]


def n_kv_layers(cfg: ModelConfig) -> int:
    return sum(s.n * s.kv_per_iter for s in build_segments(cfg))


def kv_layer_windows(cfg: ModelConfig) -> List[int]:
    """Sliding window per pool (attention) layer, in pool-layer order
    (0 = full attention).  Length == n_kv_layers(cfg); used by the fetch
    planner to avoid seeding windowed layers with positions their decode
    mask can never select."""
    wins: List[int] = []
    for seg in build_segments(cfg):
        if not seg.kv_per_iter:
            continue
        if seg.kind == "lg_super":
            per_iter = [cfg.local_window] * cfg.local_global_ratio + [0]
        else:
            per_iter = [seg.window] * seg.kv_per_iter
        wins.extend(per_iter * seg.n)
    return wins


def kv_entry_dim(cfg: ModelConfig) -> int:
    if not cfg.has_attention:
        return 0
    if cfg.mla:
        return cfg.kv_lora_rank + cfg.qk_rope_dim
    return dsa.gqa_entry_dim(cfg)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def _norm(cfg, name="g"):
    return ParamSpec((cfg.d_model,), ("G",), init="ones")


def _stack(specs, n: int):
    """Add a leading stacked-layer axis of size n to every ParamSpec leaf."""
    def one(s: ParamSpec):
        return ParamSpec((n, *s.shape), ("L", *s.dims), s.init, s.scale,
                         s.dtype)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _attn_layer_specs(cfg) -> Dict[str, Any]:
    p: Dict[str, Any] = {"ln1": _norm(cfg), "ln2": _norm(cfg)}
    p["attn"] = (dsa.mla_param_specs(cfg) if cfg.mla
                 else attn_param_specs(cfg))
    if cfg.sac.enabled:
        p["idx"] = dsa.indexer_param_specs(cfg)
    p["mlp"] = (moe.moe_param_specs(cfg) if cfg.n_experts
                else mlp_param_specs(cfg))
    return p


def segment_specs(seg: Segment, cfg: ModelConfig):
    if seg.kind in ("dense", "moe", "mla_dense", "mla_moe"):
        return _stack(_attn_layer_specs(cfg), seg.n)
    if seg.kind == "lg_super":
        one = _attn_layer_specs(cfg)
        return _stack({"local": _stack(one, cfg.local_global_ratio),
                       "global": one}, seg.n)
    if seg.kind == "zamba_super":
        inner = {"ln": _norm(cfg), "mamba": ssm.mamba2_param_specs(cfg)}
        return _stack({"mamba_layers": _stack(inner, cfg.shared_attn_every)},
                      seg.n)
    if seg.kind == "mamba_tail":
        return _stack({"ln": _norm(cfg), "mamba": ssm.mamba2_param_specs(cfg)},
                      seg.n)
    if seg.kind == "xlstm_super":
        return _stack({"mlstm": _stack({"ln": _norm(cfg),
                                        **ssm.mlstm_param_specs(cfg)}, 3),
                       "slstm": {"ln": _norm(cfg),
                                 **ssm.slstm_param_specs(cfg)}}, seg.n)
    raise ValueError(seg.kind)


def model_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    specs: Dict[str, Any] = {
        "embed": ParamSpec((v, d), ("V", "D"), scale=1.0),
        "segments": [segment_specs(s, cfg) for s in build_segments(cfg)],
        "final_norm": _norm(cfg),
        "lm_head": ParamSpec((d, v), ("D", "V")),
    }
    if cfg.ssm_state and cfg.shared_attn_every:
        # zamba2 tied shared-attention block (one set of weights, applied
        # after every 6th mamba layer)
        specs["shared"] = _attn_layer_specs(cfg)
    return specs


# ---------------------------------------------------------------------------
# forward (training) layer bodies
# ---------------------------------------------------------------------------


def _mlp_apply(p_mlp, x, cfg, *, decode: bool = False):
    """MLP or MoE on [B, S, D]; returns (out, aux).

    Grouped dispatch applies to full-sequence (train/prefill) calls only:
    decode steps route a handful of tokens — grouping them fragments the
    expert batches and regresses the collective term (§Perf B-series).
    """
    if cfg.n_experts:
        groups = 1 if decode else _opt("moe_groups", 1)
        out, aux = moe.moe_block(p_mlp, x, cfg, groups=groups)
        return out, aux
    h = constrain(x @ p_mlp["w_gate"], ("B", "Sq", "F"))
    h = jax.nn.silu(h) * (x @ p_mlp["w_up"])
    out = h @ p_mlp["w_down"]
    return out, jnp.float32(0)


def _attn_fwd(p, x, cfg, positions, window):
    """Shared attn sub-block on [B,S,D] -> (delta, entries, idx_keys,
    warm_idx).

    ``warm_idx`` ([B, w] int32, or None) is the layer's prefill warm-up
    candidate set when the ``warmup_w`` opt is on: the top-``w`` prompt
    positions by indexer score against the LAST prompt position's
    activations — the closest in-graph proxy for the first decode step's
    query, used by serving/prefetch.py to seed the HiSparse hot tier.
    """
    xn = rms_norm(x, p["ln1"])
    if cfg.mla:
        out, entry = dsa.mla_prefill_attention(p["attn"], xn, cfg, positions)
    else:
        out, (k, v) = dense_attention_block(p["attn"], xn, cfg, positions,
                                            window=window)
        entry = dsa.pack_kv_entry(k, v)
    idx_keys = (dsa.indexer_keys(p["idx"], xn) if cfg.sac.enabled else None)
    warm = None
    w = _opt("warmup_w", 0)
    if w and cfg.sac.enabled:
        scores = dsa.indexer_scores(p["idx"], xn[:, -1], idx_keys, cfg)
        if window:
            # windowed layers only ever select from the trailing window
            # at decode time — seeding anything older is guaranteed waste
            S = scores.shape[-1]
            pos = jnp.arange(S, dtype=jnp.int32)
            scores = jnp.where(pos[None, :] > S - window, scores,
                               dsa.NEG_INF)
        ws, warm = jax.lax.top_k(scores, min(w, scores.shape[-1]))
        # masked-out lanes -> -1: the planner turns them into invalid
        # warm-insert lanes instead of seeding position 0 junk
        warm = jnp.where(ws > dsa.NEG_INF / 2, warm, -1).astype(jnp.int32)
    return out, entry, idx_keys, warm


def _layer_fwd(p, x, cfg, positions, window):
    """Full (attn + mlp) layer.  Returns (x', entry, idx_keys, warm, aux)."""
    delta, entry, idx_keys, warm = _attn_fwd(p, x, cfg, positions, window)
    x = constrain(x + delta, ("B", "S", "D"))
    out, aux = _mlp_apply(p["mlp"], rms_norm(x, p["ln2"]), cfg)
    x = constrain(x + out, ("B", "S", "D"))
    return x, entry, idx_keys, warm, aux


def _mamba_fwd(p, x, cfg):
    out, _ = ssm.mamba2_block(p["mamba"], rms_norm(x, p["ln"]), cfg,
                              chunk=_opt("ssm_chunk", 256))
    return constrain(x + out, ("B", "S", "D"))


def segment_fwd(seg: Segment, cfg: ModelConfig, shared_params=None,
                collect_entries: bool = True):
    """Build the scan body for a segment's full-sequence forward.

    Body: (x, p_slice, positions) -> (x', (entries, idx_keys), aux)
    entries: [kv_per_iter, B, S, d_kv] or None.
    """

    def stack_entries(es, ks, ws):
        if not collect_entries or not es:
            return None
        e = jnp.stack(es, 0)
        k = jnp.stack(ks, 0) if cfg.sac.enabled else jnp.zeros(())
        wm = (jnp.stack(ws, 0) if ws and ws[0] is not None
              else jnp.zeros(()))
        return (e, k, wm)

    if seg.kind in ("dense", "moe", "mla_dense", "mla_moe"):
        def body(x, p, positions):
            x, entry, ikeys, wm, aux = _layer_fwd(p, x, cfg, positions,
                                                  seg.window)
            return x, stack_entries([entry], [ikeys], [wm]), aux
        return body

    if seg.kind == "lg_super":
        def body(x, p, positions):
            es, ks, ws, aux = [], [], [], jnp.float32(0)
            for i in range(cfg.local_global_ratio):
                pl = jax.tree.map(lambda a: a[i], p["local"])
                x, e, kk, wm, a = _layer_fwd(pl, x, cfg, positions,
                                             cfg.local_window)
                es.append(e); ks.append(kk); ws.append(wm); aux += a
            x, e, kk, wm, a = _layer_fwd(p["global"], x, cfg, positions, 0)
            es.append(e); ks.append(kk); ws.append(wm); aux += a
            return x, stack_entries(es, ks, ws), aux
        return body

    if seg.kind == "zamba_super":
        def body(x, p, positions):
            for i in range(cfg.shared_attn_every):
                pl = jax.tree.map(lambda a: a[i], p["mamba_layers"])
                x = _mamba_fwd(pl, x, cfg)
            x, entry, ikeys, wm, aux = _layer_fwd(shared_params, x, cfg,
                                                  positions, 0)
            return x, stack_entries([entry], [ikeys], [wm]), aux
        return body

    if seg.kind == "mamba_tail":
        def body(x, p, positions):
            return _mamba_fwd(p, x, cfg), None, jnp.float32(0)
        return body

    if seg.kind == "xlstm_super":
        def body(x, p, positions):
            for i in range(3):
                pl = jax.tree.map(lambda a: a[i], p["mlstm"])
                x = x + ssm.mlstm_block(pl, rms_norm(x, pl["ln"]), cfg)
            ps = p["slstm"]
            x = x + ssm.slstm_block(ps, rms_norm(x, ps["ln"]), cfg)
            return constrain(x, ("B", "S", "D")), None, jnp.float32(0)
        return body

    raise ValueError(seg.kind)


# ---------------------------------------------------------------------------
# decode layer bodies
# ---------------------------------------------------------------------------


def _attn_decode(p, x, cfg, ctx, kv_slice, idx_slice, window, hbuf=None):
    """One attention layer's decode.  x: [B, D]; kv_slice: [B, S, d].

    Returns (delta [B,D], new_entry [B,d_kv], new_idx_key [B,d_idx],
    new_hbuf, hits [B], misses [B]).  ``hbuf`` is this layer's HiSparse
    hot-tier state (core/hisparse.py) or None; the last three outputs are
    None unless a buffer was threaded in.
    """
    xn = rms_norm(x, p["ln1"])
    positions, cache_len = ctx["positions"], ctx["cache_len"]
    if cfg.mla:
        own = dsa.mla_kv_entry(p["attn"], xn, cfg, positions)
    else:
        own = dsa.gqa_kv_entry(p["attn"], xn, cfg, positions)
    if ctx["mode"] == "dense" or not cfg.sac.enabled:
        if window:
            delta = sac_core.window_attend(
                p["attn"], xn, cfg, kv_slice, cache_len, positions, own,
                window, fetch_fn=ctx["fetch_fn"])
        else:
            delta = sac_core.dense_attend(p["attn"], xn, cfg, kv_slice,
                                          cache_len, positions, own)
        new_key = jnp.zeros((x.shape[0], cfg.sac.d_idx), DTYPE)
        if hbuf is not None:   # keep scan pytree structure: untouched buffer
            zero = jnp.zeros((x.shape[0],), jnp.int32)
            return delta, own, new_key, hbuf, zero, zero
        return delta, own, new_key, None, None, None
    # SAC path: indexer -> top-k -> fetch -> sparse attention
    new_key = dsa.indexer_keys(p["idx"], xn)
    if hbuf is None:
        delta = sac_core.sparse_attend(
            p["attn"], p["idx"], xn, cfg, kv_slice, idx_slice, cache_len,
            positions, own, fetch_fn=ctx["fetch_fn"],
            topk_fn=ctx.get("topk_fn"), window=window)
        return delta, own, new_key, None, None, None
    # buffered read-through: values are bit-identical, but residency is
    # measured so the host charges only misses to the fabric (paper §5.5);
    # prefetch_width > 0 additionally warm-inserts next-step speculation
    # into the hot tier (counted in the buffer's pf_* fields)
    delta, hbuf, hits, misses = sac_core.sparse_attend(
        p["attn"], p["idx"], xn, cfg, kv_slice, idx_slice, cache_len,
        positions, own, fetch_fn=ctx["fetch_fn"], topk_fn=ctx.get("topk_fn"),
        window=window, buf_state=hbuf,
        prefetch_width=ctx.get("prefetch_width", 0),
        prefetch_fn=ctx.get("prefetch_fn"),
        score_margin=ctx.get("score_margin", -1.0),
        pf_budget=ctx.get("pf_budget"))
    return delta, own, new_key, hbuf, hits, misses


def _layer_decode(p, x, cfg, ctx, kv_slice, idx_slice, window, hbuf=None):
    delta, own, new_key, hbuf2, hits, misses = _attn_decode(
        p, x, cfg, ctx, kv_slice, idx_slice, window, hbuf)
    x = x + delta
    out, _ = _mlp_apply(p["mlp"], rms_norm(x, p["ln2"])[:, None, :], cfg,
                        decode=True)
    x = x + out[:, 0]
    return constrain(x, ("B", "D")), own, new_key, hbuf2, hits, misses


def _hb_layer(hb, i):
    """Slice layer ``i`` of an [a, ...]-stacked hot-buffer tree (or None)."""
    return None if hb is None else jax.tree.map(lambda t: t[i], hb)


def _hb_stack(hbs):
    """Stack per-layer hot-buffer states back to [a, ...] (or None)."""
    if not hbs or hbs[0] is None:
        return None
    return jax.tree.map(lambda *a: jnp.stack(a), *hbs)


def _hm_sum(hits, misses):
    """Stack per-layer hit/miss counts ([B] each) into ([a, B], [a, B]).

    Kept per-layer (not summed) so the host can measure per-layer miss
    rates — the signal the ``LayerSizer`` (serving/arbiter.py) apportions
    hot-tier slots by.  The decode assembly reduces over layers for the
    per-request ``buf_hits``/``buf_misses`` totals.
    """
    if not hits or hits[0] is None:
        return None
    return (jnp.stack(hits), jnp.stack(misses))


def segment_decode(seg: Segment, cfg: ModelConfig, shared_params=None):
    """Scan body for decode.

    (x, p_slice, kv_slices [a,B,S,d], idx_slices, hbuf_slices, rec_slice,
     ctx) -> (x', new_entries [a,B,d], new_keys [a,B,di], new_hbuf,
              (hits [B], misses [B]) | None, new_rec)

    ``hbuf_slices`` is the segment's per-iteration stack of HiSparse
    hot-buffer states ([a, ...] leading axes) or None; hit/miss counts
    are summed over the iteration's attention layers.
    """
    if seg.kind in ("dense", "moe", "mla_dense", "mla_moe"):
        def body(x, p, kv, ik, hb, rec, ctx):
            x, own, key, hb2, h, m = _layer_decode(
                p, x, cfg, ctx, kv[0], None if ik is None else ik[0],
                seg.window, _hb_layer(hb, 0))
            return (x, own[None], key[None], _hb_stack([hb2]),
                    _hm_sum([h], [m]), rec)
        return body

    if seg.kind == "lg_super":
        def body(x, p, kv, ik, hb, rec, ctx):
            owns, keys, hbs, hs, ms = [], [], [], [], []
            for i in range(cfg.local_global_ratio):
                pl = jax.tree.map(lambda a: a[i], p["local"])
                x, own, key, hb2, h, m = _layer_decode(
                    pl, x, cfg, ctx, kv[i], None if ik is None else ik[i],
                    cfg.local_window, _hb_layer(hb, i))
                owns.append(own); keys.append(key)
                hbs.append(hb2); hs.append(h); ms.append(m)
            g = cfg.local_global_ratio
            x, own, key, hb2, h, m = _layer_decode(
                p["global"], x, cfg, ctx, kv[g],
                None if ik is None else ik[g], 0, _hb_layer(hb, g))
            owns.append(own); keys.append(key)
            hbs.append(hb2); hs.append(h); ms.append(m)
            return (x, jnp.stack(owns), jnp.stack(keys), _hb_stack(hbs),
                    _hm_sum(hs, ms), rec)
        return body

    if seg.kind == "zamba_super":
        def body(x, p, kv, ik, hb, rec, ctx):
            new_rec = []
            for i in range(cfg.shared_attn_every):
                pl = jax.tree.map(lambda a: a[i], p["mamba_layers"])
                st = jax.tree.map(lambda a: a[i], rec)
                out, st2 = ssm.mamba2_decode(pl["mamba"],
                                             rms_norm(x, pl["ln"]), cfg, st)
                x = x + out
                new_rec.append(st2)
            x, own, key, hb2, h, m = _layer_decode(
                shared_params, x, cfg, ctx, kv[0],
                None if ik is None else ik[0], 0, _hb_layer(hb, 0))
            rec_out = jax.tree.map(lambda *a: jnp.stack(a), *new_rec)
            return (x, own[None], key[None], _hb_stack([hb2]),
                    _hm_sum([h], [m]), rec_out)
        return body

    if seg.kind == "mamba_tail":
        def body(x, p, kv, ik, hb, rec, ctx):
            out, rec2 = ssm.mamba2_decode(p["mamba"], rms_norm(x, p["ln"]),
                                          cfg, rec)
            return x + out, None, None, None, None, rec2
        return body

    if seg.kind == "xlstm_super":
        def body(x, p, kv, ik, hb, rec, ctx):
            m_rec, s_rec = rec
            new_m = []
            for i in range(3):
                pl = jax.tree.map(lambda a: a[i], p["mlstm"])
                st = jax.tree.map(lambda a: a[i], m_rec)
                out, st2 = ssm.mlstm_decode(pl, rms_norm(x, pl["ln"]), cfg, st)
                x = x + out
                new_m.append(st2)
            ps = p["slstm"]
            out, s2 = ssm.slstm_decode(ps, rms_norm(x, ps["ln"]), cfg, s_rec)
            x = x + out
            m_out = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
            return x, None, None, None, None, (m_out, s2)
        return body

    raise ValueError(seg.kind)


# ---------------------------------------------------------------------------
# recurrent-state builders
# ---------------------------------------------------------------------------


def segment_rec_shapes(seg: Segment, cfg: ModelConfig, batch: int):
    """ShapeDtypeStructs of one scan-iteration's recurrent state."""
    if seg.kind == "zamba_super":
        (ssm_s, conv_s) = ssm.mamba2_state_shape(cfg, batch)
        a = cfg.shared_attn_every
        return (jax.ShapeDtypeStruct((a, *ssm_s), jnp.float32),
                jax.ShapeDtypeStruct((a, *conv_s), DTYPE))
    if seg.kind == "mamba_tail":
        (ssm_s, conv_s) = ssm.mamba2_state_shape(cfg, batch)
        return (jax.ShapeDtypeStruct(ssm_s, jnp.float32),
                jax.ShapeDtypeStruct(conv_s, DTYPE))
    if seg.kind == "xlstm_super":
        d, nh = cfg.d_model, cfg.n_heads
        hd = d // nh
        m = (jax.ShapeDtypeStruct((3, batch, nh, hd, hd), jnp.float32),
             jax.ShapeDtypeStruct((3, batch, nh, hd), jnp.float32),
             jax.ShapeDtypeStruct((3, batch, nh), jnp.float32))
        s = tuple(jax.ShapeDtypeStruct((batch, d), jnp.float32)
                  for _ in range(4))
        return (m, s)
    return None


def _stacked_rec_shapes(seg: Segment, cfg, batch):
    per = segment_rec_shapes(seg, cfg, batch)
    if per is None:
        return None
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((seg.n, *s.shape), s.dtype), per)


# ---------------------------------------------------------------------------
# the model facade
# ---------------------------------------------------------------------------


class TransformerLM:
    """build once per (cfg, fetch_fn, mode); all methods are pure."""

    def __init__(self, cfg: ModelConfig, fetch_fn: FetchFn = local_fetch,
                 mode: str = "sac", topk_fn: Optional[Callable] = None,
                 remat: bool = True, opts: Optional[Dict] = None):
        self.cfg = cfg
        self.fetch_fn = fetch_fn
        self.mode = mode if cfg.sac.enabled else "dense"
        self.topk_fn = topk_fn
        self.remat = remat
        self.opts = opts or {}
        self.segments = build_segments(cfg)
        self.specs = model_param_specs(cfg)
        self.n_kv = n_kv_layers(cfg)
        self.kv_dim = kv_entry_dim(cfg)
        # beyond-paper: fp8 pool storage halves pool HBM + fetch traffic.
        # The fetch psum is an exactly-one-owner reduction (masked zeros
        # elsewhere), so low-precision summation is bit-exact.
        self.kv_dtype = (jnp.float8_e4m3fn if cfg.sac.kv_quant == "fp8"
                         else DTYPE)

    # -- params ------------------------------------------------------------
    def init(self, key) -> Dict:
        return init_params(self.specs, key)

    def param_shapes(self):
        return spec_shapes(self.specs)

    # -- training forward ----------------------------------------------------
    def forward(self, params, tokens) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens [B, S] -> (logits [B, S, V], aux_loss)."""
        with _use_opts(self.opts):
            return self._forward(params, tokens)

    def _forward(self, params, tokens):
        x, positions = self._embed_seq(params, tokens)
        aux_total = jnp.float32(0)
        for si, seg in enumerate(self.segments):
            body = segment_fwd(seg, self.cfg, params.get("shared"),
                               collect_entries=False)

            def scan_body(carry, p, _body=body):
                x, aux = carry
                x, _, a = _body(x, p, positions)
                return (x, aux + a), None

            if self.remat:
                scan_body = jax.checkpoint(scan_body)
            (x, aux_total), _ = jax.lax.scan(
                scan_body, (x, aux_total), params["segments"][si])
        return self._logits(params, x), aux_total

    # -- prefill -------------------------------------------------------------
    def prefill(self, params, tokens, lengths=None):
        """tokens [B, S] -> (serve_state, last_logits [B, V]).

        Writes every position's KV entry + indexer key into a fresh pool
        (the paper's prefill-instance write path).
        """
        with _use_opts(self.opts):
            return self._prefill(params, tokens, lengths)

    def _prefill(self, params, tokens, lengths=None):
        B, S = tokens.shape
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        x, positions = self._embed_seq(params, tokens)
        pools, ikeys, warms = [], [], []
        collect_warm = bool(self.opts.get("warmup_w")) and self.cfg.sac.enabled
        for si, seg in enumerate(self.segments):
            body = segment_fwd(seg, self.cfg, params.get("shared"),
                               collect_entries=True)

            def scan_body(x, p, _body=body):
                x, entries, _ = _body(x, p, positions)
                return x, entries

            x, entries = jax.lax.scan(scan_body, x, params["segments"][si])
            if entries is not None and seg.kv_per_iter:
                e, k, wm = entries
                # e: [n, a, B, S, d] -> [n*a, B, S, d]
                pools.append(e.reshape(-1, B, S, e.shape[-1]))
                if self.cfg.sac.enabled:
                    ikeys.append(k.reshape(-1, B, S, k.shape[-1]))
                if collect_warm:
                    warms.append(wm.reshape(-1, B, wm.shape[-1]))
        state = self._empty_state(B, S)
        if pools:
            state["kv_pool"] = constrain(
                jnp.concatenate(pools, 0).astype(self.kv_dtype),
                ("L", "B", "SP", "G"))
            if self.cfg.sac.enabled:
                state["idx_pool"] = constrain(
                    jnp.concatenate(ikeys, 0).astype(DTYPE),
                    ("L", "B", "SP", "G"))
            if warms:
                # per-layer top-scoring prompt positions [L, B, w]: the
                # prefill-time warm-up plan consumed by serving/prefetch.py
                # (popped by the engine — NOT part of the serve state)
                state["warm_idx"] = jnp.concatenate(warms, 0)
        state["cache_len"] = lengths
        # recurrent archs: replay the sequence through decode to build state
        # (prefill for SSMs is exercised via forward(); serving starts decode
        # from the scanned final states — built by running mamba/xlstm fwd
        # with state collection, omitted for pool archs.)
        last_idx = jnp.clip(lengths - 1, 0, S - 1)
        logits = self._logits(params, x)
        last = jnp.take_along_axis(
            logits, last_idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return state, last

    # -- decode ----------------------------------------------------------------
    def decode(self, params, state, tokens, pf_budget=None):
        """One decode step.  tokens [B] -> (state', logits [B, V]).

        ``pf_budget`` ([B] int32 or None) is the step's arbiter-granted
        speculative width per request (serving/arbiter.py): it caps how
        many speculation lanes each request may warm-insert — traffic
        shaping only, decoded tokens never depend on it."""
        with _use_opts(self.opts):
            return self._decode(params, state, tokens, pf_budget)

    def _decode(self, params, state, tokens, pf_budget=None):
        cfg = self.cfg
        B = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0).astype(DTYPE)
        x = constrain(x, ("B", "D"))
        cache_len = state["cache_len"]
        ctx = {
            "positions": cache_len,       # 0-indexed position of new token
            "cache_len": cache_len,
            "fetch_fn": self.fetch_fn,
            "topk_fn": self.topk_fn,
            "mode": self.mode,
            "prefetch_width": int(self.opts.get("prefetch_width", 0)),
            "prefetch_fn": self.opts.get("prefetch_fn"),
            "score_margin": float(self.opts.get("score_margin", -1.0)),
            "pf_budget": pf_budget,
        }
        kv_pool, idx_pool = state.get("kv_pool"), state.get("idx_pool")
        hot = state.get("hot_buf")    # layered hisparse.BufferState or None
        # speculative-prefetch step deltas: the pf_* counters inside the
        # buffer are cumulative, so the step's contribution is post - pre
        pf_ins0 = hot.pf_inserted.sum(0) if hot is not None else None
        pf_use0 = hot.pf_used.sum(0) if hot is not None else None
        pool_closure = bool(self.opts.get("pool_closure"))
        use_idx = idx_pool is not None and self.mode == "sac"
        new_entries, new_keys = [], []
        hits_l, misses_l = [], []     # per-kv-layer [l, B] blocks, in order
        kv_off = 0
        for si, seg in enumerate(self.segments):
            body = segment_decode(seg, cfg, params.get("shared"))
            a = seg.kv_per_iter
            rec = state.get(f"rec_{si}")
            hb_g = None
            if hot is not None and a and kv_pool is not None:
                # this segment's hot-buffer layer block, regrouped to
                # [n, a, ...] so the scan threads one [a, ...] slice per
                # iteration (mutable xs/ys — unlike the read-only pools,
                # the buffer is UPDATED by every layer's read_through)
                hb_g = jax.tree.map(
                    lambda t: jax.lax.dynamic_slice_in_dim(
                        t, kv_off, seg.n * a, 0).reshape(
                            seg.n, a, *t.shape[1:]), hot)

            if pool_closure and a and kv_pool is not None:
                # §Perf C4: pools stay closure-captured, FLAT — each
                # iteration dynamic-slices its [a, B, S, d] layer block
                # straight out of the state buffer.  No grouped reshape
                # (which forced a layout-assignment copy of the whole
                # pool) and no scan-xs streaming (which double-buffers it).
                def scan_body(x, xs, _body=body, _off=kv_off, _a=a):
                    p, i, hb, rc = xs
                    kv = jax.lax.dynamic_slice_in_dim(
                        kv_pool, _off + i * _a, _a, 0)
                    ik = jax.lax.dynamic_slice_in_dim(
                        idx_pool, _off + i * _a, _a, 0) if use_idx else None
                    x, own, keys, hb2, hm, rc2 = _body(x, p, kv, ik, hb,
                                                       rc, ctx)
                    return x, (own, keys, hb2, hm, rc2)

                xs = (params["segments"][si],
                      jnp.arange(seg.n, dtype=jnp.int32), hb_g, rec)
                seg_off, kv_off = kv_off, kv_off + seg.n * a
            else:
                if a and kv_pool is not None:
                    S = kv_pool.shape[2]
                    kv_g = jax.lax.dynamic_slice_in_dim(
                        kv_pool, kv_off, seg.n * a, 0).reshape(
                            seg.n, a, B, S, kv_pool.shape[-1])
                    ik_g = None
                    if use_idx:
                        ik_g = jax.lax.dynamic_slice_in_dim(
                            idx_pool, kv_off, seg.n * a, 0).reshape(
                                seg.n, a, B, S, idx_pool.shape[-1])
                    seg_off, kv_off = kv_off, kv_off + seg.n * a
                else:
                    kv_g, ik_g, seg_off = None, None, kv_off

                def scan_body(x, xs, _body=body):
                    p, kv, ik, hb, rc = xs
                    x, own, keys, hb2, hm, rc2 = _body(x, p, kv, ik, hb,
                                                       rc, ctx)
                    return x, (own, keys, hb2, hm, rc2)

                xs = (params["segments"][si], kv_g, ik_g, hb_g, rec)
            x, (own, keys, hb2, hm, rec2) = jax.lax.scan(scan_body, x, xs)
            if own is not None:
                new_entries.append(own.reshape(-1, B, own.shape[-1]))
                new_keys.append(keys.reshape(-1, B, keys.shape[-1]))
            if hb2 is not None:
                # fold the segment's updated [n, a, ...] buffer block back
                # into the layered [L, ...] state
                flat = jax.tree.map(
                    lambda t: t.reshape(t.shape[0] * t.shape[1],
                                        *t.shape[2:]), hb2)
                hot = jax.tree.map(
                    lambda full, upd, _o=seg_off:
                        jax.lax.dynamic_update_slice_in_dim(full, upd, _o, 0),
                    hot, flat)
            if hm is not None:
                # hm: ([n, a, B], [n, a, B]) — flatten to this segment's
                # kv layers in pool order
                hits_l.append(hm[0].reshape(-1, B))
                misses_l.append(hm[1].reshape(-1, B))
            if rec2 is not None:
                state = dict(state)
                state[f"rec_{si}"] = rec2
        state = dict(state)
        if new_entries and kv_pool is not None:
            state["kv_pool"] = pool_write(
                kv_pool, jnp.concatenate(new_entries, 0), cache_len)
            if idx_pool is not None:
                state["idx_pool"] = pool_write(
                    idx_pool, jnp.concatenate(new_keys, 0), cache_len)
        if hot is not None:
            state["hot_buf"] = hot
            # per-step measured hot-tier outcomes, per layer ([L, B]) and
            # summed; the engine charges miss-only fabric traffic from the
            # totals and feeds the per-layer miss rates to the LayerSizer
            hl = (jnp.concatenate(hits_l, 0) if hits_l
                  else jnp.zeros((self.n_kv, B), jnp.int32))
            ml = (jnp.concatenate(misses_l, 0) if misses_l
                  else jnp.zeros((self.n_kv, B), jnp.int32))
            state["buf_hits_l"] = hl
            state["buf_misses_l"] = ml
            state["buf_hits"] = hl.sum(0)
            state["buf_misses"] = ml.sum(0)
            state["pf_inserted"] = hot.pf_inserted.sum(0) - pf_ins0
            state["pf_useful"] = hot.pf_used.sum(0) - pf_use0
        state["cache_len"] = cache_len + 1
        x = rms_norm(x, params["final_norm"])
        logits = (x @ params["lm_head"]).astype(jnp.float32)
        return state, constrain(logits, ("B", "V"))

    # -- state builders ---------------------------------------------------------
    def _empty_state(self, batch: int, seq_len: int,
                     device_buffer=0, buffer_width=None) -> Dict:
        """``device_buffer`` is the hot-tier size per layer: one int
        (uniform) or a per-layer sequence (serving/arbiter.py LayerSizer
        apportioning, realized by hisparse DISABLED slot markers).
        ``buffer_width`` overrides the static allocation width (>= every
        per-layer size) — the headroom online re-sizing
        (hisparse.resize_layers) needs to grow layers later."""
        cfg = self.cfg
        buffered = (max(device_buffer) if isinstance(device_buffer,
                                                     (list, tuple))
                    else device_buffer)
        state: Dict[str, Any] = {"cache_len": jnp.zeros((batch,), jnp.int32)}
        if self.n_kv:
            state["kv_pool"] = jnp.zeros(
                (self.n_kv, batch, seq_len, self.kv_dim), self.kv_dtype)
            if cfg.sac.enabled:
                state["idx_pool"] = jnp.zeros(
                    (self.n_kv, batch, seq_len, cfg.sac.d_idx), DTYPE)
            if buffered and cfg.sac.enabled and self.mode == "sac":
                # HiSparse hot tier: per-(layer, request) device buffer;
                # the decode step reads through it and reports measured
                # per-request hit/miss counts in buf_hits/buf_misses.
                state["hot_buf"] = hisparse.init_layered_buffer(
                    self.n_kv, batch, device_buffer, seq_len, self.kv_dim,
                    self.kv_dtype, buf_max=buffer_width)
                state["buf_hits"] = jnp.zeros((batch,), jnp.int32)
                state["buf_misses"] = jnp.zeros((batch,), jnp.int32)
                # per-layer split of the same counters (LayerSizer signal)
                state["buf_hits_l"] = jnp.zeros((self.n_kv, batch),
                                                jnp.int32)
                state["buf_misses_l"] = jnp.zeros((self.n_kv, batch),
                                                  jnp.int32)
                # per-step speculative-prefetch outcomes (fetch pipeline)
                state["pf_inserted"] = jnp.zeros((batch,), jnp.int32)
                state["pf_useful"] = jnp.zeros((batch,), jnp.int32)
        for si, seg in enumerate(self.segments):
            shapes = _stacked_rec_shapes(seg, cfg, batch)
            if shapes is not None:
                state[f"rec_{si}"] = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        return state

    def serve_state_shapes(self, batch: int, seq_len: int,
                           device_buffer=0, buffer_width=None) -> Dict:
        """ShapeDtypeStruct pytree of the serve state (dry-run input specs).

        Traced abstractly (zero allocation) so dry-runs can lower against
        arbitrarily large states."""
        return jax.eval_shape(
            lambda: self._empty_state(batch, seq_len, device_buffer,
                                      buffer_width))

    def init_serve_state(self, batch: int, seq_len: int,
                         device_buffer=0, buffer_width=None) -> Dict:
        return self._empty_state(batch, seq_len, device_buffer,
                                 buffer_width)

    # -- shared pieces -----------------------------------------------------------
    def _embed_seq(self, params, tokens):
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(DTYPE)
        x = constrain(x, ("B", "S", "D"))
        return x, jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)

    def _logits(self, params, x):
        x = rms_norm(x, params["final_norm"])
        logits = (x @ params["lm_head"]).astype(jnp.float32)
        return constrain(logits, ("B", "S", "V"))
