"""DeepSeek Sparse Attention (DSA) building blocks + sparse decode paths.

This implements the model-side machinery the SAC paper serves:

  - **Lightning indexer** (paper Fig 1): low-dim projected keys stored per
    token; at decode time the current query scores *all* cached positions
    ``I[t,s] = sum_h w[t,h] * ReLU(q_idx[t,h] . k_idx[s])`` and the top-k
    positions are selected.
  - **MLA** (multi-head latent attention): prefill runs the non-absorbed
    form and emits the latent cache entry ``(c_kv, k_rope)`` = 512+64 dims;
    decode runs the *absorbed* form directly over fetched latent entries.
  - **GQA sparse decode**: the same top-k machinery applied to ordinary
    GQA KV entries (how SAC generalizes beyond DeepSeek, DESIGN.md §5).

All decode paths consume a ``fetch_fn(pool_layer, idx) -> [B, k, d]``
injected by the runtime: single-device ``take_along_axis`` for tests, the
shard_map pooled-HBM collective gather (core/pool.py) at scale.  That
callback *is* the SAC read path.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamSpec, apply_rope, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# lightning indexer
# ---------------------------------------------------------------------------


def indexer_param_specs(cfg) -> Dict[str, ParamSpec]:
    d, ni, di = cfg.d_model, cfg.sac.n_idx_heads, cfg.sac.d_idx
    return {
        "wq_idx": ParamSpec((d, ni * di), ("D", "H")),
        "wk_idx": ParamSpec((d, di), ("D", "C")),
        "w_w": ParamSpec((d, ni), ("D", "C"), scale=0.1),
    }


def indexer_keys(p, x) -> jnp.ndarray:
    """Per-token indexer keys. x: [..., D] -> [..., d_idx]."""
    return x @ p["wk_idx"]


def indexer_scores(p, xq, idx_keys, cfg) -> jnp.ndarray:
    """Score all cached positions against the current query token.

    xq: [B, D] (query-token activations); idx_keys: [B, S, d_idx]
    -> scores [B, S] (f32).
    """
    B = xq.shape[0]
    ni, di = cfg.sac.n_idx_heads, cfg.sac.d_idx
    q = (xq @ p["wq_idx"]).reshape(B, ni, di).astype(jnp.float32)
    w = (xq @ p["w_w"]).astype(jnp.float32)                      # [B, ni]
    logits = jnp.einsum("bhd,bsd->bhs", q, idx_keys.astype(jnp.float32))
    logits = jax.nn.relu(logits) / np.sqrt(di)
    return jnp.einsum("bh,bhs->bs", w, logits)                   # [B, S]


def topk_select(scores: jnp.ndarray, cache_len: jnp.ndarray, k: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mask positions >= cache_len, take top-k.

    scores: [B, S]; cache_len: [B] -> (idx [B, k] int32, valid [B, k] bool).
    """
    S = scores.shape[-1]
    pos = jnp.arange(S, dtype=jnp.int32)
    masked = jnp.where(pos[None, :] < cache_len[:, None], scores, NEG_INF)
    top_scores, idx = jax.lax.top_k(masked, min(k, S))
    valid = top_scores > NEG_INF / 2
    # position-sort the selected set (invalid lanes pushed last): the
    # sparse candidate order then matches the pool order, so with k >=
    # context the sparse decode is bit-exact vs dense (float accumulation
    # order is identical), and real gathers walk the pool monotonically
    return _position_sort(idx.astype(jnp.int32), valid, S)


def _position_sort(idx: jnp.ndarray, valid: jnp.ndarray, S: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort a selected set by position (invalid lanes pushed last)."""
    order = jnp.argsort(jnp.where(valid, idx, S), axis=-1)
    return (jnp.take_along_axis(idx, order, axis=-1),
            jnp.take_along_axis(valid, order, axis=-1))


def _spec_tail(top_scores, idx, k: int, width: int,
               score_margin: float = -1.0
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Ranks [k, k+width) of a top-(k+width) result, padded to width.

    ``score_margin >= 0`` switches the tail from a pure rank window to
    **score-threshold** selection: a tail entry only qualifies while its
    score is within ``margin * (s_max - s_k)`` of the k-th demand score
    ``s_k`` (scale-free — indexer score magnitudes vary per model).  A
    flat score landscape near the cut keeps the full window; a steep
    drop-off after rank k stops speculation early, so cheap steps stop
    fetching useless tail entries.  Negative margin = rank-only (PR 2
    semantics).
    """
    lo = min(k, idx.shape[-1])
    tail_idx = idx[..., lo:].astype(jnp.int32)
    tail_scores = top_scores[..., lo:]
    tail_valid = tail_scores > NEG_INF / 2
    if score_margin >= 0 and lo > 0:
        s_max = top_scores[..., :1]
        s_k = top_scores[..., lo - 1:lo]
        thr = s_k - score_margin * (s_max - s_k)
        tail_valid = tail_valid & (tail_scores >= thr)
    pad = width - tail_idx.shape[-1]
    if pad > 0:
        tail_idx = jnp.pad(tail_idx, ((0, 0), (0, pad)))
        tail_valid = jnp.pad(tail_valid, ((0, 0), (0, pad)))
    return tail_idx, tail_valid


def speculate_next_topk(scores: jnp.ndarray, cache_len: jnp.ndarray,
                        k: int, width: int, score_margin: float = -1.0
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Speculative next-step candidates: ranks [k, k+width) of this step's
    indexer scores.

    Consecutive steps' score landscapes drift slowly, so the positions
    just below the current top-k cut are the most likely *entrants* of
    the next step's top-k — the fetch pipeline (serving/prefetch.py)
    warm-inserts them into the HiSparse hot tier so next step's churn
    hits instead of missing.  scores: [B, S]; -> (idx [B, width] int32,
    valid [B, width]); lanes beyond the candidate count are invalid.

    Standalone variant (used when the demand selection is injected via
    ``topk_fn``); the default decode path uses the fused
    :func:`topk_select_with_tail` to avoid a second top-k.
    """
    S = scores.shape[-1]
    pos = jnp.arange(S, dtype=jnp.int32)
    masked = jnp.where(pos[None, :] < cache_len[:, None], scores, NEG_INF)
    kk = min(k + width, S)
    top_scores, idx = jax.lax.top_k(masked, kk)
    return _spec_tail(top_scores, idx, k, width, score_margin)


def topk_select_with_tail(scores: jnp.ndarray, cache_len: jnp.ndarray,
                          k: int, width: int, score_margin: float = -1.0):
    """Fused demand top-k + speculation tail: ONE ``top_k(k+width)``
    serves both.

    ``top_k`` orders by (score desc, index asc), so the first
    ``min(k, S)`` lanes of the larger sort are exactly
    :func:`topk_select`'s set — position-sorted identically, the demand
    half is bit-identical to the unfused path (sparse decode results do
    not depend on whether speculation runs).  ``score_margin`` applies
    score-threshold selection to the tail only (see :func:`_spec_tail`);
    the demand half never depends on it.  Returns
    ``(idx [B, min(k,S)], valid, tail_idx [B, width], tail_valid)``.
    """
    S = scores.shape[-1]
    pos = jnp.arange(S, dtype=jnp.int32)
    masked = jnp.where(pos[None, :] < cache_len[:, None], scores, NEG_INF)
    kk = min(k + width, S)
    top_scores, idx = jax.lax.top_k(masked, kk)
    lo = min(k, kk)
    d_idx = idx[..., :lo].astype(jnp.int32)
    d_valid = top_scores[..., :lo] > NEG_INF / 2
    d_idx, d_valid = _position_sort(d_idx, d_valid, S)
    return d_idx, d_valid, *_spec_tail(top_scores, idx, k, width,
                                       score_margin)


def budget_mask(valid: jnp.ndarray, budget: jnp.ndarray) -> jnp.ndarray:
    """Cap a speculation candidate set to a per-request granted budget.

    valid: [B, w] candidate lanes (score/rank-ordered best-first);
    budget: [B] int32 granted widths from the fabric budget arbiter
    (serving/arbiter.py).  Only the first ``budget[b]`` lanes survive —
    lanes are best-first, so the cap drops the least likely entrants.
    Budgets shape *speculation traffic* only; demand selection (and thus
    decoded tokens) never flows through this mask.
    """
    lanes = jnp.arange(valid.shape[-1], dtype=jnp.int32)
    return valid & (lanes[None, :] < budget[:, None].astype(jnp.int32))


# ---------------------------------------------------------------------------
# MLA parameters
# ---------------------------------------------------------------------------


def mla_param_specs(cfg) -> Dict[str, ParamSpec]:
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.hd
    dc, dr, qr = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.q_lora_rank
    return {
        "w_dq": ParamSpec((d, qr), ("D", "C")),
        "q_norm_g": ParamSpec((qr,), ("C",), init="ones"),
        "w_uq": ParamSpec((qr, nh * (hd + dr)), ("C", "H")),
        "w_dkv": ParamSpec((d, dc + dr), ("D", "C")),
        "kv_norm_g": ParamSpec((dc,), ("C",), init="ones"),
        "w_uk": ParamSpec((dc, nh * hd), ("C", "H")),
        "w_uv": ParamSpec((dc, nh * hd), ("C", "H")),
        "wo": ParamSpec((nh * hd, d), ("H", "D")),
    }


def mla_q_proj(p, x, cfg, positions):
    """x: [B(, S), D] -> q_nope [B(,S),nh,hd], q_pe [B(,S),nh,dr] (roped)."""
    nh, hd, dr = cfg.n_heads, cfg.hd, cfg.qk_rope_dim
    lead = x.shape[:-1]
    q = rms_norm(x @ p["w_dq"], p["q_norm_g"]) @ p["w_uq"]
    q = q.reshape(*lead, nh, hd + dr)
    q_nope, q_pe = q[..., :hd], q[..., hd:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def mla_kv_entry(p, x, cfg, positions):
    """Latent cache entry for each token: [.., dc+dr] (c_kv normed, k_pe roped)."""
    dc = cfg.kv_lora_rank
    kv = x @ p["w_dkv"]
    c, k_pe = kv[..., :dc], kv[..., dc:]
    c = rms_norm(c, p["kv_norm_g"])
    k_pe = apply_rope(k_pe, positions, cfg.rope_theta)
    return jnp.concatenate([c, k_pe], axis=-1)


def mla_prefill_attention(p, x, cfg, positions, *, chunk: int = 1024):
    """Non-absorbed MLA over a full sequence (training / prefill).

    x: [B, S, D] -> (out [B, S, D], cache_entries [B, S, dc+dr]).
    """
    from repro.models.layers import blocked_causal_attention

    B, S, D = x.shape
    nh, hd, dr, dc = cfg.n_heads, cfg.hd, cfg.qk_rope_dim, cfg.kv_lora_rank
    q_nope, q_pe = mla_q_proj(p, x, cfg, positions)
    entry = mla_kv_entry(p, x, cfg, positions)
    c, k_pe = entry[..., :dc], entry[..., dc:]
    k_nope = (c @ p["w_uk"]).reshape(B, S, nh, hd)
    v = (c @ p["w_uv"]).reshape(B, S, nh, hd)
    k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :], (B, S, nh, dr))
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    # pad v with zeros so q/k/v share the last dim for the blocked kernel
    v_pad = jnp.concatenate([v, jnp.zeros((B, S, nh, dr), v.dtype)], axis=-1)
    out = blocked_causal_attention(q, k, v_pad, chunk=chunk)[..., :hd]
    return out.reshape(B, S, nh * hd) @ p["wo"], entry


def mla_absorbed_decode(p, xq, cfg, fetched, valid, positions):
    """Absorbed MLA decode over fetched latent entries.

    xq: [B, D]; fetched: [B, k, dc+dr]; valid: [B, k] bool;
    positions: [B] (query positions) -> out [B, D].
    """
    B = xq.shape[0]
    nh, hd, dr, dc = cfg.n_heads, cfg.hd, cfg.qk_rope_dim, cfg.kv_lora_rank
    q_nope, q_pe = mla_q_proj(p, xq, cfg, positions)             # [B,nh,hd],[B,nh,dr]
    w_uk = p["w_uk"].reshape(dc, nh, hd)
    # absorb: q_lat[b,h,c] = sum_d q_nope[b,h,d] * w_uk[c,h,d]
    q_lat = jnp.einsum("bhd,chd->bhc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    c = fetched[..., :dc].astype(jnp.float32)                    # [B,k,dc]
    k_pe = fetched[..., dc:].astype(jnp.float32)                 # [B,k,dr]
    scale = 1.0 / np.sqrt(hd + dr)
    s = (jnp.einsum("bhc,bkc->bhk", q_lat, c)
         + jnp.einsum("bhr,bkr->bhk", q_pe.astype(jnp.float32), k_pe)) * scale
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhk,bkc->bhc", pattn, c)                 # [B,nh,dc]
    w_uv = p["w_uv"].reshape(dc, nh, hd)
    out = jnp.einsum("bhc,chd->bhd", o_lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, nh * hd).astype(xq.dtype)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# GQA sparse / dense decode over pool entries
# ---------------------------------------------------------------------------


def gqa_entry_dim(cfg) -> int:
    return 2 * cfg.n_kv_heads * cfg.hd


def gqa_kv_entry(p, x, cfg, positions):
    """Pool entry for GQA archs: stacked (roped k, v) [.., 2*nkv*hd].

    Layout matches the decode-side ``reshape(B, k, 2, nkv, hd)``.
    """
    lead = x.shape[:-1]
    nkv, hd = cfg.n_kv_heads, cfg.hd
    k = (x @ p["wk"]).reshape(*lead, nkv, hd)
    v = (x @ p["wv"]).reshape(*lead, nkv, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(nkv, hd)
        v = v + p["bv"].reshape(nkv, hd)
    k = apply_rope(k, positions, cfg.rope_theta)
    return jnp.stack([k, v], axis=-3).reshape(*lead, 2 * nkv * hd)


def pack_kv_entry(k, v):
    """[.., S, nkv, hd] k/v (k already roped) -> [.., S, 2*nkv*hd] entries."""
    lead = k.shape[:-2]
    nkv, hd = k.shape[-2:]
    return jnp.stack([k, v], axis=-3).reshape(*lead, 2 * nkv * hd)


def gqa_q_proj(p, x, cfg, positions):
    lead = x.shape[:-1]
    nh, hd = cfg.n_heads, cfg.hd
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(*lead, nh, hd)
    return apply_rope(q, positions, cfg.rope_theta)


def gqa_sparse_decode(p, xq, cfg, fetched, valid, positions):
    """GQA attention over fetched top-k entries.

    xq: [B, D]; fetched: [B, k, 2*nkv*hd]; valid: [B, k] -> [B, D].
    """
    B, k = fetched.shape[:2]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = gqa_q_proj(p, xq, cfg, positions)                        # [B,nh,hd]
    kv = fetched.reshape(B, k, 2, nkv, hd)
    keys = kv[:, :, 0].astype(jnp.float32)                       # [B,k,nkv,hd]
    vals = kv[:, :, 1].astype(jnp.float32)
    n_rep = nh // nkv
    qf = q.astype(jnp.float32).reshape(B, nkv, n_rep, hd) / np.sqrt(hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qf, keys)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", pattn, vals)
    out = out.reshape(B, nh * hd).astype(xq.dtype)
    return out @ p["wo"]


def gqa_dense_decode(p, xq, cfg, pool_layer, cache_len, positions):
    """Dense decode over the full pool slice (RDMA-full-prefetch analogue /
    upper-bound baseline).  pool_layer: [B, S, 2*nkv*hd]."""
    B, S, _ = pool_layer.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = gqa_q_proj(p, xq, cfg, positions)
    kv = pool_layer.reshape(B, S, 2, nkv, hd)
    keys = kv[:, :, 0].astype(jnp.float32)
    vals = kv[:, :, 1].astype(jnp.float32)
    n_rep = nh // nkv
    qf = q.astype(jnp.float32).reshape(B, nkv, n_rep, hd) / np.sqrt(hd)
    s = jnp.einsum("bgrd,bsgd->bgrs", qf, keys)
    pos = jnp.arange(S, dtype=jnp.int32)
    s = jnp.where((pos[None, None, None, :] < cache_len[:, None, None, None]),
                  s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", pattn, vals)
    out = out.reshape(B, nh * hd).astype(xq.dtype)
    return out @ p["wo"]


def mla_dense_decode(p, xq, cfg, pool_layer, cache_len, positions):
    """Dense absorbed-MLA decode over the full latent pool slice."""
    B, S, _ = pool_layer.shape
    valid = jnp.arange(S, dtype=jnp.int32)[None, :] < cache_len[:, None]
    return mla_absorbed_decode(p, xq, cfg, pool_layer,
                               valid, positions)
