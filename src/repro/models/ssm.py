"""State-space / recurrent blocks: Mamba2 (SSD chunked scan) and xLSTM
(mLSTM matrix-memory + sLSTM scalar-memory).

Mamba2 follows the state-space-duality formulation: within a chunk the
output is computed quadratically, states are passed between chunks with a
lax.scan — O(S * N * d) total, constant-memory decode.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamSpec, rms_norm


# ---------------------------------------------------------------------------
# Mamba2 (zamba2 backbone)
# ---------------------------------------------------------------------------


def mamba2_dims(cfg):
    d_inner = 2 * cfg.d_model
    head_d = 64
    n_heads = d_inner // head_d
    return d_inner, n_heads, head_d, cfg.ssm_state


def mamba2_param_specs(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_inner, nh, hd, N = mamba2_dims(cfg)
    return {
        "w_in": ParamSpec((d, 2 * d_inner + 2 * N + nh), ("D", "F")),  # x,z,B,C,dt
        "conv": ParamSpec((4, d_inner), ("C4", "F"), scale=0.5),
        "A_log": ParamSpec((nh,), ("Hm",), init="zeros"),
        "dt_bias": ParamSpec((nh,), ("Hm",), init="zeros"),
        "D_skip": ParamSpec((nh,), ("Hm",), init="ones"),
        "norm_g": ParamSpec((d_inner,), ("F",), init="ones"),
        "w_out": ParamSpec((d_inner, d), ("F", "D")),
    }


def _mamba2_project(p, x, cfg):
    d_inner, nh, hd, N = mamba2_dims(cfg)
    zxbcdt = x @ p["w_in"]
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [nh], negative
    return z, xs, Bc, Cc, dt, A


def _causal_conv(xs, conv_w, state=None):
    """Depthwise causal conv, kernel 4. xs: [B, S, F]."""
    B, S, F = xs.shape
    k = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((B, k - 1, F), xs.dtype)
    else:
        pad = state                                              # [B, k-1, F]
    xp = jnp.concatenate([pad, xs], axis=1)
    out = sum(xp[:, i:i + S, :] * conv_w[i] for i in range(k))
    new_state = xp[:, S:, :] if state is not None else xp[:, -(k - 1):, :]
    return jax.nn.silu(out), new_state


def mamba2_block(p, x, cfg, *, chunk: int = 256):
    """Training/prefill SSD pass. x: [B, S, D] -> ([B, S, D], last_state)."""
    B, S, D = x.shape
    d_inner, nh, hd, N = mamba2_dims(cfg)
    z, xs, Bc, Cc, dt, A = _mamba2_project(p, x, cfg)
    xs, _ = _causal_conv(xs, p["conv"])
    xh = xs.reshape(B, S, nh, hd).astype(jnp.float32)
    n_chunks = max(S // chunk, 1)
    Lc = S // n_chunks

    # chunked SSD
    xh_c = xh.reshape(B, n_chunks, Lc, nh, hd)
    B_c = Bc.reshape(B, n_chunks, Lc, N).astype(jnp.float32)
    C_c = Cc.reshape(B, n_chunks, Lc, N).astype(jnp.float32)
    dt_c = dt.reshape(B, n_chunks, Lc, nh)

    dA = dt_c * A                                                # [B,c,L,nh]
    cum = jnp.cumsum(dA, axis=2)                                 # within-chunk logs

    def chunk_body(state, inp):
        xh_j, B_j, C_j, dA_j, cum_j = inp                        # [B,L,...]
        # intra-chunk quadratic part
        seg = cum_j[:, :, None, :] - cum_j[:, None, :, :]        # [B,L,L,nh]
        Lmask = jnp.tril(jnp.ones((Lc, Lc), bool))
        decay = jnp.where(Lmask[None, :, :, None], jnp.exp(seg), 0.0)
        G = jnp.einsum("bln,bmn->blm", C_j, B_j)                 # [B,L,L]
        M = G[..., None] * decay * dA_j[:, None, :, :]           # [B,L,L,nh] (dt in B-side)
        y_intra = jnp.einsum("blmh,bmhd->blhd", M, xh_j)
        # contribution of carried state
        state_decay = jnp.exp(cum_j)                             # [B,L,nh]
        y_state = jnp.einsum("bln,bhnd,blh->blhd", C_j, state, state_decay)
        # new state
        chunk_decay = jnp.exp(cum_j[:, -1:, :] - cum_j)          # [B,L,nh]
        wB = B_j[:, :, None, :] * (dA_j * chunk_decay)[..., None]  # [B,L,nh,N]
        new_state = state * jnp.exp(cum_j[:, -1, :])[..., None, None] \
            + jnp.einsum("blhn,blhd->bhnd", wB, xh_j)
        return new_state, y_intra + y_state

    init = jnp.zeros((B, nh, N, hd), jnp.float32)
    xs_in = (xh_c.transpose(1, 0, 2, 3, 4), B_c.transpose(1, 0, 2, 3),
             C_c.transpose(1, 0, 2, 3), dt_c.transpose(1, 0, 2, 3),
             cum.transpose(1, 0, 2, 3))
    last_state, ys = jax.lax.scan(chunk_body, init, xs_in)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hd)
    y = y + xh * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_g"])
    return y @ p["w_out"], last_state


def mamba2_decode(p, x, cfg, state):
    """Single-step update. x: [B, D]; state: (ssm [B,nh,N,hd] f32, conv [B,3,F])."""
    ssm_state, conv_state = state
    B, D = x.shape
    d_inner, nh, hd, N = mamba2_dims(cfg)
    z, xs, Bc, Cc, dt, A = _mamba2_project(p, x[:, None, :], cfg)
    xs, conv_state = _causal_conv(xs, p["conv"], conv_state)
    xh = xs.reshape(B, nh, hd).astype(jnp.float32)
    dA = jnp.exp(dt[:, 0] * A)                                   # [B,nh]
    Bf = Bc[:, 0].astype(jnp.float32)                            # [B,N]
    Cf = Cc[:, 0].astype(jnp.float32)
    ssm_state = ssm_state * dA[..., None, None] + \
        jnp.einsum("bn,bh,bhd->bhnd", Bf, dt[:, 0], xh)
    y = jnp.einsum("bn,bhnd->bhd", Cf, ssm_state)
    y = y + xh * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, 0]), p["norm_g"])
    return y @ p["w_out"], (ssm_state, conv_state)


def mamba2_state_shape(cfg, B):
    d_inner, nh, hd, N = mamba2_dims(cfg)
    return ((B, nh, N, hd), (B, 3, d_inner))


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) + sLSTM (scalar memory)
# ---------------------------------------------------------------------------


def mlstm_param_specs(cfg) -> Dict[str, ParamSpec]:
    d, nh = cfg.d_model, cfg.n_heads
    hd = d // nh
    return {
        "wq": ParamSpec((d, d), ("D", "H")),
        "wk": ParamSpec((d, d), ("D", "H")),
        "wv": ParamSpec((d, d), ("D", "H")),
        "wi": ParamSpec((d, nh), ("D", "Hm")),
        "wf": ParamSpec((d, nh), ("D", "Hm")),
        "wo_gate": ParamSpec((d, d), ("D", "H")),
        "w_out": ParamSpec((d, d), ("H", "D")),
        "norm_g": ParamSpec((d,), ("H",), init="ones"),
    }


def mlstm_block(p, x, cfg):
    """Parallel (training) mLSTM: decayed linear attention. x: [B,S,D]."""
    B, S, D = x.shape
    nh = cfg.n_heads
    hd = D // nh
    q = (x @ p["wq"]).reshape(B, S, nh, hd).astype(jnp.float32) / np.sqrt(hd)
    k = (x @ p["wk"]).reshape(B, S, nh, hd).astype(jnp.float32) / np.sqrt(hd)
    v = (x @ p["wv"]).reshape(B, S, nh, hd).astype(jnp.float32)
    logf = jax.nn.log_sigmoid((x @ p["wf"]).astype(jnp.float32))   # [B,S,nh]
    logi = (x @ p["wi"]).astype(jnp.float32)
    F = jnp.cumsum(logf, axis=1)
    # D_ts = exp(F_t - F_s + i_s) stabilized, causal
    logD = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]  # [B,t,s,nh]
    mask = jnp.tril(jnp.ones((S, S), bool))
    logD = jnp.where(mask[None, :, :, None], logD, -jnp.inf)
    m = jnp.max(logD, axis=2, keepdims=True)
    Dmat = jnp.exp(logD - m)
    scores = jnp.einsum("bthd,bshd->btsh", q, k) * Dmat
    norm = jnp.maximum(jnp.abs(scores.sum(2)), jnp.exp(-m[:, :, 0, :]))  # [B,t,nh]
    y = jnp.einsum("btsh,bshd->bthd", scores, v) / norm[..., None]
    y = rms_norm(y.reshape(B, S, D).astype(x.dtype), p["norm_g"])
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    return (y * o) @ p["w_out"]


def mlstm_decode(p, x, cfg, state):
    """Recurrent mLSTM step. state: (C [B,nh,hd,hd], n [B,nh,hd], m [B,nh])."""
    C, n, mprev = state
    B, D = x.shape
    nh = cfg.n_heads
    hd = D // nh
    q = (x @ p["wq"]).reshape(B, nh, hd).astype(jnp.float32) / np.sqrt(hd)
    k = (x @ p["wk"]).reshape(B, nh, hd).astype(jnp.float32) / np.sqrt(hd)
    v = (x @ p["wv"]).reshape(B, nh, hd).astype(jnp.float32)
    logf = jax.nn.log_sigmoid((x @ p["wf"]).astype(jnp.float32))
    logi = (x @ p["wi"]).astype(jnp.float32)
    m_new = jnp.maximum(logf + mprev, logi)
    fg = jnp.exp(logf + mprev - m_new)
    ig = jnp.exp(logi - m_new)
    C = C * fg[..., None, None] + ig[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = n * fg[..., None] + ig[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, D).astype(x.dtype)
    y = rms_norm(y, p["norm_g"])
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    return (y * o) @ p["w_out"], (C, n, m_new)


def slstm_param_specs(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    return {
        "w_zifo": ParamSpec((d, 4 * d), ("D", "F")),
        "r_zifo": ParamSpec((d, 4 * d), ("D", "F"), scale=0.5),
        "norm_g": ParamSpec((d,), ("H",), init="ones"),
        "w_out": ParamSpec((d, d), ("H", "D")),
    }


def _slstm_step(p, carry, x_t):
    h, c, n, m = carry                                            # [B,D] f32 each
    D = h.shape[-1]
    g = (x_t @ p["w_zifo"]).astype(jnp.float32) + h.astype(x_t.dtype) @ p["r_zifo"]
    z, i, f, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    m_new = jnp.maximum(f + m, i)
    ig = jnp.exp(i - m_new)
    fg = jnp.exp(f + m - m_new)
    c = fg * c + ig * jnp.tanh(z)
    n = fg * n + ig
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1e-6)
    return (h, c, n, m_new)


def slstm_block(p, x, cfg):
    """Sequential sLSTM over time (lax.scan). x: [B,S,D]."""
    B, S, D = x.shape
    init = tuple(jnp.zeros((B, D), jnp.float32) for _ in range(4))

    def body(carry, x_t):
        new = _slstm_step(p, carry, x_t)
        return new, new[0]

    _, hs = jax.lax.scan(body, init, x.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    return rms_norm(y, p["norm_g"]) @ p["w_out"]


def slstm_decode(p, x, cfg, state):
    new = _slstm_step(p, state, x)
    y = rms_norm(new[0].astype(x.dtype), p["norm_g"]) @ p["w_out"]
    return y, new
