"""Quickstart: build a SAC-served model, prefill, decode with top-k
fetching, and inspect what moved over the fabric.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.transfer import CXL, RDMA
from repro.models.model import build_model


def main():
    # 1. pick an architecture (any of the 10 assigned + deepseek-v32)
    cfg = get_config("deepseek-v32").reduced()   # tiny CPU-sized variant
    print(f"arch={cfg.name} (MLA latent KV, lightning indexer, "
          f"top-k={cfg.sac.topk})")

    # 2. build the SAC-mode model: decode fetches only top-k entries
    model = build_model(cfg, mode="sac")
    params = model.init(jax.random.PRNGKey(0))

    # 3. prefill a prompt -> KV entries + indexer keys land in the pool
    B, S = 2, 48
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    state, last_logits = model.prefill(params, prompt)
    print(f"prefill: pool kv {state['kv_pool'].shape} "
          f"idx {state['idx_pool'].shape}")

    # 4. decode: per layer, scores -> top-k -> fetch -> sparse attention
    toks = jnp.argmax(last_logits, -1).astype(jnp.int32)
    for step in range(5):
        state, logits = model.decode(params, state, toks)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        print(f"  step {step}: tokens {toks.tolist()} "
              f"cache_len {state['cache_len'].tolist()}")

    # 5. the paper's point, in numbers: per-step fabric traffic
    k = min(cfg.sac.topk, S)
    entry = cfg.kv_bytes_per_token_layer
    n_layers = cfg.n_layers
    sparse_bytes = k * entry * n_layers
    full_bytes = S * entry * n_layers
    print(f"\nper-request per-step fetch: top-k {sparse_bytes} B vs "
          f"full-prefetch {full_bytes} B")
    t_cxl = sum(CXL.sparse_fetch_time(k, entry) for _ in range(n_layers))
    t_rdma = sum(RDMA.sparse_fetch_time(k, entry) for _ in range(n_layers))
    print(f"fetch latency: CXL {t_cxl*1e6:.1f}us vs per-layer RDMA "
          f"{t_rdma*1e6:.1f}us  ({t_rdma/t_cxl:.1f}x — why the paper "
          f"excludes RDMA dynamic top-k)")


if __name__ == "__main__":
    main()
