"""End-to-end serving: continuous batching over the SAC cache with real
pool reads/writes, radix prefix reuse, and fabric accounting — then the
same workload on the cluster simulator at paper scale.

    PYTHONPATH=src python examples/serve_sac.py
"""
from repro.configs import get_config
from repro.serving.engine import Engine
from repro.serving.request import sharegpt_trace
from repro.serving.simulator import (SimConfig, default_backends,
                                     profile_from_config, simulate)


def main():
    # ---- real engine (reduced model, CPU) ----
    cfg = get_config("qwen2-1.5b").reduced()
    eng = Engine(cfg, slots=4, max_ctx=96, backend="cxl")
    reqs = sharegpt_trace(8, context_len=40, output_len=8, seed=0,
                          ctx_jitter=0.2, vocab=cfg.vocab)
    out = eng.run(reqs)
    print("== real engine (reduced qwen2, CXL backend) ==")
    for k in ("n_done", "throughput_tok_s", "engine_steps",
              "radix_hit_tokens", "fabric_time_s"):
        print(f"  {k}: {out[k]}")

    # ---- cluster simulator at paper scale (DeepSeek-V3.2, 8xH20) ----
    print("\n== simulator: Round-2, ctx=64K, concurrency 64 ==")
    model = profile_from_config(get_config("deepseek-v32"))
    backends = default_backends()
    trace = sharegpt_trace(256, context_len=65536, output_len=1024, seed=1)
    for name in ("cxl", "rdma", "dram", "hbm"):
        r = simulate(trace, model, backends[name], SimConfig(concurrency=64))
        print(f"  {name:>5}: {r['throughput_tok_s']:7.0f} tok/s   "
              f"ttft {r['ttft_mean_s']:6.2f}s   tbt {r['tbt_mean_s']*1e3:5.1f}ms")


if __name__ == "__main__":
    main()
