"""Train a ~small sparse-attention LM (MLA + lightning indexer weights)
for a few hundred steps on CPU — the end-to-end training driver.

    PYTHONPATH=src python examples/train_sparse_lm.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models.model import build_model
from repro.training.data import batch_iterator
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="deepseek-v32")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"training {cfg.name} (reduced): {n/1e6:.2f}M params, "
          f"WSD schedule, {args.steps} steps")

    opt = init_opt_state(params)
    ocfg = OptConfig(lr=2e-3, schedule="wsd",
                     warmup_steps=args.steps // 10, total_steps=args.steps)
    step = jax.jit(make_train_step(model, ocfg, grad_accum=2),
                   donate_argnums=(0, 1))
    it = batch_iterator(cfg, ShapeConfig("ex", 64, 16, "train"))
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"  step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print("done — loss should have dropped by >1 nat on the synthetic "
          "zipf+copy stream")


if __name__ == "__main__":
    main()
