"""Fault-tolerance walkthrough: train, checkpoint, corrupt the newest
snapshot (simulated torn write / node crash), restore onto a re-meshed
"cluster", resume bit-exactly.

    PYTHONPATH=src python examples/failover_restart.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.distributed.elastic import SkipSlowReducer, remesh, reshard_tree
from repro.models.model import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import batch_iterator
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_loop import make_train_step


def main():
    cfg = get_config("granite-34b").reduced()
    model = build_model(cfg)
    ocfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=20,
                     schedule="const")
    step = jax.jit(make_train_step(model, ocfg, 1))
    shape = ShapeConfig("ex", 32, 8, "train")

    params, opt = model.init(jax.random.PRNGKey(0)), None
    opt = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        it = batch_iterator(cfg, shape)
        for i in range(4):
            b = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt, m = step(params, opt, b)
            ckpt.save(d, i + 1, {"p": params, "o": opt},
                      extras={"data_step": i + 1})
        print(f"trained 4 steps, snapshots: "
              f"{sorted(os.listdir(d))}")

        # simulate a torn write on the newest snapshot
        victim = os.path.join(d, "step_000000004", "arr_00000.npy")
        with open(victim, "wb") as f:
            f.write(b"torn write from a dying node")
        print("corrupted newest snapshot (node crash mid-write)")

        # restart: restore newest CONSISTENT snapshot
        restored, s, extras = ckpt.restore(d, {"p": params, "o": opt})
        print(f"restored step {s} (fell back past the corrupt snapshot)")
        assert s == 3 and extras["data_step"] == 3

        # elastic: re-mesh onto the surviving devices and reshard
        mesh = remesh(len(jax.devices()))
        on_mesh = reshard_tree(restored["p"], model.specs, mesh)
        print(f"resharded onto mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

        # resume with the restored data cursor: bit-exact continuation
        p2, o2 = restored["p"], restored["o"]
        it2 = batch_iterator(cfg, shape, start_step=extras["data_step"])
        b = {k: jnp.asarray(v) for k, v in next(it2).items()}
        p2, o2, m2 = step(p2, o2, b)
        print(f"resumed: step-4 loss (replayed) = {float(m2['loss']):.5f}")

    # straggler mitigation: drop the slow host, rescale the mean
    red = SkipSlowReducer(n_hosts=4)
    g = lambda v: {"w": np.full((2,), float(v))}
    grads, report = red.aggregate(0, {0: (g(1), 0.1), 1: (g(2), 0.1),
                                      2: (g(3), 0.12), 3: (g(9), 3.0)})
    print(f"straggler aggregation: kept {report.contributors}/4 hosts, "
          f"skipped {report.skipped}, grad mean {grads['w'][0]:.2f}")


if __name__ == "__main__":
    main()
