"""Programmatic entry point shared by the CLI and the self-check test."""
from __future__ import annotations

from pathlib import Path
from typing import Dict, Iterable, Optional

from tools.sacheck.config import SacheckConfig, repo_config
from tools.sacheck.core import (CheckContext, RunResult, collect_files,
                                run_passes)
from tools.sacheck.passes import PASSES

#: repo-relative trees sacheck analyzes
DEFAULT_SUBDIRS = ("src",)
BASELINE_NAME = "baseline.json"


def repo_root(start: Optional[Path] = None) -> Path:
    """Nearest ancestor containing src/repro (works from any cwd)."""
    p = (start or Path(__file__)).resolve()
    for cand in [p] + list(p.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise SystemExit("sacheck: cannot locate the repo root "
                     "(no src/repro above " + str(p) + ")")


def baseline_path(root: Path) -> Path:
    return root / "tools" / "sacheck" / BASELINE_NAME


def check_tree(root: Path, *, config: Optional[SacheckConfig] = None,
               passes: Optional[Dict] = None,
               baseline: Iterable[str] = (),
               subdirs: Iterable[str] = DEFAULT_SUBDIRS) -> RunResult:
    """Run (a subset of) the passes over ``root`` and return the split
    result.  ``root`` may be the real repo or a fixture tree mirroring
    its layout (tests/test_sacheck.py)."""
    files = collect_files(root, subdirs)
    ctx = CheckContext(root=root, files=files,
                       config=config or repo_config())
    return run_passes(ctx, passes or PASSES, baseline)
