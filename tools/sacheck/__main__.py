"""sacheck CLI.

    python -m tools.sacheck                  # all passes, baseline applied
    python -m tools.sacheck units jit-purity # a subset of passes
    python -m tools.sacheck --json report.json
    python -m tools.sacheck --write-baseline # record current findings

Exit status: 0 clean (modulo baseline), 1 new findings, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.sacheck.api import baseline_path, check_tree, repo_root
from tools.sacheck.core import load_baseline, save_baseline
from tools.sacheck.passes import PASSES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="sacheck")
    ap.add_argument("passes", nargs="*",
                    help=f"passes to run (default: all of "
                         f"{', '.join(PASSES)})")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the full machine-readable report here")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file (default: tools/sacheck/"
                         "baseline.json under the root)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record every current finding into the baseline "
                         "(prunes stale entries) and exit 0")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    root = (args.root or repo_root(Path.cwd())).resolve()
    for p in args.passes:
        if p not in PASSES:
            ap.error(f"unknown pass {p!r} (have: {', '.join(PASSES)})")
    passes = ({k: PASSES[k] for k in args.passes} if args.passes
              else dict(PASSES))
    bpath = args.baseline or baseline_path(root)
    baseline = load_baseline(bpath)

    res = check_tree(root, passes=passes, baseline=baseline)

    if args.write_baseline:
        fps = [f.fingerprint for f in res.new + res.baselined]
        save_baseline(bpath, fps)
        print(f"sacheck: baseline written to {bpath} "
              f"({len(set(fps))} entries)")
        return 0

    if not args.quiet:
        for f in res.new:
            print(f.render())
        if res.baselined:
            print(f"sacheck: {len(res.baselined)} baselined finding(s) "
                  f"tolerated (see {bpath.name})")
        if res.suppressed:
            print(f"sacheck: {len(res.suppressed)} finding(s) suppressed "
                  f"inline with reasons")
        if res.stale_baseline:
            print(f"sacheck: NOTE {len(res.stale_baseline)} stale "
                  f"baseline entr(ies) no longer fire — run "
                  f"--write-baseline to prune")
    if args.json:
        args.json.write_text(json.dumps({
            "root": str(root),
            "passes": sorted(passes),
            "new": [vars(f) for f in res.new],
            "baselined": [vars(f) for f in res.baselined],
            "suppressed": [
                {"finding": vars(f), "reason": s.reason,
                 "line": s.line} for f, s in res.suppressed],
            "stale_baseline": res.stale_baseline,
            "ok": res.ok,
        }, indent=1) + "\n")
    if res.ok:
        if not args.quiet:
            print(f"sacheck: clean ({len(passes)} passes, "
                  f"{len(res.baselined)} baselined, "
                  f"{len(res.suppressed)} suppressed)")
        return 0
    print(f"sacheck: {len(res.new)} NEW finding(s) — fix them, suppress "
          f"inline with a reason, or (pre-existing debt only) "
          f"--write-baseline", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
