"""sacheck — repo-invariant static-analysis suite (PR 9).

Five passes, each guarding one invariant the codebase's correctness
story rests on (see tools/sacheck/passes/*.py for the why of each):

  twin-coverage        engine<->simulator knob parity + serve.py flags
  units                _s/_bytes/_tokens/_frac suffix discipline
  accounting-boundary  TrafficStats mutated only via FabricAccountant
  jit-purity           no RNG/time/global/concretizing casts under jit
  determinism          no global-state RNG; no unordered set iteration

Run:    python -m tools.sacheck            (from the repo root)
        make lint                          (sacheck + ruff)
"""
from tools.sacheck.api import check_tree, repo_root  # noqa: F401
from tools.sacheck.passes import PASSES  # noqa: F401
